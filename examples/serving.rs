//! Serving example: compile a trained model to its fastest engine (§3.7),
//! serve concurrent batched requests from multiple threads through the
//! allocation-free batch path (`predict_into` writes into a reusable
//! per-client buffer), and report latency/throughput — including the
//! PJRT/XLA engine when `make artifacts` has been run.
//!
//! Run: `cargo run --release --example serving`

use std::sync::Arc;
use ydf::dataset::synthetic;
use ydf::inference::{compile_engines, InferenceEngine};
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};

fn main() {
    // Train the model to serve.
    let spec = synthetic::spec_by_name("Wilt").unwrap();
    let opts = synthetic::GenOptions { max_examples: 2000, ..Default::default() };
    let train = synthetic::generate(spec, 41, &opts);
    let mut cfg = GbtConfig::new("label");
    cfg.num_trees = 40;
    cfg.max_depth = 5;
    let model = GradientBoostedTreesLearner::new(cfg).train(&train).unwrap();

    // Engine selection (§3.7): all compatible engines, fastest first.
    // The first one is what the serving loop below (and `predict_flat`)
    // auto-selects — print it rather than choosing silently.
    let engines = compile_engines(model.as_ref());
    println!("compatible engines:");
    for (i, e) in engines.iter().enumerate() {
        let marker = if i == 0 { "  <- auto-selected" } else { "" };
        println!("  {}{marker}", e.name());
    }

    // Optional PJRT engine, if the XLA artifact is available.
    let pjrt: Option<Arc<dyn InferenceEngine>> =
        match ydf::runtime::Runtime::cpu().and_then(|rt| {
            ydf::inference::pjrt::PjrtEngine::compile(model.as_ref(), &rt)
        }) {
            Ok(e) => {
                println!("  {} (XLA artifact)", e.name());
                Some(Arc::new(e))
            }
            Err(e) => {
                println!("  (PJRT engine unavailable: {e})");
                None
            }
        };

    // Serve: 4 client threads, batched requests, measure latency. Each
    // client allocates its output buffer once and the engine writes
    // predictions into it — the steady-state request loop performs no
    // heap allocation.
    let engine: Arc<dyn InferenceEngine> = Arc::from(
        compile_engines(model.as_ref()).remove(0), // fastest
    );
    let requests_per_client = 50usize;
    let batch = synthetic::generate(
        spec,
        42,
        &synthetic::GenOptions { max_examples: 64, ..Default::default() },
    );
    let dim = engine.output_dim();
    let t0 = std::time::Instant::now();
    let mut latencies: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let engine = Arc::clone(&engine);
                let batch = &batch;
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(requests_per_client);
                    let mut out = vec![0.0f64; batch.num_rows() * dim];
                    for _ in 0..requests_per_client {
                        let t = std::time::Instant::now();
                        engine.predict_into(batch, 1, &mut out);
                        std::hint::black_box(&mut out);
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    lat
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total_examples = 4 * requests_per_client * batch.num_rows();
    println!(
        "served {} batched requests ({} examples) in {:.2}s  ->  {:.0} examples/s",
        4 * requests_per_client,
        total_examples,
        wall,
        total_examples as f64 / wall
    );
    println!(
        "batch latency p50={:.3}ms p95={:.3}ms p99={:.3}ms",
        latencies[latencies.len() / 2],
        latencies[latencies.len() * 95 / 100],
        latencies[latencies.len() * 99 / 100]
    );

    // One PJRT batch for comparison, if available.
    if let Some(p) = pjrt {
        let mut out = vec![0.0f64; batch.num_rows() * p.output_dim()];
        let t = std::time::Instant::now();
        p.predict_into(&batch, 1, &mut out);
        println!(
            "PJRT/XLA engine: {} predictions in {:.3}ms",
            batch.num_rows(),
            t.elapsed().as_secs_f64() * 1e3
        );
    }
}
