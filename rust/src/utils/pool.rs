//! A small scoped thread pool (rayon is unavailable offline).
//!
//! Used by the Random Forest learner (per-tree parallelism), the distributed
//! backend and the serving example. Work items are closures; `scope_map`
//! offers the common "parallel map over indices" pattern.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Runs `f(i)` for `i in 0..n` across `threads` OS threads and returns the
/// results in index order. Falls back to sequential execution when
/// `threads <= 1` (the common case on this single-core testbed).
///
/// Work is handed out as contiguous index blocks through one atomic
/// counter (dynamic balancing for uneven items like RF trees); each
/// thread appends results to its own buffers, which are stitched back in
/// index order at the end. No per-item synchronization — the old
/// `Mutex<Option<T>>`-per-item scheme cost one allocation and one lock
/// round-trip per item on the training and batch-inference hot paths.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let threads = threads.min(n);
    // ~4 blocks per thread: coarse enough to amortize the counter, fine
    // enough to balance uneven per-item cost.
    let block = n.div_ceil(threads * 4).max(1);
    let next = AtomicUsize::new(0);
    let mut pieces: Vec<(usize, Vec<T>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local: Vec<(usize, Vec<T>)> = Vec::new();
                    loop {
                        let start = next.fetch_add(block, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + block).min(n);
                        let mut buf = Vec::with_capacity(end - start);
                        for i in start..end {
                            buf.push(f(i));
                        }
                        local.push((start, buf));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("parallel_map worker panicked"))
            .collect()
    });
    pieces.sort_by_key(|&(start, _)| start);
    let mut out = Vec::with_capacity(n);
    for (_, mut piece) in pieces {
        out.append(&mut piece);
    }
    debug_assert_eq!(out.len(), n);
    out
}

/// Long-lived worker pool with explicit job submission; used by the
/// distributed backend to model persistent training workers.
pub struct WorkerPool {
    senders: Vec<std::sync::mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = std::sync::mpsc::channel::<Job>();
            senders.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ydf-worker-{w}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("failed to spawn worker"),
            );
        }
        WorkerPool { senders, handles }
    }

    pub fn num_workers(&self) -> usize {
        self.senders.len()
    }

    /// Submits a job to a specific worker (the feature-parallel algorithm
    /// pins features to workers, so placement matters).
    pub fn submit_to<F: FnOnce() + Send + 'static>(&self, worker: usize, f: F) {
        self.senders[worker].send(Box::new(f)).expect("worker channel closed");
    }

    /// Runs `f(w)` on every worker and blocks until all complete.
    pub fn broadcast<F>(&self, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        for w in 0..self.senders.len() {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            self.submit_to(w, move || {
                f(w);
                let _ = done.send(());
            });
        }
        for _ in 0..self.senders.len() {
            done_rx.recv().expect("worker died");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.senders.clear(); // close channels, letting workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_sequential_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_map_uneven_blocks() {
        // n not divisible by threads*4: tail blocks must still land in
        // index order.
        for n in [2usize, 7, 10, 65, 100] {
            for threads in [2usize, 3, 8] {
                let out = parallel_map(n, threads, |i| 3 * i);
                assert_eq!(out, (0..n).map(|i| 3 * i).collect::<Vec<_>>(), "n={n} t={threads}");
            }
        }
    }

    #[test]
    fn worker_pool_broadcast_touches_all() {
        let pool = WorkerPool::new(3);
        static COUNT: AtomicU64 = AtomicU64::new(0);
        pool.broadcast(|_w| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn worker_pool_submit_to_runs() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit_to(1, move || tx.send(42).unwrap());
        assert_eq!(rx.recv().unwrap(), 42);
    }
}
