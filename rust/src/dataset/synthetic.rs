//! Synthetic benchmark suite substituting for the paper's 70 OpenML
//! datasets (Table 5) — this environment has no network access.
//!
//! Each spec mirrors a Table 5 row (name, #examples, #numerical and
//! #categorical features) and adds a class count (taken from the well-known
//! dataset when applicable, 2 otherwise). Labels are produced by a hidden
//! *teacher*: a small random decision forest plus a linear component and
//! label noise — so tree learners, oblique splits and linear models all
//! receive exploitable (but different) signal, which is what drives the
//! paper's relative comparisons.

use super::dataspec::{ColumnSpec, DataSpec, NumericalStats};
use super::{ColumnData, Dataset, MISSING_CAT};
use crate::utils::rng::Rng;
use crate::utils::stats::{softmax_in_place, Moments};

/// One synthetic dataset specification (a Table 5 row).
#[derive(Clone, Copy, Debug)]
pub struct SyntheticSpec {
    pub name: &'static str,
    pub examples: usize,
    pub numerical: usize,
    pub categorical: usize,
    pub classes: usize,
}

impl SyntheticSpec {
    pub fn features(&self) -> usize {
        self.numerical + self.categorical
    }
}

/// The 70 dataset specs of Table 5 (name, examples, categorical, numerical
/// features; class counts from the public datasets where known).
pub const TABLE5: &[SyntheticSpec] = &[
    SyntheticSpec { name: "Adult", examples: 48842, numerical: 6, categorical: 8, classes: 2 },
    SyntheticSpec { name: "Adult_v2", examples: 32561, numerical: 6, categorical: 8, classes: 2 },
    SyntheticSpec { name: "Analcatdata_Authorship", examples: 841, numerical: 70, categorical: 0, classes: 4 },
    SyntheticSpec { name: "AnalcatData_Dmft", examples: 797, numerical: 2, categorical: 2, classes: 6 },
    SyntheticSpec { name: "Balance_Scale", examples: 625, numerical: 4, categorical: 0, classes: 3 },
    SyntheticSpec { name: "Bank_Marketing", examples: 45211, numerical: 7, categorical: 9, classes: 2 },
    SyntheticSpec { name: "Banknote_Authentication", examples: 1372, numerical: 4, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Beast_W", examples: 699, numerical: 8, categorical: 1, classes: 2 },
    SyntheticSpec { name: "Bioresponce", examples: 3751, numerical: 1776, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Blood_Transfusion", examples: 748, numerical: 4, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Car", examples: 1728, numerical: 0, categorical: 6, classes: 4 },
    SyntheticSpec { name: "Churn", examples: 5000, numerical: 20, categorical: 0, classes: 2 },
    SyntheticSpec { name: "ClimateC", examples: 540, numerical: 20, categorical: 0, classes: 2 },
    SyntheticSpec { name: "CMC", examples: 1473, numerical: 9, categorical: 0, classes: 3 },
    SyntheticSpec { name: "CNAE9", examples: 1080, numerical: 856, categorical: 0, classes: 9 },
    SyntheticSpec { name: "Connect4", examples: 67557, numerical: 42, categorical: 0, classes: 3 },
    SyntheticSpec { name: "Credit_Approval", examples: 690, numerical: 4, categorical: 11, classes: 2 },
    SyntheticSpec { name: "Credit_G", examples: 1000, numerical: 7, categorical: 13, classes: 2 },
    SyntheticSpec { name: "Cylinder_Bands", examples: 540, numerical: 4, categorical: 35, classes: 2 },
    SyntheticSpec { name: "Diabetes", examples: 768, numerical: 8, categorical: 0, classes: 2 },
    SyntheticSpec { name: "DNA", examples: 3186, numerical: 180, categorical: 0, classes: 3 },
    SyntheticSpec { name: "Dresses_Sales", examples: 500, numerical: 1, categorical: 11, classes: 2 },
    SyntheticSpec { name: "Eletricity", examples: 45312, numerical: 8, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Eucalyptus", examples: 736, numerical: 5, categorical: 14, classes: 5 },
    SyntheticSpec { name: "FOTheorem", examples: 6118, numerical: 51, categorical: 0, classes: 6 },
    SyntheticSpec { name: "GestureSeg", examples: 9873, numerical: 32, categorical: 0, classes: 5 },
    SyntheticSpec { name: "GSarBD", examples: 1055, numerical: 41, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Har", examples: 10299, numerical: 561, categorical: 0, classes: 6 },
    SyntheticSpec { name: "ILPD", examples: 583, numerical: 9, categorical: 1, classes: 2 },
    SyntheticSpec { name: "IntAds", examples: 3279, numerical: 1558, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Iris", examples: 150, numerical: 4, categorical: 0, classes: 3 },
    SyntheticSpec { name: "Isolet", examples: 7797, numerical: 617, categorical: 0, classes: 26 },
    SyntheticSpec { name: "JM1", examples: 10885, numerical: 16, categorical: 5, classes: 2 },
    SyntheticSpec { name: "JChess2PCs", examples: 44819, numerical: 6, categorical: 0, classes: 3 },
    SyntheticSpec { name: "KC1", examples: 2109, numerical: 21, categorical: 0, classes: 2 },
    SyntheticSpec { name: "KC2", examples: 522, numerical: 21, categorical: 0, classes: 2 },
    SyntheticSpec { name: "KRvsKP", examples: 3196, numerical: 0, categorical: 36, classes: 2 },
    SyntheticSpec { name: "Letter", examples: 20000, numerical: 16, categorical: 0, classes: 26 },
    SyntheticSpec { name: "Madelon", examples: 2600, numerical: 500, categorical: 0, classes: 2 },
    SyntheticSpec { name: "MFeatF", examples: 2000, numerical: 216, categorical: 0, classes: 10 },
    SyntheticSpec { name: "MFeatFou", examples: 2000, numerical: 76, categorical: 0, classes: 10 },
    SyntheticSpec { name: "MFeatK", examples: 2000, numerical: 64, categorical: 0, classes: 10 },
    SyntheticSpec { name: "MFeat", examples: 2000, numerical: 6, categorical: 0, classes: 10 },
    SyntheticSpec { name: "MFeat_Pixel", examples: 2000, numerical: 240, categorical: 0, classes: 10 },
    SyntheticSpec { name: "MFeat_Zernike", examples: 2000, numerical: 47, categorical: 0, classes: 10 },
    SyntheticSpec { name: "Mice_Protein", examples: 1080, numerical: 28, categorical: 53, classes: 8 },
    SyntheticSpec { name: "Nomao", examples: 34465, numerical: 118, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Numerai28_6", examples: 96320, numerical: 21, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Opt_Digits", examples: 5620, numerical: 64, categorical: 0, classes: 10 },
    SyntheticSpec { name: "OzoneL8", examples: 2534, numerical: 72, categorical: 0, classes: 2 },
    SyntheticSpec { name: "PC1", examples: 1109, numerical: 21, categorical: 0, classes: 2 },
    SyntheticSpec { name: "PC3", examples: 1563, numerical: 37, categorical: 0, classes: 2 },
    SyntheticSpec { name: "PC4", examples: 1458, numerical: 37, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Pen_Digits", examples: 10992, numerical: 16, categorical: 0, classes: 10 },
    SyntheticSpec { name: "Phishing", examples: 11055, numerical: 30, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Phoneme", examples: 5404, numerical: 5, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Satimage", examples: 6430, numerical: 36, categorical: 0, classes: 6 },
    SyntheticSpec { name: "Segment", examples: 2310, numerical: 19, categorical: 0, classes: 7 },
    SyntheticSpec { name: "Semeion", examples: 1593, numerical: 256, categorical: 0, classes: 10 },
    SyntheticSpec { name: "Sick", examples: 3772, numerical: 0, categorical: 29, classes: 2 },
    SyntheticSpec { name: "Spambase", examples: 4601, numerical: 57, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Splice", examples: 3190, numerical: 0, categorical: 61, classes: 3 },
    SyntheticSpec { name: "SteelPlatesF", examples: 1941, numerical: 27, categorical: 0, classes: 7 },
    SyntheticSpec { name: "Texture", examples: 5500, numerical: 40, categorical: 0, classes: 11 },
    SyntheticSpec { name: "TicTacToe", examples: 958, numerical: 0, categorical: 9, classes: 2 },
    SyntheticSpec { name: "Vehicule", examples: 846, numerical: 18, categorical: 0, classes: 4 },
    SyntheticSpec { name: "Vowel", examples: 990, numerical: 10, categorical: 2, classes: 11 },
    SyntheticSpec { name: "Wall_Robot_Navigation", examples: 5456, numerical: 24, categorical: 0, classes: 4 },
    SyntheticSpec { name: "WDBC", examples: 569, numerical: 30, categorical: 0, classes: 2 },
    SyntheticSpec { name: "Wilt", examples: 4839, numerical: 5, categorical: 0, classes: 2 },
];

/// Looks up a Table 5 spec by name.
pub fn spec_by_name(name: &str) -> Option<&'static SyntheticSpec> {
    TABLE5.iter().find(|s| s.name == name)
}

/// Generation options.
#[derive(Clone, Debug)]
pub struct GenOptions {
    /// Cap on generated examples (the full Table 5 sizes are impractical on
    /// this single-core testbed; the cap is reported by the harness).
    pub max_examples: usize,
    /// Fraction of feature cells turned into missing values.
    pub missing_rate: f64,
    /// Label noise: probability of resampling the label uniformly.
    pub label_noise: f64,
    /// Cap on generated features (speeds up the wide 1.7k-feature sets).
    pub max_features: usize,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions { max_examples: usize::MAX, missing_rate: 0.02, label_noise: 0.05, max_features: usize::MAX }
    }
}

/// Hidden teacher: a small random forest over the latent feature values
/// plus a linear component. Both tree and linear learners can extract
/// signal; trees more of it (matching the benchmark's outcome structure).
struct Teacher {
    // Depth-1 stumps plus depth-2 interactions (XOR-like structure that
    // axis-aligned trees capture and linear/one-hot models cannot).
    stumps: Vec<TeacherStump>,
    linear_w: Vec<Vec<f64>>, // [classes][num_features]
    classes: usize,
}

enum TeacherStump {
    Numerical { feature: usize, threshold: f64, logits_lo: Vec<f64>, logits_hi: Vec<f64> },
    Categorical { feature: usize, mask: Vec<bool>, logits_in: Vec<f64>, logits_out: Vec<f64> },
    /// Interaction of two tests: four logit vectors, one per quadrant.
    Interaction {
        a: TeacherTest,
        b: TeacherTest,
        logits: [Vec<f64>; 4],
    },
}

enum TeacherTest {
    Num { feature: usize, threshold: f64 },
    Cat { feature: usize, mask: Vec<bool> },
}

impl TeacherTest {
    fn eval(&self, num: &[f64], cat: &[usize]) -> bool {
        match self {
            TeacherTest::Num { feature, threshold } => num[*feature] >= *threshold,
            TeacherTest::Cat { feature, mask } => mask[cat[*feature] % mask.len()],
        }
    }
}

impl Teacher {
    fn new(num_numerical: usize, cat_cards: &[usize], classes: usize, rng: &mut Rng) -> Teacher {
        let total_stumps = 8 + rng.uniform_usize(8);
        let mut stumps = Vec::new();
        let logits = |rng: &mut Rng| -> Vec<f64> {
            (0..classes).map(|_| rng.normal_ms(0.0, 1.2)).collect()
        };
        for _ in 0..total_stumps {
            let use_cat = !cat_cards.is_empty()
                && (num_numerical == 0 || rng.bernoulli(cat_cards.len() as f64 / (cat_cards.len() + num_numerical) as f64));
            if use_cat {
                let f = rng.uniform_usize(cat_cards.len());
                let card = cat_cards[f];
                let mask: Vec<bool> = (0..card).map(|_| rng.bernoulli(0.5)).collect();
                stumps.push(TeacherStump::Categorical {
                    feature: f,
                    mask,
                    logits_in: logits(rng),
                    logits_out: logits(rng),
                });
            } else if num_numerical > 0 {
                stumps.push(TeacherStump::Numerical {
                    feature: rng.uniform_usize(num_numerical),
                    threshold: rng.normal_ms(0.0, 0.7),
                    logits_lo: logits(rng),
                    logits_hi: logits(rng),
                });
            }
        }
        // Depth-2 interaction terms: genuinely non-additive signal that
        // tree learners exploit but linear / one-hot models cannot.
        let make_test = |rng: &mut Rng| -> Option<TeacherTest> {
            let use_cat = !cat_cards.is_empty()
                && (num_numerical == 0 || rng.bernoulli(0.4));
            if use_cat {
                let f = rng.uniform_usize(cat_cards.len());
                Some(TeacherTest::Cat {
                    feature: f,
                    mask: (0..cat_cards[f]).map(|_| rng.bernoulli(0.5)).collect(),
                })
            } else if num_numerical > 0 {
                Some(TeacherTest::Num {
                    feature: rng.uniform_usize(num_numerical),
                    threshold: rng.normal_ms(0.0, 0.7),
                })
            } else {
                None
            }
        };
        let num_interactions = 4 + rng.uniform_usize(5);
        for _ in 0..num_interactions {
            let (a, b) = match (make_test(rng), make_test(rng)) {
                (Some(a), Some(b)) => (a, b),
                _ => break,
            };
            // Amplified XOR-quadrant logits.
            let ls = [
                (0..classes).map(|_| rng.normal_ms(0.0, 1.6)).collect::<Vec<f64>>(),
                (0..classes).map(|_| rng.normal_ms(0.0, 1.6)).collect(),
                (0..classes).map(|_| rng.normal_ms(0.0, 1.6)).collect(),
                (0..classes).map(|_| rng.normal_ms(0.0, 1.6)).collect(),
            ];
            stumps.push(TeacherStump::Interaction { a, b, logits: ls });
        }
        // Linear signal over (a subset of) numerical features.
        let linear_w: Vec<Vec<f64>> = (0..classes)
            .map(|_| {
                (0..num_numerical)
                    .map(|_| if rng.bernoulli(0.4) { rng.normal_ms(0.0, 0.5) } else { 0.0 })
                    .collect()
            })
            .collect();
        Teacher { stumps, linear_w, classes }
    }

    fn label(&self, num: &[f64], cat: &[usize], rng: &mut Rng, noise: f64) -> usize {
        let mut logit = vec![0.0f64; self.classes];
        for s in &self.stumps {
            match s {
                TeacherStump::Numerical { feature, threshold, logits_lo, logits_hi } => {
                    let l = if num[*feature] >= *threshold { logits_hi } else { logits_lo };
                    for (a, b) in logit.iter_mut().zip(l) {
                        *a += b;
                    }
                }
                TeacherStump::Categorical { feature, mask, logits_in, logits_out } => {
                    let l = if mask[cat[*feature] % mask.len()] { logits_in } else { logits_out };
                    for (a, b) in logit.iter_mut().zip(l) {
                        *a += b;
                    }
                }
                TeacherStump::Interaction { a, b, logits } => {
                    let quadrant =
                        (a.eval(num, cat) as usize) * 2 + b.eval(num, cat) as usize;
                    for (acc, v) in logit.iter_mut().zip(&logits[quadrant]) {
                        *acc += v;
                    }
                }
            }
        }
        for (c, w) in self.linear_w.iter().enumerate() {
            logit[c] += w.iter().zip(num).map(|(wi, xi)| wi * xi).sum::<f64>();
        }
        softmax_in_place(&mut logit);
        if rng.bernoulli(noise) {
            return rng.uniform_usize(self.classes);
        }
        // Sample from the softmax (gives irreducible Bayes error like real
        // data rather than a deterministic function).
        let u = rng.uniform();
        let mut acc = 0.0;
        for (c, p) in logit.iter().enumerate() {
            acc += p;
            if u < acc {
                return c;
            }
        }
        self.classes - 1
    }
}

/// Generates the dataset for a spec. Deterministic in (spec.name, seed).
pub fn generate(spec: &SyntheticSpec, seed: u64, opts: &GenOptions) -> Dataset {
    // Derive the seed from the dataset name so each dataset is a distinct,
    // stable task.
    let name_hash: u64 = spec.name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    let mut rng = Rng::seed_from_u64(seed ^ name_hash);
    let n = spec.examples.min(opts.max_examples);
    let scale = (opts.max_features as f64 / spec.features().max(1) as f64).min(1.0);
    let num_numerical = if spec.numerical == 0 { 0 } else { ((spec.numerical as f64 * scale) as usize).max(1) };
    let num_categorical = if spec.categorical == 0 { 0 } else { ((spec.categorical as f64 * scale) as usize).max(1) };

    // Categorical cardinalities: 2..=24, skewed small.
    let cat_cards: Vec<usize> =
        (0..num_categorical).map(|_| 2 + rng.uniform_usize(23)).collect();
    let teacher = Teacher::new(num_numerical, &cat_cards, spec.classes, &mut rng);

    // Latent per-feature distributions.
    let num_means: Vec<f64> = (0..num_numerical).map(|_| rng.normal_ms(0.0, 1.0)).collect();
    let num_stds: Vec<f64> =
        (0..num_numerical).map(|_| rng.uniform_range(0.5, 2.0)).collect();
    let num_scales: Vec<f64> =
        (0..num_numerical).map(|_| 10f64.powf(rng.uniform_range(-1.0, 3.0))).collect();

    let mut num_data: Vec<Vec<f32>> = vec![Vec::with_capacity(n); num_numerical];
    let mut cat_data: Vec<Vec<u32>> = vec![Vec::with_capacity(n); num_categorical];
    let mut labels: Vec<u32> = Vec::with_capacity(n);
    let mut num_row = vec![0.0f64; num_numerical];
    let mut cat_row = vec![0usize; num_categorical];
    for _ in 0..n {
        for f in 0..num_numerical {
            num_row[f] = rng.normal_ms(0.0, 1.0) * num_stds[f] + num_means[f];
        }
        for f in 0..num_categorical {
            // Skewed category distribution (Zipf-ish via squaring).
            let u = rng.uniform();
            cat_row[f] = ((u * u) * cat_cards[f] as f64) as usize % cat_cards[f];
        }
        let y = teacher.label(&num_row, &cat_row, &mut rng, opts.label_noise);
        labels.push(y as u32);
        for f in 0..num_numerical {
            let missing = rng.bernoulli(opts.missing_rate);
            num_data[f].push(if missing {
                f32::NAN
            } else {
                // Per-feature affine transform so raw scales vary wildly —
                // exercising exact-splitter threshold handling.
                (num_row[f] * num_scales[f]) as f32
            });
        }
        for f in 0..num_categorical {
            let missing = rng.bernoulli(opts.missing_rate);
            cat_data[f].push(if missing { MISSING_CAT } else { cat_row[f] as u32 });
        }
    }

    // Assemble columns + spec. Label column is last, named "label".
    let mut columns = Vec::new();
    let mut col_specs = Vec::new();
    for (f, data) in num_data.into_iter().enumerate() {
        let mut m = Moments::new();
        for &v in &data {
            if !v.is_nan() {
                m.add(v as f64);
            }
        }
        let mut cs = ColumnSpec::numerical(&format!("num_{f}"));
        cs.num_stats =
            NumericalStats { mean: m.mean(), min: m.min(), max: m.max(), std: m.std() };
        cs.missing_count = data.iter().filter(|v| v.is_nan()).count() as u64;
        col_specs.push(cs);
        columns.push(ColumnData::Numerical(data));
    }
    for (f, data) in cat_data.into_iter().enumerate() {
        let card = cat_cards[f];
        let dict: Vec<String> = (0..card).map(|c| format!("v{c}")).collect();
        let mut cs = ColumnSpec::categorical(&format!("cat_{f}"), dict);
        cs.dict_counts = {
            let mut counts = vec![0u64; card];
            for &v in &data {
                if v != MISSING_CAT {
                    counts[v as usize] += 1;
                }
            }
            counts
        };
        cs.missing_count = data.iter().filter(|&&v| v == MISSING_CAT).count() as u64;
        col_specs.push(cs);
        columns.push(ColumnData::Categorical(data));
    }
    let label_dict: Vec<String> = (0..spec.classes).map(|c| format!("c{c}")).collect();
    let mut label_spec = ColumnSpec::categorical("label", label_dict);
    label_spec.dict_counts = {
        let mut counts = vec![0u64; spec.classes];
        for &y in &labels {
            counts[y as usize] += 1;
        }
        counts
    };
    col_specs.push(label_spec);
    columns.push(ColumnData::Categorical(labels));

    Dataset::new(DataSpec { columns: col_specs }, columns).expect("generated dataset is valid")
}

/// Adult-like dataset with named, human-readable features, used by the
/// usage example (§4) and the Appendix B report reproduction.
pub fn adult_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0xAD017);
    let workclasses = ["Private", "Self-emp-inc", "Self-emp-not-inc", "Federal-gov", "Local-gov"];
    let educations =
        ["HS-grad", "Some-college", "Bachelors", "Masters", "Assoc-voc", "7th-8th", "10th", "Doctorate"];
    let occupations = [
        "Machine-op-inspct", "Other-service", "Adm-clerical", "Exec-managerial",
        "Prof-specialty", "Sales", "Handlers-cleaners", "Craft-repair",
    ];
    let maritals = ["Married-civ-spouse", "Never-married", "Divorced", "Widowed"];

    let mut age = Vec::with_capacity(n);
    let mut fnlwgt = Vec::with_capacity(n);
    let mut edu = Vec::with_capacity(n);
    let mut occ = Vec::with_capacity(n);
    let mut wc = Vec::with_capacity(n);
    let mut marital = Vec::with_capacity(n);
    let mut hours = Vec::with_capacity(n);
    let mut cap_gain = Vec::with_capacity(n);
    let mut income = Vec::with_capacity(n);
    for _ in 0..n {
        let a = (17.0 + rng.uniform() * 60.0).round();
        let e = rng.uniform_usize(educations.len());
        let o = rng.uniform_usize(occupations.len());
        let w = rng.uniform_usize(workclasses.len());
        let m = rng.uniform_usize(maritals.len());
        let h = (20.0 + rng.uniform() * 40.0).round();
        let cg = if rng.bernoulli(0.08) { (rng.uniform() * 20000.0).round() } else { 0.0 };
        // Income teacher: education + age + hours + managerial/professional
        // occupations + marriage push income up; mirrors the real Adult
        // variable importances (Appendix B.2).
        let edu_score = match educations[e] {
            "Doctorate" => 2.2,
            "Masters" => 1.6,
            "Bachelors" => 1.1,
            "Assoc-voc" | "Some-college" => 0.3,
            "HS-grad" => 0.0,
            _ => -0.8,
        };
        let occ_score = match occupations[o] {
            "Exec-managerial" => 1.0,
            "Prof-specialty" => 0.8,
            "Sales" | "Adm-clerical" => 0.1,
            _ => -0.3,
        };
        let married = if maritals[m] == "Married-civ-spouse" { 1.0 } else { 0.0 };
        let z = 1.6
            * (-3.0
                + 0.035 * (a - 38.0)
                + edu_score
                + occ_score
                + 1.3 * married
                + 0.02 * (h - 40.0)
                + 0.0002 * cg)
            + rng.normal_ms(0.0, 0.5);
        let y = if crate::utils::stats::sigmoid(z) > rng.uniform() { 1u32 } else { 0u32 };
        let miss = rng.bernoulli(0.01);
        age.push(a as f32);
        fnlwgt.push((rng.uniform() * 400000.0 + 20000.0) as f32);
        edu.push(e as u32);
        occ.push(if miss { MISSING_CAT } else { o as u32 });
        wc.push(w as u32);
        marital.push(m as u32);
        hours.push(h as f32);
        cap_gain.push(cg as f32);
        income.push(y);
    }

    let mk_cat = |name: &str, dict: &[&str], data: &Vec<u32>| {
        let mut cs =
            ColumnSpec::categorical(name, dict.iter().map(|s| s.to_string()).collect());
        let mut counts = vec![0u64; dict.len()];
        for &v in data {
            if v != MISSING_CAT {
                counts[v as usize] += 1;
            }
        }
        cs.dict_counts = counts;
        cs.missing_count = data.iter().filter(|&&v| v == MISSING_CAT).count() as u64;
        cs
    };
    let mk_num = |name: &str, data: &Vec<f32>| {
        let mut m = Moments::new();
        for &v in data {
            m.add(v as f64);
        }
        let mut cs = ColumnSpec::numerical(name);
        cs.num_stats =
            NumericalStats { mean: m.mean(), min: m.min(), max: m.max(), std: m.std() };
        cs
    };

    let spec = DataSpec {
        columns: vec![
            mk_num("age", &age),
            mk_num("fnlwgt", &fnlwgt),
            mk_cat("workclass", &workclasses, &wc),
            mk_cat("education", &educations, &edu),
            mk_cat("occupation", &occupations, &occ),
            mk_cat("marital_status", &maritals, &marital),
            mk_num("hours_per_week", &hours),
            mk_num("capital_gain", &cap_gain),
            mk_cat("income", &["<=50K", ">50K"], &income),
        ],
    };
    Dataset::new(
        spec,
        vec![
            ColumnData::Numerical(age),
            ColumnData::Numerical(fnlwgt),
            ColumnData::Categorical(wc),
            ColumnData::Categorical(edu),
            ColumnData::Categorical(occ),
            ColumnData::Categorical(marital),
            ColumnData::Numerical(hours),
            ColumnData::Numerical(cap_gain),
            ColumnData::Categorical(income),
        ],
    )
    .expect("adult_like dataset is valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_has_70_datasets() {
        assert_eq!(TABLE5.len(), 70);
        // Sizes and feature ranges match the paper's summary (§5: examples
        // 150..96320, features 4..1776).
        let min_ex = TABLE5.iter().map(|s| s.examples).min().unwrap();
        let max_ex = TABLE5.iter().map(|s| s.examples).max().unwrap();
        assert_eq!(min_ex, 150);
        assert_eq!(max_ex, 96320);
        let max_f = TABLE5.iter().map(|s| s.features()).max().unwrap();
        assert_eq!(max_f, 1776);
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = spec_by_name("Iris").unwrap();
        let a = generate(spec, 1, &GenOptions::default());
        let b = generate(spec, 1, &GenOptions::default());
        assert_eq!(a.num_rows(), 150);
        let ca: Vec<u32> = a.column(0).as_numerical().unwrap().iter().map(|v| v.to_bits()).collect();
        let cb: Vec<u32> = b.column(0).as_numerical().unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(ca, cb);
    }

    #[test]
    fn different_seeds_differ() {
        let spec = spec_by_name("Iris").unwrap();
        let a = generate(spec, 1, &GenOptions::default());
        let b = generate(spec, 2, &GenOptions::default());
        assert_ne!(
            a.column(0).as_numerical().unwrap(),
            b.column(0).as_numerical().unwrap()
        );
    }

    #[test]
    fn respects_caps() {
        let spec = spec_by_name("Adult").unwrap();
        let opts = GenOptions { max_examples: 500, max_features: 6, ..Default::default() };
        let d = generate(spec, 3, &opts);
        assert_eq!(d.num_rows(), 500);
        assert!(d.num_columns() <= 8); // scaled features + label
    }

    #[test]
    fn labels_cover_classes_and_features_match_spec() {
        let spec = spec_by_name("Car").unwrap(); // all-categorical dataset
        let d = generate(spec, 5, &GenOptions::default());
        assert_eq!(d.num_columns(), 7); // 6 features + label
        let label = d.column(6).as_categorical().unwrap();
        let mut seen = vec![false; 4];
        for &y in label {
            seen[y as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 2);
    }

    #[test]
    fn labels_are_learnable() {
        // A trivial majority-vote on the teacher's strongest stump feature
        // should beat uniform guessing; verify signal exists by checking
        // class balance isn't degenerate and features correlate with label.
        let spec = spec_by_name("Banknote_Authentication").unwrap();
        let d = generate(spec, 7, &GenOptions::default());
        let y = d.column(d.num_columns() - 1).as_categorical().unwrap();
        let pos = y.iter().filter(|&&v| v == 1).count();
        let frac = pos as f64 / y.len() as f64;
        assert!(frac > 0.03 && frac < 0.97, "degenerate labels: {frac}");
    }

    #[test]
    fn adult_like_shape() {
        let d = adult_like(500, 1);
        assert_eq!(d.num_rows(), 500);
        assert_eq!(d.num_columns(), 9);
        assert_eq!(d.column_index("income"), Some(8));
        let y = d.column(8).as_categorical().unwrap();
        let pos = y.iter().filter(|&&v| v == 1).count() as f64 / 500.0;
        // Roughly 25% >50K as in the real Adult dataset.
        assert!(pos > 0.08 && pos < 0.5, "positive rate {pos}");
    }
}
