//! Linear learner: multinomial logistic regression / linear regression via
//! mini-batch SGD over the dense encoding. This is the "TF Linear" baseline
//! of the paper's benchmark (§5) — at serving time its compute graph is
//! exactly the L2 JAX linear model lowered to the PJRT engine.

use super::{classification_labels, regression_targets, Learner};
use crate::dataset::Dataset;
use crate::model::linear::{DenseEncoding, LinearModel};
use crate::model::{Model, SelfEvaluation, Task};
use crate::utils::rng::Rng;
use crate::utils::stats::softmax_in_place;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct LinearConfig {
    pub label: String,
    pub task: Task,
    pub epochs: usize,
    pub learning_rate: f64,
    pub l2: f64,
    pub batch_size: usize,
    pub seed: u64,
}

impl LinearConfig {
    pub fn new(label: &str) -> LinearConfig {
        LinearConfig {
            label: label.to_string(),
            task: Task::Classification,
            epochs: 30,
            learning_rate: 0.1,
            l2: 1e-4,
            batch_size: 32,
            seed: 555,
        }
    }
}

pub struct LinearLearner {
    pub config: LinearConfig,
}

impl LinearLearner {
    pub fn new(config: LinearConfig) -> Self {
        LinearLearner { config }
    }

    pub fn default_config(label: &str) -> Self {
        LinearLearner::new(LinearConfig::new(label))
    }
}

pub fn factory(
    label: &str,
    params: &HashMap<String, String>,
) -> Result<Box<dyn Learner>, String> {
    let mut cfg = LinearConfig::new(label);
    cfg.epochs = super::parse_param(params, "epochs", cfg.epochs)?;
    cfg.learning_rate = super::parse_param(params, "learning_rate", cfg.learning_rate)?;
    cfg.l2 = super::parse_param(params, "l2", cfg.l2)?;
    cfg.seed = super::parse_param(params, "seed", cfg.seed)?;
    if let Some(t) = params.get("task") {
        cfg.task = match t.as_str() {
            "CLASSIFICATION" => Task::Classification,
            "REGRESSION" => Task::Regression,
            other => return Err(format!("unknown task '{other}'")),
        };
    }
    Ok(Box::new(LinearLearner::new(cfg)))
}

impl Learner for LinearLearner {
    fn name(&self) -> &'static str {
        "LINEAR"
    }

    fn label(&self) -> &str {
        &self.config.label
    }

    fn train_with_valid(
        &self,
        ds: &Dataset,
        _valid: Option<&Dataset>,
    ) -> Result<Box<dyn Model>, String> {
        let cfg = &self.config;
        let n = ds.num_rows();
        if n == 0 {
            return Err("cannot train on an empty dataset.".to_string());
        }
        let (label_col, class_labels, reg_targets, num_out) = match cfg.task {
            Task::Classification => {
                let (c, l) = classification_labels(ds, &cfg.label)?;
                let k = ds.spec.columns[c].vocab_size();
                (c, l, vec![], k)
            }
            Task::Regression => {
                let (c, t) = regression_targets(ds, &cfg.label)?;
                (c, vec![], t, 1)
            }
        };
        let encoding = DenseEncoding::build(&ds.spec, label_col);
        let dim = encoding.dim;

        // Materialize the dense matrix once (row-major).
        let mut dense = vec![0.0f32; n * dim];
        for r in 0..n {
            encoding.encode_ds(&ds.spec, ds, r, &mut dense[r * dim..(r + 1) * dim]);
        }

        let mut weights = vec![vec![0.0f32; dim]; num_out];
        let mut bias = vec![0.0f32; num_out];
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let mut order: Vec<usize> = (0..n).collect();
        let mut logits = vec![0.0f64; num_out];
        let mut final_loss = 0.0f64;

        for epoch in 0..cfg.epochs {
            rng.shuffle(&mut order);
            let lr = cfg.learning_rate / (1.0 + 0.1 * epoch as f64);
            let mut epoch_loss = 0.0f64;
            for chunk in order.chunks(cfg.batch_size) {
                // Accumulate batch gradient.
                let mut gw = vec![vec![0.0f64; dim]; num_out];
                let mut gb = vec![0.0f64; num_out];
                for &r in chunk {
                    let x = &dense[r * dim..(r + 1) * dim];
                    for k in 0..num_out {
                        logits[k] = bias[k] as f64
                            + weights[k]
                                .iter()
                                .zip(x)
                                .map(|(&w, &xi)| w as f64 * xi as f64)
                                .sum::<f64>();
                    }
                    match cfg.task {
                        Task::Classification => {
                            softmax_in_place(&mut logits);
                            epoch_loss -=
                                logits[class_labels[r] as usize].max(1e-12).ln();
                            for k in 0..num_out {
                                let err = logits[k]
                                    - (class_labels[r] as usize == k) as u8 as f64;
                                gb[k] += err;
                                for (g, &xi) in gw[k].iter_mut().zip(x) {
                                    *g += err * xi as f64;
                                }
                            }
                        }
                        Task::Regression => {
                            let err = logits[0] - reg_targets[r] as f64;
                            epoch_loss += err * err;
                            gb[0] += err;
                            for (g, &xi) in gw[0].iter_mut().zip(x) {
                                *g += err * xi as f64;
                            }
                        }
                    }
                }
                let scale = lr / chunk.len() as f64;
                for k in 0..num_out {
                    bias[k] -= (scale * gb[k]) as f32;
                    for (w, g) in weights[k].iter_mut().zip(&gw[k]) {
                        *w = (*w as f64 * (1.0 - lr * cfg.l2) - scale * g) as f32;
                    }
                }
            }
            final_loss = epoch_loss / n as f64;
        }

        Ok(Box::new(LinearModel {
            spec: ds.spec.clone(),
            label_col,
            task: cfg.task,
            encoding,
            weights,
            bias,
            self_eval: Some(SelfEvaluation {
                metric: "final training loss".to_string(),
                value: final_loss,
                num_examples: n as u64,
            }),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::evaluation_free_accuracy;

    #[test]
    fn learns_linearly_separable_signal() {
        let ds = synthetic::adult_like(600, 51);
        let model = LinearLearner::default_config("income").train(&ds).unwrap();
        let acc = evaluation_free_accuracy(model.as_ref(), &ds);
        // The adult teacher has a large linear component.
        assert!(acc > 0.72, "accuracy {acc}");
    }

    #[test]
    fn multiclass_probabilities_normalized() {
        let spec = synthetic::spec_by_name("Iris").unwrap();
        let ds = synthetic::generate(spec, 3, &synthetic::GenOptions::default());
        let model = LinearLearner::default_config("label").train(&ds).unwrap();
        let p = model.predict_ds_row(&ds, 0);
        assert_eq!(p.len(), 3);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn regression_mode() {
        let ds = synthetic::adult_like(300, 53);
        let mut cfg = LinearConfig::new("hours_per_week");
        cfg.task = Task::Regression;
        cfg.epochs = 10;
        let model = LinearLearner::new(cfg).train(&ds).unwrap();
        let p = model.predict_ds_row(&ds, 0);
        assert!(p[0].is_finite());
    }

    #[test]
    fn deterministic() {
        let ds = synthetic::adult_like(150, 57);
        let m1 = LinearLearner::default_config("income").train(&ds).unwrap();
        let m2 = LinearLearner::default_config("income").train(&ds).unwrap();
        assert_eq!(m1.to_json().to_string(), m2.to_json().to_string());
    }
}
