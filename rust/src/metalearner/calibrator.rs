//! Calibrator meta-learner (§3.2): Platt scaling of a binary classifier's
//! scores on a held-out calibration split.

use crate::dataset::{DataSpec, Dataset, Observation};
use crate::learner::{classification_labels, Learner};
use crate::model::{Model, Task};
use crate::utils::json::Json;
use crate::utils::stats::sigmoid;

/// A model whose positive-class probability is re-mapped through a fitted
/// logistic transform `sigmoid(a·logit(p) + b)`.
pub struct CalibratedModel {
    pub base: Box<dyn Model>,
    pub a: f64,
    pub b: f64,
}

impl CalibratedModel {
    fn calibrate(&self, mut probs: Vec<f64>) -> Vec<f64> {
        if probs.len() == 2 {
            let p = probs[1].clamp(1e-9, 1.0 - 1e-9);
            let logit = (p / (1.0 - p)).ln();
            let q = sigmoid(self.a * logit + self.b);
            probs[1] = q;
            probs[0] = 1.0 - q;
        }
        probs
    }
}

impl Model for CalibratedModel {
    fn model_type(&self) -> &'static str {
        "CALIBRATED"
    }
    fn task(&self) -> Task {
        self.base.task()
    }
    fn spec(&self) -> &DataSpec {
        self.base.spec()
    }
    fn label_col(&self) -> usize {
        self.base.label_col()
    }
    fn input_features(&self) -> Vec<usize> {
        self.base.input_features()
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        self.calibrate(self.base.predict_row(obs))
    }

    fn predict_ds_row(&self, ds: &Dataset, row: usize) -> Vec<f64> {
        self.calibrate(self.base.predict_ds_row(ds, row))
    }

    fn describe(&self) -> String {
        format!(
            "Type: \"CALIBRATED\" (a={:.4}, b={:.4})\n--- base ---\n{}",
            self.a,
            self.b,
            self.base.describe()
        )
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("format_version", Json::Num(crate::model::io::MODEL_FORMAT_VERSION as f64))
            .set("model_type", Json::Str("CALIBRATED".into()))
            .set("a", Json::Num(self.a))
            .set("b", Json::Num(self.b))
            .set("base", self.base.to_json());
        j
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Platt-scaling calibrator for binary classifiers.
pub struct CalibratorLearner {
    pub base: Box<dyn Learner>,
    /// Fraction of the training data held out for calibration.
    pub calibration_ratio: f64,
    pub seed: u64,
}

impl CalibratorLearner {
    pub fn new(base: Box<dyn Learner>) -> CalibratorLearner {
        CalibratorLearner { base, calibration_ratio: 0.2, seed: 0xCA11 }
    }
}

impl Learner for CalibratorLearner {
    fn name(&self) -> &'static str {
        "CALIBRATOR"
    }

    fn label(&self) -> &str {
        self.base.label()
    }

    fn train_with_valid(
        &self,
        ds: &Dataset,
        valid: Option<&Dataset>,
    ) -> Result<Box<dyn Model>, String> {
        // Hold out a calibration split (or reuse a provided validation set).
        let (train_ds, calib_ds) = match valid {
            Some(v) => (ds.clone(), v.clone()),
            None => {
                let (tr, ca) = ds.train_valid_split(self.calibration_ratio, self.seed);
                (ds.subset(&tr), ds.subset(&ca))
            }
        };
        let base_model = self.base.train(&train_ds)?;
        if base_model.task() != Task::Classification || base_model.num_classes() != 2 {
            return Err(
                "the calibrator meta-learner requires a binary classification base learner."
                    .to_string(),
            );
        }
        let (_, labels) = classification_labels(&calib_ds, self.base.label())?;
        // Fit sigmoid(a·logit + b) by gradient descent on log-loss.
        let logits: Vec<f64> = (0..calib_ds.num_rows())
            .map(|r| {
                let p = base_model.predict_ds_row(&calib_ds, r)[1].clamp(1e-9, 1.0 - 1e-9);
                (p / (1.0 - p)).ln()
            })
            .collect();
        let (mut a, mut b) = (1.0f64, 0.0f64);
        let n = logits.len().max(1) as f64;
        for _ in 0..200 {
            let mut ga = 0.0;
            let mut gb = 0.0;
            for (&z, &y) in logits.iter().zip(&labels) {
                let p = sigmoid(a * z + b);
                let err = p - y as f64;
                ga += err * z;
                gb += err;
            }
            a -= 0.1 * ga / n;
            b -= 0.1 * gb / n;
        }
        Ok(Box::new(CalibratedModel { base: base_model, a, b }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::evaluation::evaluate_model;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::GradientBoostedTreesLearner;

    #[test]
    fn calibration_preserves_or_improves_logloss() {
        let train = synthetic::adult_like(500, 95);
        let test = synthetic::adult_like(300, 96);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 15;
        cfg.max_depth = 3;
        let base = GradientBoostedTreesLearner::new(cfg.clone());
        let base_model = base.train(&train).unwrap();
        let base_eval = evaluate_model(base_model.as_ref(), &test, "income").unwrap();

        let calib = CalibratorLearner::new(Box::new(GradientBoostedTreesLearner::new(cfg)));
        let calib_model = calib.train(&train).unwrap();
        let calib_eval = evaluate_model(calib_model.as_ref(), &test, "income").unwrap();
        // Platt scaling should not blow up the log-loss.
        assert!(
            calib_eval.log_loss < base_eval.log_loss + 0.05,
            "calibrated {} vs base {}",
            calib_eval.log_loss,
            base_eval.log_loss
        );
        // Probabilities stay normalized.
        let p = calib_model.predict_ds_row(&test, 0);
        assert!((p[0] + p[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn multiclass_base_rejected() {
        let spec = synthetic::spec_by_name("Iris").unwrap();
        let ds = synthetic::generate(spec, 3, &synthetic::GenOptions::default());
        let mut cfg = GbtConfig::new("label");
        cfg.num_trees = 4;
        let calib = CalibratorLearner::new(Box::new(GradientBoostedTreesLearner::new(cfg)));
        assert!(calib.train(&ds).is_err());
    }
}
