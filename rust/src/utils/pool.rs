//! A small scoped thread pool (rayon is unavailable offline).
//!
//! Used by the Random Forest learner (per-tree parallelism), the distributed
//! backend and the serving example. Work items are closures; `scope_map`
//! offers the common "parallel map over indices" pattern.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Runs `f(i)` for `i in 0..n` across `threads` OS threads and returns the
/// results in index order. Falls back to sequential execution when
/// `threads <= 1` (the common case on this single-core testbed).
///
/// Work is handed out as contiguous index blocks through one atomic
/// counter (dynamic balancing for uneven items like RF trees); each
/// thread appends results to its own buffers, which are stitched back in
/// index order at the end. No per-item synchronization — the old
/// `Mutex<Option<T>>`-per-item scheme cost one allocation and one lock
/// round-trip per item on the training and batch-inference hot paths.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let threads = threads.min(n);
    // ~4 blocks per thread: coarse enough to amortize the counter, fine
    // enough to balance uneven per-item cost.
    let block = n.div_ceil(threads * 4).max(1);
    let next = AtomicUsize::new(0);
    let mut pieces: Vec<(usize, Vec<T>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local: Vec<(usize, Vec<T>)> = Vec::new();
                    loop {
                        let start = next.fetch_add(block, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + block).min(n);
                        let mut buf = Vec::with_capacity(end - start);
                        for i in start..end {
                            buf.push(f(i));
                        }
                        local.push((start, buf));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("parallel_map worker panicked"))
            .collect()
    });
    pieces.sort_by_key(|&(start, _)| start);
    let mut out = Vec::with_capacity(n);
    for (_, mut piece) in pieces {
        out.append(&mut piece);
    }
    debug_assert_eq!(out.len(), n);
    out
}

/// Long-lived worker pool with explicit job submission; used by the
/// distributed backend to model persistent training workers.
pub struct WorkerPool {
    senders: Vec<std::sync::mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        // Registered eagerly (not on first job) so pool metrics exist in
        // the `{"cmd":"metrics"}` exposition as soon as a pool is built —
        // a freshly started server has pools but may not have flushed a
        // multi-span batch yet.
        crate::obs::metrics()
            .counter("ydf_pools_total", "Worker pools constructed.")
            .inc();
        crate::obs::metrics()
            .counter("ydf_pool_workers_total", "Worker threads spawned across all pools.")
            .add(workers as u64);
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = std::sync::mpsc::channel::<Job>();
            senders.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ydf-worker-{w}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            // A panicking job must not take the worker with
                            // it: the pool is long-lived and shared (every
                            // serving model's flush spans land here), and a
                            // dead worker would silently degrade all future
                            // work. The panic is contained to the job;
                            // `run_scoped`/`broadcast` accounting still
                            // notices the loss because the job's completion
                            // signal is dropped unsent.
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                        }
                    })
                    .expect("failed to spawn worker"),
            );
        }
        WorkerPool { senders, handles }
    }

    pub fn num_workers(&self) -> usize {
        self.senders.len()
    }

    /// Submits a job to a specific worker (the feature-parallel algorithm
    /// pins features to workers, so placement matters).
    pub fn submit_to<F: FnOnce() + Send + 'static>(&self, worker: usize, f: F) {
        self.senders[worker].send(Box::new(f)).expect("worker channel closed");
    }

    /// Runs every job to completion on the pool's workers, blocking the
    /// caller until all of them finish. Unlike [`WorkerPool::submit_to`],
    /// the jobs may borrow from the caller's stack frame (they are not
    /// `'static`): this is the scoped span-scatter the serving batcher
    /// uses to fan one coalesced flush out across persistent workers with
    /// index-disjoint `&mut` slices, the same contract as
    /// `InferenceEngine::predict_into` — but without spawning fresh OS
    /// threads per flush.
    ///
    /// Jobs are placed round-robin. With one worker (or one job) the jobs
    /// run inline on the caller's thread. Panics if any job is lost — it
    /// panicked mid-run, or its worker died — because the caller's borrows
    /// would otherwise be unguarded; the workers themselves survive a
    /// panicking job.
    pub fn run_scoped<'env, F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'env,
    {
        let n_jobs = jobs.len();
        // Called per tree node during feature-parallel training: the
        // metric handle is resolved once per process, after which this is
        // one relaxed fetch_add.
        {
            use std::sync::OnceLock;
            static SCOPED_JOBS: OnceLock<crate::obs::Counter> = OnceLock::new();
            SCOPED_JOBS
                .get_or_init(|| {
                    crate::obs::metrics().counter(
                        "ydf_pool_scoped_jobs_total",
                        "Jobs executed through WorkerPool::run_scoped (inline or on workers).",
                    )
                })
                .add(n_jobs as u64);
        }
        if n_jobs <= 1 || self.num_workers() <= 1 {
            for job in jobs {
                job();
            }
            return;
        }
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        for (i, job) in jobs.into_iter().enumerate() {
            let done = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                job();
                let _ = done.send(());
            });
            // SAFETY: the job may borrow data from the caller's frame
            // (lifetime 'env). We erase that lifetime to hand it to a
            // persistent worker, which is sound because this function does
            // not unwind or return until every `done_tx` clone is gone —
            // each job either ran to completion (sent, then dropped its
            // clone) or was dropped unexecuted (a panicked job unwinds
            // past the send; a dead worker's queue drops pending jobs) —
            // so no job can still be running, and no borrow can still be
            // live, once the drain loop below finishes. Box<dyn FnOnce>
            // has the same (fat-pointer) layout for both lifetimes.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'env>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            // Deliberately NOT submit_to: its "worker channel closed"
            // panic would unwind this frame mid-scatter while jobs on the
            // surviving workers still hold `&mut` borrows into it. A send
            // to a dead worker instead drops the job (and its `done`
            // clone); the accounting below notices the loss only after
            // every surviving job has finished.
            let _ = self.senders[i % self.senders.len()].send(job);
        }
        drop(done_tx);
        // Drain until the channel closes or all jobs reported in. Only
        // after that — when no job can still be running — is it safe to
        // unwind on a lost job.
        let mut completed = 0usize;
        while completed < n_jobs && done_rx.recv().is_ok() {
            completed += 1;
        }
        assert_eq!(
            completed, n_jobs,
            "worker pool lost {} scoped job(s): a job panicked or a worker died mid-run",
            n_jobs - completed
        );
    }

    /// Runs `f(w)` on every worker and blocks until all complete.
    pub fn broadcast<F>(&self, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        for w in 0..self.senders.len() {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            self.submit_to(w, move || {
                f(w);
                let _ = done.send(());
            });
        }
        // Without this drop a lost job (panicked, or its worker died)
        // would leave the original sender alive and `recv` blocked
        // forever — fail loudly instead of hanging.
        drop(done_tx);
        for _ in 0..self.senders.len() {
            done_rx.recv().expect("a broadcast job was lost: it panicked or its worker died");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.senders.clear(); // close channels, letting workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_sequential_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_map_uneven_blocks() {
        // n not divisible by threads*4: tail blocks must still land in
        // index order.
        for n in [2usize, 7, 10, 65, 100] {
            for threads in [2usize, 3, 8] {
                let out = parallel_map(n, threads, |i| 3 * i);
                assert_eq!(out, (0..n).map(|i| 3 * i).collect::<Vec<_>>(), "n={n} t={threads}");
            }
        }
    }

    #[test]
    fn worker_pool_broadcast_touches_all() {
        let pool = WorkerPool::new(3);
        static COUNT: AtomicU64 = AtomicU64::new(0);
        pool.broadcast(|_w| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn run_scoped_borrows_and_joins() {
        let pool = WorkerPool::new(3);
        // Jobs borrow disjoint &mut chunks of a stack-local buffer — the
        // exact shape of the batcher's parallel flush.
        let mut out = vec![0u64; 97];
        {
            let mut jobs = Vec::new();
            let mut rest: &mut [u64] = &mut out;
            let mut start = 0usize;
            while !rest.is_empty() {
                let take = rest.len().min(10);
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
                rest = tail;
                let s = start;
                start += take;
                jobs.push(move || {
                    for (i, x) in head.iter_mut().enumerate() {
                        *x = (s + i) as u64 * 2;
                    }
                });
            }
            pool.run_scoped(jobs);
        }
        assert_eq!(out, (0..97).map(|i| i * 2).collect::<Vec<u64>>());
    }

    #[test]
    fn run_scoped_empty_and_single() {
        let pool = WorkerPool::new(2);
        pool.run_scoped(Vec::<fn()>::new());
        let mut hit = false;
        pool.run_scoped(vec![|| hit = true]);
        assert!(hit);
    }

    #[test]
    fn worker_pool_submit_to_runs() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit_to(1, move || tx.send(42).unwrap());
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn worker_survives_panicking_job() {
        let pool = WorkerPool::new(2);
        pool.submit_to(0, || panic!("injected job panic"));
        // The same worker is still alive and processing its queue.
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit_to(0, move || tx.send(7).unwrap());
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(), 7);
    }

    #[test]
    fn run_scoped_reports_lost_jobs_and_pool_survives() {
        let pool = WorkerPool::new(2);
        // A panicking scoped job is reported to the caller once every
        // surviving job has finished (the borrows are then dead)...
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scoped(vec![
                Box::new(|| {}) as Box<dyn FnOnce() + Send>,
                Box::new(|| panic!("injected scoped-job panic")),
                Box::new(|| {}),
                Box::new(|| {}),
            ]);
        }));
        let payload = r.unwrap_err();
        let message = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(message.contains("lost 1 scoped job"), "{message}");
        // ...and the workers survived: the pool still completes new work.
        let hits = AtomicUsize::new(0);
        pool.run_scoped(
            (0..4)
                .map(|_| {
                    || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }
}
