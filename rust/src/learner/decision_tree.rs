//! Decision-tree growth: local (divide-and-conquer) and global best-first
//! (leaf-wise, Shi 2007) strategies (§3.11), generic over label type.
//!
//! Both growers are allocation-free per node: the tree's example set lives
//! in a [`RowArena`] partitioned in place, nodes address it as
//! `(start, len)` spans, and the split search runs through a
//! [`SplitEngine`] (shared [`crate::splitter::ColumnIndex`] + per-thread
//! scratch, optionally fanned out across candidate features).

use crate::dataset::Dataset;
use crate::model::tree::{DecisionTree, Node};
use crate::splitter::score::Labels;
use crate::splitter::{RowArena, SplitEngine, SplitterConfig};
use crate::utils::rng::Rng;

/// Tree growth strategy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GrowingStrategy {
    /// Divide and conquer, depth-first, bounded by `max_depth`.
    Local,
    /// Best-first (leaf-wise) growth bounded by a total leaf budget —
    /// `growing_strategy: BEST_FIRST_GLOBAL` of benchmark_rank1@v1.
    BestFirstGlobal { max_num_leaves: usize },
}

/// Number of candidate attributes examined per split (Breiman's rule of
/// thumb √p is the RF classification default — §3.11).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttrSampling {
    All,
    Sqrt,
    Ratio(f64),
    Count(usize),
}

impl AttrSampling {
    pub fn num_candidates(&self, total: usize) -> usize {
        match self {
            AttrSampling::All => total,
            AttrSampling::Sqrt => ((total as f64).sqrt().ceil() as usize).clamp(1, total),
            AttrSampling::Ratio(r) => {
                (((total as f64) * r).ceil() as usize).clamp(1, total)
            }
            AttrSampling::Count(k) => (*k).clamp(1, total),
        }
    }
}

/// Configuration for one tree.
#[derive(Clone, Debug)]
pub struct TreeConfig {
    pub max_depth: usize,
    pub min_examples: usize,
    pub splitter: SplitterConfig,
    pub growing: GrowingStrategy,
    pub attr_sampling: AttrSampling,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig {
            max_depth: 16,
            min_examples: 5,
            splitter: SplitterConfig::default(),
            growing: GrowingStrategy::Local,
            attr_sampling: AttrSampling::All,
        }
    }
}

fn leaf_from_rows(rows: &[u32], labels: &Labels) -> Node {
    let mut acc = labels.new_acc();
    for &r in rows {
        acc.add(labels, r as usize);
    }
    Node::leaf(acc.leaf_value(labels), rows.len() as f64)
}

fn sample_features(features: &[usize], sampling: AttrSampling, rng: &mut Rng) -> Vec<usize> {
    let k = sampling.num_candidates(features.len());
    if k >= features.len() {
        features.to_vec()
    } else {
        rng.sample_without_replacement(features.len(), k)
            .into_iter()
            .map(|i| features[i])
            .collect()
    }
}

/// Grows one decision tree on the `rows` of `ds` (duplicates allowed —
/// bootstrap), splitting on `features`. `engine` carries the shared column
/// index and split-search threads; `arena` is the (reusable) row storage —
/// both survive across trees so repeated growth allocates nothing per node
/// and almost nothing per tree.
pub fn grow_tree(
    ds: &Dataset,
    rows: &[u32],
    labels: &Labels,
    features: &[usize],
    cfg: &TreeConfig,
    engine: &mut SplitEngine,
    arena: &mut RowArena,
    rng: &mut Rng,
) -> DecisionTree {
    arena.reset(rows);
    match cfg.growing {
        GrowingStrategy::Local => grow_local(ds, labels, features, cfg, engine, arena, rng),
        GrowingStrategy::BestFirstGlobal { max_num_leaves } => {
            grow_best_first(ds, labels, features, cfg, engine, arena, rng, max_num_leaves)
        }
    }
}

fn grow_local(
    ds: &Dataset,
    labels: &Labels,
    features: &[usize],
    cfg: &TreeConfig,
    engine: &mut SplitEngine,
    arena: &mut RowArena,
    rng: &mut Rng,
) -> DecisionTree {
    let n = arena.len();
    let mut tree = DecisionTree { nodes: vec![leaf_from_rows(arena.span(0, n), labels)] };
    // Stack of (node index, span start, span len, depth). Depth-first
    // keeps the open frontier at O(depth) spans; spans are disjoint
    // sub-ranges of the arena, so no row set is ever copied.
    let mut stack = vec![(0usize, 0usize, n, 0usize)];
    while let Some((idx, start, len, depth)) = stack.pop() {
        if depth >= cfg.max_depth || len < 2 * cfg.min_examples.max(1) {
            continue; // keep as leaf
        }
        let cands = sample_features(features, cfg.attr_sampling, rng);
        let split = match engine.find_best_split(
            ds,
            arena.span(start, len),
            labels,
            &cands,
            &cfg.splitter,
            rng,
        ) {
            Some(s) => s,
            None => continue,
        };
        let n_pos =
            arena.partition_span(ds, &split.condition, split.missing_to_positive, start, len);
        if n_pos < cfg.min_examples || len - n_pos < cfg.min_examples {
            continue;
        }
        let pos_idx = tree.nodes.len() as u32;
        tree.nodes.push(leaf_from_rows(arena.span(start, n_pos), labels));
        let neg_idx = tree.nodes.len() as u32;
        tree.nodes.push(leaf_from_rows(arena.span(start + n_pos, len - n_pos), labels));
        {
            let node = &mut tree.nodes[idx];
            node.condition = Some(split.condition);
            node.positive = pos_idx;
            node.negative = neg_idx;
            node.missing_to_positive = split.missing_to_positive;
            node.score = split.gain as f32;
            node.value = vec![];
        }
        stack.push((pos_idx as usize, start, n_pos, depth + 1));
        stack.push((neg_idx as usize, start + n_pos, len - n_pos, depth + 1));
    }
    tree
}

#[allow(clippy::too_many_arguments)]
fn grow_best_first(
    ds: &Dataset,
    labels: &Labels,
    features: &[usize],
    cfg: &TreeConfig,
    engine: &mut SplitEngine,
    arena: &mut RowArena,
    rng: &mut Rng,
    max_num_leaves: usize,
) -> DecisionTree {
    let n = arena.len();
    let mut tree = DecisionTree { nodes: vec![leaf_from_rows(arena.span(0, n), labels)] };
    // Expandable leaves with their precomputed best split. Spans of open
    // leaves are disjoint, and `partition_span` only permutes within one
    // span, so open spans stay valid while others are expanded.
    struct Open {
        idx: usize,
        start: usize,
        len: usize,
        depth: usize,
        split: crate::splitter::SplitCandidate,
    }
    let mut open: Vec<Open> = Vec::new();
    let try_open = |idx: usize,
                        start: usize,
                        len: usize,
                        depth: usize,
                        engine: &mut SplitEngine,
                        arena: &RowArena,
                        rng: &mut Rng,
                        open: &mut Vec<Open>| {
        if depth >= cfg.max_depth || len < 2 * cfg.min_examples.max(1) {
            return;
        }
        let cands = sample_features(features, cfg.attr_sampling, rng);
        if let Some(split) = engine.find_best_split(
            ds,
            arena.span(start, len),
            labels,
            &cands,
            &cfg.splitter,
            rng,
        ) {
            open.push(Open { idx, start, len, depth, split });
        }
    };
    try_open(0, 0, n, 0, engine, arena, rng, &mut open);
    let mut num_leaves = 1usize;
    while num_leaves < max_num_leaves && !open.is_empty() {
        // Pop the highest-gain candidate (leaf-wise growth).
        let best_i = open
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.split.gain.partial_cmp(&b.1.split.gain).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let Open { idx, start, len, depth, split } = open.swap_remove(best_i);
        let n_pos =
            arena.partition_span(ds, &split.condition, split.missing_to_positive, start, len);
        if n_pos < cfg.min_examples || len - n_pos < cfg.min_examples {
            continue;
        }
        let pos_idx = tree.nodes.len();
        tree.nodes.push(leaf_from_rows(arena.span(start, n_pos), labels));
        let neg_idx = tree.nodes.len();
        tree.nodes.push(leaf_from_rows(arena.span(start + n_pos, len - n_pos), labels));
        {
            let node = &mut tree.nodes[idx];
            node.condition = Some(split.condition);
            node.positive = pos_idx as u32;
            node.negative = neg_idx as u32;
            node.missing_to_positive = split.missing_to_positive;
            node.score = split.gain as f32;
            node.value = vec![];
        }
        num_leaves += 1; // one leaf became two
        try_open(pos_idx, start, n_pos, depth + 1, engine, arena, rng, &mut open);
        try_open(neg_idx, start + n_pos, len - n_pos, depth + 1, engine, arena, rng, &mut open);
    }
    tree
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::{ColumnSpec, DataSpec};
    use crate::dataset::ColumnData;
    use crate::splitter::ColumnIndex;
    use std::sync::Arc;

    fn xor_dataset(n: usize) -> (Dataset, Vec<u32>) {
        // XOR over two features: needs depth 2.
        let mut rng = Rng::seed_from_u64(3);
        let x0: Vec<f32> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        let x1: Vec<f32> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        let y: Vec<u32> =
            x0.iter().zip(&x1).map(|(&a, &b)| ((a > 0.0) ^ (b > 0.0)) as u32).collect();
        let spec = DataSpec {
            columns: vec![ColumnSpec::numerical("x0"), ColumnSpec::numerical("x1")],
        };
        let ds = Dataset::new(
            spec,
            vec![ColumnData::Numerical(x0), ColumnData::Numerical(x1)],
        )
        .unwrap();
        (ds, y)
    }

    fn engine_for(ds: &Dataset) -> SplitEngine {
        SplitEngine::sequential(Arc::new(ColumnIndex::new(ds)))
    }

    fn grow_simple(
        ds: &Dataset,
        rows: Vec<u32>,
        labels: &Labels,
        cfg: &TreeConfig,
        seed: u64,
    ) -> DecisionTree {
        let mut engine = engine_for(ds);
        let mut arena = RowArena::new();
        grow_tree(
            ds,
            &rows,
            labels,
            &[0, 1],
            cfg,
            &mut engine,
            &mut arena,
            &mut Rng::seed_from_u64(seed),
        )
    }

    fn accuracy(tree: &DecisionTree, ds: &Dataset, y: &[u32]) -> f64 {
        let mut correct = 0usize;
        for r in 0..ds.num_rows() {
            let leaf = tree.eval_ds(ds, r);
            let pred = leaf
                .value
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred as u32 == y[r] {
                correct += 1;
            }
        }
        correct as f64 / ds.num_rows() as f64
    }

    #[test]
    fn local_growth_learns_xor() {
        let (ds, y) = xor_dataset(400);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig {
            max_depth: 4,
            min_examples: 2,
            ..Default::default()
        };
        let rows: Vec<u32> = (0..ds.num_rows() as u32).collect();
        let tree = grow_simple(&ds, rows, &labels, &cfg, 1);
        assert!(tree.max_depth() >= 2);
        let acc = accuracy(&tree, &ds, &y);
        assert!(acc > 0.95, "accuracy {acc}");
    }

    #[test]
    fn best_first_respects_leaf_budget() {
        let (ds, y) = xor_dataset(400);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig {
            max_depth: 10,
            min_examples: 2,
            growing: GrowingStrategy::BestFirstGlobal { max_num_leaves: 8 },
            ..Default::default()
        };
        let rows: Vec<u32> = (0..ds.num_rows() as u32).collect();
        let tree = grow_simple(&ds, rows, &labels, &cfg, 1);
        assert!(tree.num_leaves() <= 8);
        assert!(accuracy(&tree, &ds, &y) > 0.9);
    }

    #[test]
    fn max_depth_zero_is_single_leaf() {
        let (ds, y) = xor_dataset(50);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig { max_depth: 0, ..Default::default() };
        let tree = grow_simple(&ds, (0..50).collect(), &labels, &cfg, 1);
        assert_eq!(tree.num_nodes(), 1);
        assert!(tree.nodes[0].is_leaf());
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, y) = xor_dataset(200);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig { attr_sampling: AttrSampling::Sqrt, ..Default::default() };
        let grow = |seed: u64| grow_simple(&ds, (0..200).collect(), &labels, &cfg, seed);
        let a = grow(7);
        let b = grow(7);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        let c = grow(8);
        // Different seed may legitimately produce an identical tree on this
        // simple task, but number of nodes is a cheap sanity check that the
        // seed is actually used.
        let _ = c;
    }

    #[test]
    fn engine_and_arena_reuse_across_trees_is_clean() {
        // Growing two different trees through the same engine + arena must
        // give exactly the trees grown through fresh ones (no state leaks
        // between trees).
        let (ds, y) = xor_dataset(300);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig { max_depth: 5, min_examples: 2, ..Default::default() };
        let rows_a: Vec<u32> = (0..300).collect();
        let rows_b: Vec<u32> = (0..300).rev().collect();

        let mut engine = engine_for(&ds);
        let mut arena = RowArena::new();
        let mut rng = Rng::seed_from_u64(9);
        let a1 =
            grow_tree(&ds, &rows_a, &labels, &[0, 1], &cfg, &mut engine, &mut arena, &mut rng);
        let mut rng = Rng::seed_from_u64(9);
        let b1 =
            grow_tree(&ds, &rows_b, &labels, &[0, 1], &cfg, &mut engine, &mut arena, &mut rng);

        let a2 = grow_simple(&ds, rows_a, &labels, &cfg, 9);
        let b2 = grow_simple(&ds, rows_b, &labels, &cfg, 9);
        assert_eq!(a1.to_json().to_string(), a2.to_json().to_string());
        assert_eq!(b1.to_json().to_string(), b2.to_json().to_string());
    }

    #[test]
    fn attr_sampling_counts() {
        assert_eq!(AttrSampling::All.num_candidates(10), 10);
        assert_eq!(AttrSampling::Sqrt.num_candidates(100), 10);
        assert_eq!(AttrSampling::Sqrt.num_candidates(10), 4);
        assert_eq!(AttrSampling::Ratio(0.5).num_candidates(10), 5);
        assert_eq!(AttrSampling::Count(3).num_candidates(10), 3);
        assert_eq!(AttrSampling::Count(30).num_candidates(10), 10);
        assert_eq!(AttrSampling::Ratio(0.0).num_candidates(10), 1);
    }

    #[test]
    fn min_examples_leaf_size() {
        let (ds, y) = xor_dataset(300);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig { min_examples: 20, max_depth: 20, ..Default::default() };
        let tree = grow_simple(&ds, (0..300).collect(), &labels, &cfg, 2);
        for n in &tree.nodes {
            if n.is_leaf() {
                assert!(n.num_examples >= 20.0, "leaf with {} examples", n.num_examples);
            }
        }
    }
}
