//! Shared substrates: deterministic RNG, statistics, JSON, histograms,
//! micro-benchmark harness, thread pool and property-testing helpers.
//!
//! The execution environment is offline (no crates.io), so these modules
//! replace the usual `rand`/`serde_json`/`criterion`/`rayon`/`proptest`
//! dependencies with small, well-tested implementations.

pub mod bench;
pub mod env;
pub mod histogram;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
