//! Hyper-parameter templates (§3.11) and the tuning search spaces of
//! Appendix C.2.
//!
//! Templates are versioned: `benchmark_rank1@v1` always denotes the same
//! hyper-parameters, preserving the backwards-compatibility guarantee that
//! "running a learner configured with a given set of hyper-parameters
//! always returns the same model".

use crate::utils::rng::Rng;
use std::collections::HashMap;

/// A named, versioned hyper-parameter template.
#[derive(Clone, Debug)]
pub struct HyperParameterTemplate {
    pub name: &'static str,
    pub version: u32,
    pub learner: &'static str,
    pub params: &'static [(&'static str, &'static str)],
}

/// All built-in templates. `benchmark_rank1@v1` mirrors Appendix C.1.
pub const TEMPLATES: &[HyperParameterTemplate] = &[
    HyperParameterTemplate {
        name: "benchmark_rank1",
        version: 1,
        learner: "GRADIENT_BOOSTED_TREES",
        params: &[("template", "benchmark_rank1@v1")],
    },
    HyperParameterTemplate {
        name: "benchmark_rank1",
        version: 1,
        learner: "RANDOM_FOREST",
        params: &[("template", "benchmark_rank1@v1")],
    },
];

/// Looks up a template by `name@version` (e.g. "benchmark_rank1@v1").
pub fn find_template(learner: &str, spec: &str) -> Option<&'static HyperParameterTemplate> {
    let (name, version) = match spec.split_once("@v") {
        Some((n, v)) => (n, v.parse::<u32>().ok()?),
        None => (spec, 1),
    };
    TEMPLATES
        .iter()
        .find(|t| t.learner == learner && t.name == name && t.version == version)
}

/// One hyper-parameter axis of a search space.
#[derive(Clone, Debug)]
pub enum ParamRange {
    IntRange { key: &'static str, lo: i64, hi: i64 },
    FloatRange { key: &'static str, lo: f64, hi: f64 },
    Choice { key: &'static str, options: &'static [&'static str] },
}

impl ParamRange {
    /// Draws one random value, rendered as a string override.
    pub fn sample(&self, rng: &mut Rng) -> (String, String) {
        match self {
            ParamRange::IntRange { key, lo, hi } => (
                key.to_string(),
                (lo + rng.uniform_usize((hi - lo + 1) as usize) as i64).to_string(),
            ),
            ParamRange::FloatRange { key, lo, hi } => {
                (key.to_string(), format!("{}", rng.uniform_range(*lo, *hi)))
            }
            ParamRange::Choice { key, options } => {
                (key.to_string(), options[rng.uniform_usize(options.len())].to_string())
            }
        }
    }
}

/// YDF's tuning space for GBT (Appendix C.2): min examples, categorical
/// algorithm, split axis, hessian splits, shrinkage, attribute ratio,
/// growing strategy.
pub fn gbt_search_space() -> Vec<ParamRange> {
    vec![
        ParamRange::IntRange { key: "min_examples", lo: 2, hi: 10 },
        ParamRange::Choice { key: "categorical_algorithm", options: &["CART", "RANDOM"] },
        ParamRange::Choice { key: "split_axis", options: &["AXIS_ALIGNED", "SPARSE_OBLIQUE"] },
        ParamRange::Choice { key: "use_hessian_gain", options: &["true", "false"] },
        ParamRange::FloatRange { key: "shrinkage", lo: 0.02, hi: 0.15 },
        ParamRange::FloatRange { key: "num_candidate_attributes_ratio", lo: 0.2, hi: 1.0 },
        ParamRange::Choice { key: "growing_strategy", options: &["LOCAL", "BEST_FIRST_GLOBAL"] },
        ParamRange::IntRange { key: "max_depth", lo: 3, hi: 8 },
        ParamRange::IntRange { key: "max_num_leaves", lo: 16, hi: 256 },
    ]
}

/// YDF's tuning space for Random Forests (Appendix C.2).
pub fn rf_search_space() -> Vec<ParamRange> {
    vec![
        ParamRange::IntRange { key: "min_examples", lo: 2, hi: 10 },
        ParamRange::Choice { key: "categorical_algorithm", options: &["CART", "RANDOM"] },
        ParamRange::Choice { key: "split_axis", options: &["AXIS_ALIGNED", "SPARSE_OBLIQUE"] },
        ParamRange::IntRange { key: "max_depth", lo: 12, hi: 30 },
    ]
}

/// Applies string overrides of the C.2 vocabulary onto a GBT config.
pub fn apply_gbt_overrides(
    cfg: &mut super::gbt::GbtConfig,
    overrides: &HashMap<String, String>,
) -> Result<(), String> {
    use crate::learner::decision_tree::{AttrSampling, GrowingStrategy};
    use crate::splitter::{CategoricalSplit, ObliqueNormalization, SplitAxis};
    // Apply the growing strategy first: `max_num_leaves` only applies on
    // top of BEST_FIRST_GLOBAL (HashMap iteration order is arbitrary).
    if overrides.get("growing_strategy").map(|s| s.as_str()) == Some("BEST_FIRST_GLOBAL")
        && !matches!(cfg.growing, GrowingStrategy::BestFirstGlobal { .. })
    {
        cfg.growing = GrowingStrategy::BestFirstGlobal { max_num_leaves: 64 };
        cfg.max_depth = usize::MAX;
    }
    for (k, v) in overrides {
        match k.as_str() {
            "min_examples" => {
                cfg.min_examples =
                    v.parse().map_err(|_| format!("bad min_examples '{v}'"))?
            }
            "shrinkage" => {
                cfg.shrinkage = v.parse().map_err(|_| format!("bad shrinkage '{v}'"))?
            }
            "max_depth" => {
                cfg.max_depth = v.parse().map_err(|_| format!("bad max_depth '{v}'"))?
            }
            "use_hessian_gain" => {
                cfg.use_hessian_gain =
                    v.parse().map_err(|_| format!("bad use_hessian_gain '{v}'"))?
            }
            "num_candidate_attributes_ratio" => {
                cfg.attr_sampling = AttrSampling::Ratio(
                    v.parse().map_err(|_| format!("bad ratio '{v}'"))?,
                )
            }
            "categorical_algorithm" => {
                cfg.splitter.categorical = match v.as_str() {
                    "CART" => CategoricalSplit::Cart,
                    "RANDOM" => CategoricalSplit::Random { trials: 32 },
                    "ONE_HOT" => CategoricalSplit::OneHot,
                    other => return Err(format!("unknown categorical algorithm '{other}'")),
                }
            }
            "split_axis" => {
                cfg.splitter.axis = match v.as_str() {
                    "AXIS_ALIGNED" => SplitAxis::AxisAligned,
                    "SPARSE_OBLIQUE" => SplitAxis::SparseOblique {
                        num_projections_exponent: 1.0,
                        normalization: ObliqueNormalization::MinMax,
                    },
                    other => return Err(format!("unknown split axis '{other}'")),
                }
            }
            "growing_strategy" => match v.as_str() {
                "LOCAL" => cfg.growing = GrowingStrategy::Local,
                "BEST_FIRST_GLOBAL" => {
                    if !matches!(cfg.growing, GrowingStrategy::BestFirstGlobal { .. }) {
                        cfg.growing = GrowingStrategy::BestFirstGlobal { max_num_leaves: 64 };
                        cfg.max_depth = usize::MAX;
                    }
                }
                other => return Err(format!("unknown growing strategy '{other}'")),
            },
            "max_num_leaves" => {
                if let GrowingStrategy::BestFirstGlobal { .. } = cfg.growing {
                    cfg.growing = GrowingStrategy::BestFirstGlobal {
                        max_num_leaves: v
                            .parse()
                            .map_err(|_| format!("bad max_num_leaves '{v}'"))?,
                    };
                }
            }
            _ => {} // tolerated: axes for other learners
        }
    }
    Ok(())
}

/// Applies overrides onto an RF config.
pub fn apply_rf_overrides(
    cfg: &mut super::random_forest::RandomForestConfig,
    overrides: &HashMap<String, String>,
) -> Result<(), String> {
    use crate::splitter::{CategoricalSplit, ObliqueNormalization, SplitAxis};
    for (k, v) in overrides {
        match k.as_str() {
            "min_examples" => {
                cfg.min_examples =
                    v.parse().map_err(|_| format!("bad min_examples '{v}'"))?
            }
            "max_depth" => {
                cfg.max_depth = v.parse().map_err(|_| format!("bad max_depth '{v}'"))?
            }
            "categorical_algorithm" => {
                cfg.splitter.categorical = match v.as_str() {
                    "CART" => CategoricalSplit::Cart,
                    "RANDOM" => CategoricalSplit::Random { trials: 32 },
                    "ONE_HOT" => CategoricalSplit::OneHot,
                    other => return Err(format!("unknown categorical algorithm '{other}'")),
                }
            }
            "split_axis" => {
                cfg.splitter.axis = match v.as_str() {
                    "AXIS_ALIGNED" => SplitAxis::AxisAligned,
                    "SPARSE_OBLIQUE" => SplitAxis::SparseOblique {
                        num_projections_exponent: 1.0,
                        normalization: ObliqueNormalization::MinMax,
                    },
                    other => return Err(format!("unknown split axis '{other}'")),
                }
            }
            _ => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn template_lookup() {
        assert!(find_template("GRADIENT_BOOSTED_TREES", "benchmark_rank1@v1").is_some());
        assert!(find_template("GRADIENT_BOOSTED_TREES", "benchmark_rank1").is_some());
        assert!(find_template("GRADIENT_BOOSTED_TREES", "benchmark_rank1@v9").is_none());
        assert!(find_template("LINEAR", "benchmark_rank1@v1").is_none());
    }

    #[test]
    fn search_space_samples_in_range() {
        let mut rng = Rng::seed_from_u64(1);
        for range in gbt_search_space() {
            for _ in 0..20 {
                let (k, v) = range.sample(&mut rng);
                assert!(!k.is_empty() && !v.is_empty());
                if let ParamRange::IntRange { lo, hi, .. } = range {
                    let x: i64 = v.parse().unwrap();
                    assert!(x >= lo && x <= hi);
                }
                if let ParamRange::FloatRange { lo, hi, .. } = range {
                    let x: f64 = v.parse().unwrap();
                    assert!(x >= lo && x <= hi);
                }
            }
        }
    }

    #[test]
    fn gbt_overrides_applied() {
        let mut cfg = crate::learner::gbt::GbtConfig::new("y");
        let mut o = HashMap::new();
        o.insert("shrinkage".to_string(), "0.05".to_string());
        o.insert("categorical_algorithm".to_string(), "RANDOM".to_string());
        o.insert("growing_strategy".to_string(), "BEST_FIRST_GLOBAL".to_string());
        o.insert("max_num_leaves".to_string(), "32".to_string());
        apply_gbt_overrides(&mut cfg, &o).unwrap();
        assert!((cfg.shrinkage - 0.05).abs() < 1e-12);
        assert!(matches!(
            cfg.splitter.categorical,
            crate::splitter::CategoricalSplit::Random { .. }
        ));
        assert!(matches!(
            cfg.growing,
            crate::learner::decision_tree::GrowingStrategy::BestFirstGlobal {
                max_num_leaves: 32
            }
        ));
    }

    #[test]
    fn rf_overrides_applied() {
        let mut cfg = crate::learner::random_forest::RandomForestConfig::new("y");
        let mut o = HashMap::new();
        o.insert("max_depth".to_string(), "25".to_string());
        o.insert("split_axis".to_string(), "SPARSE_OBLIQUE".to_string());
        apply_rf_overrides(&mut cfg, &o).unwrap();
        assert_eq!(cfg.max_depth, 25);
        assert!(matches!(
            cfg.splitter.axis,
            crate::splitter::SplitAxis::SparseOblique { .. }
        ));
    }
}
