//! Numerical feature splitters: exact in-sorting, exact pre-sorted, the
//! per-node automatic choice between them, and approximate histogram
//! splitting (§3.8, §2.3).
//!
//! The row-proportional state is allocation-free in the steady state:
//! `(value, row)` pairs and missing rows go into the reusable
//! [`NodeScratch`] buffers, global sort orders and binnings come from the
//! shared read-only [`ColumnIndex`], and the histogram's per-bin/suffix
//! accumulators are pooled in the scratch. (A handful of O(1) score
//! accumulators — parent/left/missing — are still built per candidate;
//! they are constant-size, not node-size.)

use super::score::{Labels, ScoreAcc};
use super::{
    collect_numerical, scan_sorted_pairs, ColumnIndex, NodeScratch, NumericalSplit,
    SplitCandidate, SplitterConfig,
};
use crate::dataset::Dataset;
use crate::model::tree::Condition;

/// Dispatches to the configured numerical splitter.
pub fn split_numerical(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    index: &ColumnIndex,
    scratch: &mut NodeScratch,
) -> Option<SplitCandidate> {
    match cfg.numerical {
        NumericalSplit::ExactInSort => split_insort(ds, col, rows, labels, cfg, scratch),
        NumericalSplit::Presorted => {
            split_presorted(ds, col, rows, labels, cfg, index, scratch)
        }
        NumericalSplit::Auto => {
            // In-sorting costs n·log n on node size n; pre-sorting costs a
            // full pass over all N rows. Pick the cheaper one per node —
            // the dynamic-choice behaviour §2.3 attributes to modularity.
            let n = rows.len() as f64;
            if n * n.log2().max(1.0) <= index.num_rows() as f64 {
                split_insort(ds, col, rows, labels, cfg, scratch)
            } else {
                split_presorted(ds, col, rows, labels, cfg, index, scratch)
            }
        }
        NumericalSplit::Histogram { bins } => {
            split_histogram(ds, col, rows, labels, cfg, index, scratch, bins)
        }
    }
}

/// Exact splitter, in-sorting approach: sort the node's feature values
/// (in the reusable scratch pair buffer).
pub fn split_insort(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    scratch: &mut NodeScratch,
) -> Option<SplitCandidate> {
    collect_numerical(ds, col, rows, &mut scratch.pairs, &mut scratch.missing);
    scratch.pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    scan_sorted_pairs(&scratch.pairs, &scratch.missing, labels, cfg.min_examples).map(|r| {
        SplitCandidate {
            condition: Condition::Higher { attr: col, threshold: r.threshold },
            gain: r.gain,
            missing_to_positive: r.missing_to_positive,
        }
    })
}

/// Exact splitter, pre-sorting approach: reuse the global sort order of the
/// column and filter it down to the node's rows via the membership stamps.
pub fn split_presorted(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    index: &ColumnIndex,
    scratch: &mut NodeScratch,
) -> Option<SplitCandidate> {
    // Duplicated rows (bootstrap) need multiplicity, which membership
    // stamps cannot express; fall back to in-sorting in that case. The RF
    // learner does not use presorting for exactly this reason.
    let (epoch, distinct) = scratch.mark_members(rows);
    if distinct != rows.len() {
        return split_insort(ds, col, rows, labels, cfg, scratch);
    }
    let order = index.sorted_order(ds, col);
    let values = ds.columns[col].as_numerical().expect("numerical column");
    let (members, pairs, missing) = scratch.members_and_pairs();
    pairs.clear();
    for &r in order {
        if members[r as usize] == epoch {
            pairs.push((values[r as usize], r));
        }
    }
    missing.clear();
    missing.extend(rows.iter().copied().filter(|&r| values[r as usize].is_nan()));
    scan_sorted_pairs(pairs, missing, labels, cfg.min_examples).map(|r| SplitCandidate {
        condition: Condition::Higher { attr: col, threshold: r.threshold },
        gain: r.gain,
        missing_to_positive: r.missing_to_positive,
    })
}

/// Approximate histogram splitter (LightGBM-style): bucket values into
/// quantile bins once (shared [`ColumnIndex`]), then scan per-bin
/// statistics per node with pooled accumulators.
#[allow(clippy::too_many_arguments)]
pub fn split_histogram(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    index: &ColumnIndex,
    scratch: &mut NodeScratch,
    bins: usize,
) -> Option<SplitCandidate> {
    let (edges, assignment) = index.binned_column(ds, col, bins);
    if edges.is_empty() {
        return None;
    }
    let num_bins = edges.len() + 1;
    scratch.ensure_bins(labels, num_bins);
    let mut miss = labels.new_acc();
    let values = ds.columns[col].as_numerical().expect("numerical column");
    let mut sum = 0.0f64;
    let mut n_nonmissing = 0usize;
    for &r in rows {
        let b = assignment[r as usize];
        if b == u16::MAX {
            miss.add(labels, r as usize);
        } else {
            scratch.bin_accs[b as usize].add(labels, r as usize);
            scratch.bin_counts[b as usize] += 1;
            sum += values[r as usize] as f64;
            n_nonmissing += 1;
        }
    }
    if n_nonmissing < 2 * cfg.min_examples.max(1) {
        return None;
    }
    let mean = (sum / n_nonmissing as f64) as f32;
    let has_missing = miss.count() > 0.0;

    // The pools keep their high-water-mark length (ensure_bins) — only
    // the first `num_bins` entries belong to this column.
    let mut parent = labels.new_acc();
    for a in &scratch.bin_accs[..num_bins] {
        parent.merge(a);
    }
    parent.merge(&miss);

    // Suffix accumulators: suffix[b] = union of bins b..num_bins, computed
    // once so the scan is O(bins), not O(bins^2). Pooled in the scratch —
    // filled back-to-front in place.
    for b in (0..num_bins).rev() {
        let (head, tail) = scratch.suffix_accs.split_at_mut(b + 1);
        let dst = &mut head[b];
        dst.reset();
        dst.merge(&tail[0]);
        dst.merge(&scratch.bin_accs[b]);
    }

    // Scan: left = bins 0..=b (values <= edges[b]), threshold just above
    // edge b. Condition is x >= t, so left is the negative branch.
    let mut left = labels.new_acc();
    let mut n_left = 0usize;
    let mut best: Option<SplitCandidate> = None;
    for b in 0..num_bins - 1 {
        left.merge(&scratch.bin_accs[b]);
        n_left += scratch.bin_counts[b];
        let n_right = n_nonmissing - n_left;
        if n_left < cfg.min_examples || n_right < cfg.min_examples {
            continue;
        }
        let threshold = next_up(edges[b]);
        let missing_to_positive = mean >= threshold;
        let gain = if has_missing {
            if missing_to_positive {
                let mut r2 = scratch.suffix_accs[b + 1].clone();
                r2.merge(&miss);
                ScoreAcc::gain(&parent, &left, &r2, labels)
            } else {
                let mut l2 = left.clone();
                l2.merge(&miss);
                ScoreAcc::gain(&parent, &l2, &scratch.suffix_accs[b + 1], labels)
            }
        } else {
            ScoreAcc::gain(&parent, &left, &scratch.suffix_accs[b + 1], labels)
        };
        if gain > best.as_ref().map(|b| b.gain).unwrap_or(0.0) {
            best = Some(SplitCandidate {
                condition: Condition::Higher { attr: col, threshold },
                gain,
                missing_to_positive,
            });
        }
    }
    best
}

/// Smallest f32 strictly greater than x (threshold "just above the edge").
fn next_up(x: f32) -> f32 {
    if x.is_nan() || x == f32::INFINITY {
        return x;
    }
    let bits = x.to_bits();
    let next = if x >= 0.0 { bits + 1 } else { bits - 1 };
    f32::from_bits(next)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::{ColumnSpec, DataSpec};
    use crate::dataset::ColumnData;
    use crate::utils::rng::Rng;

    fn ds_with(values: Vec<f32>) -> Dataset {
        let spec = DataSpec { columns: vec![ColumnSpec::numerical("x")] };
        Dataset::new(spec, vec![ColumnData::Numerical(values)]).unwrap()
    }

    fn cfg() -> SplitterConfig {
        SplitterConfig { min_examples: 1, ..Default::default() }
    }

    fn scratch_for(ds: &Dataset) -> NodeScratch {
        NodeScratch::new(ds.num_rows())
    }

    #[test]
    fn insort_finds_obvious_boundary() {
        let ds = ds_with(vec![1.0, 2.0, 3.0, 10.0, 11.0, 12.0]);
        let labels_data = vec![0u32, 0, 0, 1, 1, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..6).collect();
        let mut scratch = scratch_for(&ds);
        let c = split_insort(&ds, 0, &rows, &labels, &cfg(), &mut scratch).unwrap();
        match c.condition {
            Condition::Higher { attr, threshold } => {
                assert_eq!(attr, 0);
                assert!((threshold - 6.5).abs() < 1e-6, "threshold {threshold}");
            }
            _ => panic!("wrong condition"),
        }
        assert!(c.gain > 0.0);
    }

    #[test]
    fn presorted_matches_insort() {
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..20 {
            let n = 30 + rng.uniform_usize(50);
            let values: Vec<f32> =
                (0..n).map(|_| rng.uniform_range(-5.0, 5.0) as f32).collect();
            let labels_data: Vec<u32> =
                values.iter().map(|&v| (v > 0.0) as u32 ^ (rng.bernoulli(0.1) as u32)).collect();
            let ds = ds_with(values);
            let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
            let rows: Vec<u32> = (0..n as u32).filter(|r| r % 3 != 0).collect();
            let index = ColumnIndex::new(&ds);
            let mut scratch = scratch_for(&ds);
            let a = split_insort(&ds, 0, &rows, &labels, &cfg(), &mut scratch);
            let b = split_presorted(&ds, 0, &rows, &labels, &cfg(), &index, &mut scratch);
            match (a, b) {
                (Some(a), Some(b)) => {
                    assert!((a.gain - b.gain).abs() < 1e-9, "{} vs {}", a.gain, b.gain);
                    match (&a.condition, &b.condition) {
                        (
                            Condition::Higher { threshold: ta, .. },
                            Condition::Higher { threshold: tb, .. },
                        ) => assert_eq!(ta, tb),
                        _ => panic!(),
                    }
                }
                (None, None) => {}
                (a, b) => panic!("mismatch: {a:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn presorted_with_duplicates_falls_back_to_insort() {
        let ds = ds_with(vec![1.0, 2.0, 3.0, 10.0, 11.0, 12.0]);
        let labels_data = vec![0u32, 0, 0, 1, 1, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        // Bootstrap-style duplicates.
        let rows: Vec<u32> = vec![0, 0, 1, 2, 3, 4, 5, 5];
        let index = ColumnIndex::new(&ds);
        let mut scratch = scratch_for(&ds);
        let a = split_insort(&ds, 0, &rows, &labels, &cfg(), &mut scratch);
        let b = split_presorted(&ds, 0, &rows, &labels, &cfg(), &index, &mut scratch);
        match (a, b) {
            (Some(a), Some(b)) => {
                assert_eq!(a.gain.to_bits(), b.gain.to_bits());
            }
            other => panic!("mismatch: {other:?}"),
        }
    }

    #[test]
    fn histogram_close_to_exact_on_separable() {
        let n = 200;
        let mut rng = Rng::seed_from_u64(9);
        let values: Vec<f32> = (0..n).map(|_| rng.uniform_range(0.0, 1.0) as f32).collect();
        let labels_data: Vec<u32> = values.iter().map(|&v| (v > 0.6) as u32).collect();
        let ds = ds_with(values);
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..n as u32).collect();
        let index = ColumnIndex::new(&ds);
        let mut scratch = scratch_for(&ds);
        let c =
            split_histogram(&ds, 0, &rows, &labels, &cfg(), &index, &mut scratch, 64).unwrap();
        match c.condition {
            Condition::Higher { threshold, .. } => {
                assert!((threshold - 0.6).abs() < 0.05, "threshold {threshold}");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn histogram_scratch_reuse_is_stable() {
        // Two consecutive calls through the same scratch must agree bit
        // for bit (pooled accumulators fully reset between nodes).
        let n = 120;
        let mut rng = Rng::seed_from_u64(13);
        let values: Vec<f32> = (0..n)
            .map(|_| if rng.bernoulli(0.05) { f32::NAN } else { rng.uniform_range(-3.0, 3.0) as f32 })
            .collect();
        let labels_data: Vec<u32> =
            values.iter().map(|&v| (v.is_nan() || v > 0.0) as u32).collect();
        let ds = ds_with(values);
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..n as u32).collect();
        let index = ColumnIndex::new(&ds);
        let mut scratch = scratch_for(&ds);
        let a = split_histogram(&ds, 0, &rows, &labels, &cfg(), &index, &mut scratch, 16)
            .unwrap();
        let b = split_histogram(&ds, 0, &rows, &labels, &cfg(), &index, &mut scratch, 16)
            .unwrap();
        assert_eq!(a.gain.to_bits(), b.gain.to_bits());

        // A low-cardinality column dedupes to fewer bins: the pool keeps
        // its high-water length and only `[..num_bins]` may be read —
        // results through the warm pool must match a fresh scratch.
        let coarse: Vec<f32> = (0..n).map(|i| (i % 3) as f32).collect();
        let labels2_data: Vec<u32> = coarse.iter().map(|&v| (v > 1.0) as u32).collect();
        let labels2 = Labels::Classification { labels: &labels2_data, num_classes: 2 };
        let ds2 = ds_with(coarse);
        let index2 = ColumnIndex::new(&ds2);
        let warm =
            split_histogram(&ds2, 0, &rows, &labels2, &cfg(), &index2, &mut scratch, 16)
                .unwrap();
        let mut fresh = scratch_for(&ds2);
        let cold =
            split_histogram(&ds2, 0, &rows, &labels2, &cfg(), &index2, &mut fresh, 16)
                .unwrap();
        assert_eq!(warm.gain.to_bits(), cold.gain.to_bits());

        // And back to the wide column through the same (shrunk-use) pool.
        let c = split_histogram(&ds, 0, &rows, &labels, &cfg(), &index, &mut scratch, 16)
            .unwrap();
        assert_eq!(a.gain.to_bits(), c.gain.to_bits());
    }

    #[test]
    fn missing_values_follow_mean() {
        // Mean is in the high block, so missing should go positive.
        let ds = ds_with(vec![1.0, 1.5, 100.0, 101.0, 102.0, f32::NAN]);
        let labels_data = vec![0u32, 0, 1, 1, 1, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..6).collect();
        let mut scratch = scratch_for(&ds);
        let c = split_insort(&ds, 0, &rows, &labels, &cfg(), &mut scratch).unwrap();
        assert!(c.missing_to_positive);
    }

    #[test]
    fn constant_feature_yields_none() {
        let ds = ds_with(vec![3.0; 10]);
        let labels_data = vec![0u32, 1, 0, 1, 0, 1, 0, 1, 0, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..10).collect();
        let mut scratch = scratch_for(&ds);
        assert!(split_insort(&ds, 0, &rows, &labels, &cfg(), &mut scratch).is_none());
    }

    #[test]
    fn min_examples_respected() {
        let ds = ds_with(vec![1.0, 2.0, 3.0, 4.0]);
        let labels_data = vec![0u32, 1, 1, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..4).collect();
        let mut c = cfg();
        c.min_examples = 2;
        let mut scratch = scratch_for(&ds);
        let best = split_insort(&ds, 0, &rows, &labels, &c, &mut scratch).unwrap();
        // The only legal boundary is 2|2.
        match best.condition {
            Condition::Higher { threshold, .. } => {
                assert!((threshold - 2.5).abs() < 1e-6)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn next_up_is_strictly_greater() {
        for x in [0.0f32, 1.0, -1.0, 12345.678, -0.0001] {
            assert!(next_up(x) > x);
        }
    }

    #[test]
    fn regression_split() {
        let ds = ds_with(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let targets = vec![1.0f32, 1.1, 0.9, 5.0, 5.1, 4.9];
        let labels = Labels::Regression { targets: &targets };
        let rows: Vec<u32> = (0..6).collect();
        let mut scratch = scratch_for(&ds);
        let c = split_insort(&ds, 0, &rows, &labels, &cfg(), &mut scratch).unwrap();
        match c.condition {
            Condition::Higher { threshold, .. } => {
                assert!((threshold - 3.5).abs() < 1e-6)
            }
            _ => panic!(),
        }
    }
}
