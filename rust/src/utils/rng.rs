//! Deterministic pseudo-random number generation.
//!
//! YDF's §3.11 determinism guarantee ("the same Learner on the same dataset
//! always returns the same Model") requires that every stochastic component
//! draws from an explicitly seeded generator. We use SplitMix64 for seeding
//! and Xoshiro256++ for the stream — both are tiny, fast and well studied.

/// SplitMix64: used to expand a single `u64` seed into a full generator
/// state. Passes BigCrush; recommended by the Xoshiro authors for seeding.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ deterministic RNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Creates a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derives an independent child generator. Used to hand deterministic
    /// sub-streams to trees / workers without sharing mutable state.
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ 0xA5A5_5A5A_F00D_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` using Lemire's unbiased method.
    #[inline]
    pub fn uniform_usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn uniform_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.uniform_usize(hi - lo + 1)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (single value; simple and adequate).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.uniform_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Samples `k` distinct indices from `[0, n)` (k <= n), in random order.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Partial Fisher-Yates over an index vector; O(n) memory, fine for
        // the feature counts we deal with.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.uniform_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Samples `k` indices from `[0, n)` with replacement (bootstrap).
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.uniform_usize(n)).collect()
    }

    /// Picks one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.uniform_usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_usize_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.uniform_usize(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::seed_from_u64(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut r = Rng::seed_from_u64(9);
        let s = r.sample_without_replacement(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::seed_from_u64(21);
        let mut c1 = a.fork();
        let mut c2 = a.fork();
        let v1: Vec<u64> = (0..4).map(|_| c1.next_u64()).collect();
        let v2: Vec<u64> = (0..4).map(|_| c2.next_u64()).collect();
        assert_ne!(v1, v2);
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::seed_from_u64(33);
        let n = 50_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }
}
