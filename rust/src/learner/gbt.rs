//! Gradient Boosted Trees learner (Friedman 2001) with binomial,
//! multinomial and squared-error losses, shrinkage, early stopping on a
//! self-extracted validation split (§3.3), optional hessian gain and the
//! benchmark_rank1@v1 template (Appendix C.1).

use super::decision_tree::{grow_tree, AttrSampling, GrowingStrategy, TreeConfig};
use super::{classification_labels, feature_columns, regression_targets, Learner};
use crate::dataset::Dataset;
use crate::model::forest::{GbtLoss, GradientBoostedTreesModel};
use crate::model::{Model, SelfEvaluation, Task};
use crate::splitter::score::Labels;
use crate::splitter::{
    CategoricalSplit, ColumnIndex, ObliqueNormalization, RowArena, SplitAxis, SplitEngine,
    SplitterConfig,
};
use crate::utils::rng::Rng;
use crate::utils::stats::{sigmoid, softmax_in_place};
use std::collections::HashMap;
use std::sync::Arc;

/// Early-stopping policy (Appendix C.1: `early_stopping: LOSS_INCREASE`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EarlyStopping {
    None,
    /// Stop when validation loss has not improved for `patience`
    /// iterations; keep the best iteration's trees.
    LossIncrease { patience: usize },
}

/// GBT configuration. Defaults = Appendix C.1 "Gradient Boosted Trees
/// hyper-parameters".
#[derive(Clone, Debug)]
pub struct GbtConfig {
    pub label: String,
    pub task: Task,
    pub num_trees: usize,
    pub shrinkage: f64,
    pub max_depth: usize,
    pub min_examples: usize,
    pub l1: f64,
    pub l2: f64,
    pub use_hessian_gain: bool,
    /// Row subsampling per iteration (`sampling_method: NONE` -> 1.0).
    pub subsample: f64,
    pub attr_sampling: AttrSampling,
    pub splitter: SplitterConfig,
    pub growing: GrowingStrategy,
    /// Fraction of the training set set aside for validation when no
    /// validation dataset is provided (§3.3).
    pub validation_ratio: f64,
    pub early_stopping: EarlyStopping,
    /// Threads for the per-node split search (§3.10 work division across
    /// features): tree growth is sequential by nature in boosting, but
    /// each node's candidate features are scored in parallel. Bit-identical
    /// to single-threaded for any value. Defaults to
    /// [`super::train_threads`] (the `YDF_TRAIN_THREADS` override, else 1).
    pub num_threads: usize,
    pub seed: u64,
}

impl GbtConfig {
    pub fn new(label: &str) -> GbtConfig {
        GbtConfig {
            label: label.to_string(),
            task: Task::Classification,
            num_trees: 300,
            shrinkage: 0.1,
            max_depth: 6,
            min_examples: 5,
            l1: 0.0,
            l2: 0.0,
            use_hessian_gain: false,
            subsample: 1.0,
            attr_sampling: AttrSampling::All, // num_candidate_attributes: -1
            splitter: SplitterConfig::default(),
            growing: GrowingStrategy::Local,
            validation_ratio: 0.1,
            early_stopping: EarlyStopping::LossIncrease { patience: 30 },
            num_threads: super::train_threads(),
            seed: 4321,
        }
    }

    /// benchmark_rank1@v1 (Appendix C.1): best-first global growth, random
    /// categorical splits, sparse oblique projections with MIN_MAX
    /// normalization.
    pub fn benchmark_rank1(label: &str) -> GbtConfig {
        let mut cfg = GbtConfig::new(label);
        cfg.growing = GrowingStrategy::BestFirstGlobal { max_num_leaves: 32 };
        cfg.max_depth = usize::MAX;
        cfg.splitter.categorical = CategoricalSplit::Random { trials: 32 };
        cfg.splitter.axis = SplitAxis::SparseOblique {
            num_projections_exponent: 1.0,
            normalization: ObliqueNormalization::MinMax,
        };
        cfg
    }
}

pub struct GradientBoostedTreesLearner {
    pub config: GbtConfig,
}

impl GradientBoostedTreesLearner {
    pub fn new(config: GbtConfig) -> Self {
        GradientBoostedTreesLearner { config }
    }

    pub fn default_config(label: &str) -> Self {
        GradientBoostedTreesLearner::new(GbtConfig::new(label))
    }
}

/// Registry factory (§3.5).
pub fn factory(
    label: &str,
    params: &HashMap<String, String>,
) -> Result<Box<dyn Learner>, String> {
    let mut cfg = GbtConfig::new(label);
    if params.get("template").map(|s| s.as_str()) == Some("benchmark_rank1@v1") {
        cfg = GbtConfig::benchmark_rank1(label);
    }
    cfg.num_trees = super::parse_param(params, "num_trees", cfg.num_trees)?;
    cfg.shrinkage = super::parse_param(params, "shrinkage", cfg.shrinkage)?;
    cfg.max_depth = super::parse_param(params, "max_depth", cfg.max_depth)?;
    cfg.min_examples = super::parse_param(params, "min_examples", cfg.min_examples)?;
    cfg.subsample = super::parse_param(params, "subsample", cfg.subsample)?;
    cfg.use_hessian_gain =
        super::parse_param(params, "use_hessian_gain", cfg.use_hessian_gain)?;
    cfg.seed = super::parse_param(params, "seed", cfg.seed)?;
    cfg.num_threads = super::parse_param(params, "num_threads", cfg.num_threads)?;
    if let Some(t) = params.get("task") {
        cfg.task = match t.as_str() {
            "CLASSIFICATION" => Task::Classification,
            "REGRESSION" => Task::Regression,
            other => return Err(format!("unknown task '{other}'")),
        };
    }
    Ok(Box::new(GradientBoostedTreesLearner::new(cfg)))
}

impl Learner for GradientBoostedTreesLearner {
    fn name(&self) -> &'static str {
        "GRADIENT_BOOSTED_TREES"
    }

    fn label(&self) -> &str {
        &self.config.label
    }

    fn train_with_valid(
        &self,
        ds: &Dataset,
        valid: Option<&Dataset>,
    ) -> Result<Box<dyn Model>, String> {
        let cfg = &self.config;
        if ds.num_rows() < 4 {
            return Err(format!(
                "GBT training requires at least 4 examples, got {}.",
                ds.num_rows()
            ));
        }
        // Split off the validation set unless one was provided (§3.3).
        let use_early_stop = cfg.early_stopping != EarlyStopping::None;
        let (train_ds, valid_ds): (Dataset, Option<Dataset>) = match valid {
            Some(v) => (ds.clone(), Some(v.clone())),
            None if use_early_stop && cfg.validation_ratio > 0.0 => {
                let (tr, va) = ds.train_valid_split(cfg.validation_ratio, cfg.seed ^ 0x7777);
                (ds.subset(&tr), Some(ds.subset(&va)))
            }
            None => (ds.clone(), None),
        };

        match cfg.task {
            Task::Classification => {
                let (label_col, labels) = classification_labels(&train_ds, &cfg.label)?;
                let num_classes = train_ds.spec.columns[label_col].vocab_size();
                if num_classes < 2 {
                    return Err(format!(
                        "the label column \"{}\" has fewer than 2 classes.",
                        cfg.label
                    ));
                }
                let valid_labels = valid_ds
                    .as_ref()
                    .map(|v| classification_labels(v, &cfg.label).map(|(_, l)| l))
                    .transpose()?;
                if num_classes == 2 {
                    self.boost(
                        &train_ds,
                        valid_ds.as_ref(),
                        label_col,
                        BoostTargets::Binary { labels, valid_labels },
                    )
                } else {
                    self.boost(
                        &train_ds,
                        valid_ds.as_ref(),
                        label_col,
                        BoostTargets::Multiclass { labels, valid_labels, num_classes },
                    )
                }
            }
            Task::Regression => {
                let (label_col, targets) = regression_targets(&train_ds, &cfg.label)?;
                let valid_targets = valid_ds
                    .as_ref()
                    .map(|v| regression_targets(v, &cfg.label).map(|(_, t)| t))
                    .transpose()?;
                self.boost(
                    &train_ds,
                    valid_ds.as_ref(),
                    label_col,
                    BoostTargets::Regression { targets, valid_targets },
                )
            }
        }
    }
}

enum BoostTargets {
    Binary { labels: Vec<u32>, valid_labels: Option<Vec<u32>> },
    Multiclass { labels: Vec<u32>, valid_labels: Option<Vec<u32>>, num_classes: usize },
    Regression { targets: Vec<f32>, valid_targets: Option<Vec<f32>> },
}

impl GradientBoostedTreesLearner {
    fn boost(
        &self,
        train: &Dataset,
        valid: Option<&Dataset>,
        label_col: usize,
        targets: BoostTargets,
    ) -> Result<Box<dyn Model>, String> {
        let cfg = &self.config;
        let n = train.num_rows();
        let features = feature_columns(train, label_col);
        let mut rng = Rng::seed_from_u64(cfg.seed);

        let (loss, dim, initial): (GbtLoss, usize, Vec<f64>) = match &targets {
            BoostTargets::Binary { labels, .. } => {
                let pos = labels.iter().filter(|&&l| l == 1).count() as f64;
                let p = (pos / n as f64).clamp(1e-6, 1.0 - 1e-6);
                (GbtLoss::BinomialLogLikelihood, 1, vec![(p / (1.0 - p)).ln()])
            }
            BoostTargets::Multiclass { labels, num_classes, .. } => {
                let mut priors = vec![0.0f64; *num_classes];
                for &l in labels {
                    priors[l as usize] += 1.0;
                }
                let init = priors
                    .iter()
                    .map(|&c| ((c / n as f64).max(1e-9)).ln())
                    .collect();
                (GbtLoss::MultinomialLogLikelihood, *num_classes, init)
            }
            BoostTargets::Regression { targets, .. } => {
                let mean = targets.iter().map(|&t| t as f64).sum::<f64>() / n as f64;
                (GbtLoss::SquaredError, 1, vec![mean])
            }
        };

        // Raw scores per train/valid example per dim.
        let mut scores: Vec<f64> = (0..n * dim).map(|i| initial[i % dim]).collect();
        let n_valid = valid.map(|v| v.num_rows()).unwrap_or(0);
        let mut valid_scores: Vec<f64> =
            (0..n_valid * dim).map(|i| initial[i % dim]).collect();

        let tree_cfg = TreeConfig {
            max_depth: cfg.max_depth,
            min_examples: cfg.min_examples,
            splitter: cfg.splitter.clone(),
            growing: cfg.growing,
            attr_sampling: cfg.attr_sampling,
        };

        // One split engine (shared column index + worker pool + per-thread
        // scratch) and one row arena for the whole boosting run: per-node
        // and per-tree training state is reused, not reallocated.
        let mut engine =
            SplitEngine::new(Arc::new(ColumnIndex::new(train)), cfg.num_threads);
        let mut arena = RowArena::new();
        let mut trees = Vec::new();
        let mut grad = vec![0.0f32; n];
        let mut hess = vec![0.0f32; n];
        let mut best_valid_loss = f64::INFINITY;
        let mut best_num_trees = 0usize;
        let mut since_best = 0usize;
        let mut last_valid_loss = None;

        // Training telemetry: per-tree counters in the global metrics
        // registry, per-tree trace spans, and a per-iteration progress
        // line (train loss included) at info level. The train loss is an
        // extra pass over `scores`, so it is computed only when someone is
        // listening — with `YDF_LOG=off`/`warn` and no trace, the boosting
        // loop does exactly the work it did before.
        let obs_trees = crate::obs::metrics().counter_with(
            "ydf_train_trees_total",
            "Trees grown during training, by learner.",
            &[("learner", "gbt")],
        );
        let obs_iters = crate::obs::metrics().counter_with(
            "ydf_train_iterations_total",
            "Boosting iterations completed.",
            &[("learner", "gbt")],
        );
        let obs_tree_us = crate::obs::metrics().counter_with(
            "ydf_train_tree_micros_total",
            "Wall-clock microseconds spent growing trees (split search included), by learner.",
            &[("learner", "gbt")],
        );

        'outer: for iter in 0..cfg.num_trees {
            // Row subsampling for this iteration.
            let rows: Vec<u32> = if cfg.subsample < 1.0 {
                (0..n as u32)
                    .filter(|_| rng.bernoulli(cfg.subsample))
                    .collect()
            } else {
                (0..n as u32).collect()
            };
            if rows.len() < 2 * cfg.min_examples {
                break;
            }
            for k in 0..dim {
                // Gradients of the loss at current scores.
                match &targets {
                    BoostTargets::Binary { labels, .. } => {
                        for i in 0..n {
                            let p = sigmoid(scores[i]);
                            grad[i] = (p - labels[i] as f64) as f32;
                            hess[i] = (p * (1.0 - p)).max(1e-6) as f32;
                        }
                    }
                    BoostTargets::Multiclass { labels, num_classes, .. } => {
                        for i in 0..n {
                            let mut probs: Vec<f64> =
                                (0..*num_classes).map(|c| scores[i * dim + c]).collect();
                            softmax_in_place(&mut probs);
                            let y = (labels[i] as usize == k) as u8 as f64;
                            grad[i] = (probs[k] - y) as f32;
                            hess[i] = (probs[k] * (1.0 - probs[k])).max(1e-6) as f32;
                        }
                    }
                    BoostTargets::Regression { targets, .. } => {
                        for i in 0..n {
                            grad[i] = (scores[i] - targets[i] as f64) as f32;
                            hess[i] = 1.0;
                        }
                    }
                }
                let labels_view = Labels::Gradients {
                    grad: &grad,
                    hess: &hess,
                    use_hessian_gain: cfg.use_hessian_gain,
                    l1: cfg.l1,
                    l2: cfg.l2,
                };
                let t_span = crate::obs::trace::begin();
                let t_grow = std::time::Instant::now();
                let mut tree = grow_tree(
                    train,
                    &rows,
                    &labels_view,
                    &features,
                    &tree_cfg,
                    &mut engine,
                    &mut arena,
                    &mut rng,
                );
                let grow_us = t_grow.elapsed().as_secs_f64() * 1e6;
                obs_trees.inc();
                obs_tree_us.add(grow_us as u64);
                crate::obs::trace::end(t_span, "train_tree", || {
                    use crate::obs::trace::ArgValue;
                    vec![
                        ("learner", ArgValue::Str("gbt".to_string())),
                        ("iter", ArgValue::U64(iter as u64)),
                        ("dim", ArgValue::U64(k as u64)),
                        ("nodes", ArgValue::U64(tree.nodes.len() as u64)),
                        ("us", ArgValue::F64(grow_us)),
                    ]
                });
                crate::ydf_debug!(
                    "gbt iter {iter} dim {k}: grew tree with {} nodes in {:.0} us",
                    tree.nodes.len(),
                    grow_us
                );
                // Bake the shrinkage into leaf values.
                for node in &mut tree.nodes {
                    if node.is_leaf() {
                        node.value[0] *= cfg.shrinkage as f32;
                    }
                }
                // Update scores.
                for i in 0..n {
                    scores[i * dim + k] += tree.eval_ds(train, i).value[0] as f64;
                }
                if let Some(v) = valid {
                    for i in 0..n_valid {
                        valid_scores[i * dim + k] += tree.eval_ds(v, i).value[0] as f64;
                    }
                }
                trees.push(tree);
            }
            obs_iters.inc();
            if crate::obs::log::enabled(crate::obs::log::Level::Info)
                || crate::obs::trace::enabled()
            {
                // Train loss at the current scores — same formulas as the
                // validation loss below, over the training arrays.
                let train_loss = match &targets {
                    BoostTargets::Binary { labels, .. } => {
                        let mut loss_sum = 0.0;
                        for i in 0..n {
                            let p = sigmoid(scores[i]).clamp(1e-12, 1.0 - 1e-12);
                            loss_sum -= if labels[i] == 1 { p.ln() } else { (1.0 - p).ln() };
                        }
                        loss_sum / n.max(1) as f64
                    }
                    BoostTargets::Multiclass { labels, num_classes, .. } => {
                        let mut loss_sum = 0.0;
                        for i in 0..n {
                            let mut probs: Vec<f64> =
                                (0..*num_classes).map(|c| scores[i * dim + c]).collect();
                            softmax_in_place(&mut probs);
                            loss_sum -= probs[labels[i] as usize].max(1e-12).ln();
                        }
                        loss_sum / n.max(1) as f64
                    }
                    BoostTargets::Regression { targets, .. } => {
                        let mut loss_sum = 0.0;
                        for i in 0..n {
                            let e = scores[i] - targets[i] as f64;
                            loss_sum += e * e;
                        }
                        loss_sum / n.max(1) as f64
                    }
                };
                crate::ydf_info!(
                    "gbt iter {iter}: {} trees, train loss {train_loss:.6}, \
                     {} sampled rows, arena {} rows",
                    trees.len(),
                    rows.len(),
                    arena.len()
                );
                crate::obs::trace::instant("train_iteration", || {
                    use crate::obs::trace::ArgValue;
                    vec![
                        ("learner", ArgValue::Str("gbt".to_string())),
                        ("iter", ArgValue::U64(iter as u64)),
                        ("trees", ArgValue::U64(trees.len() as u64)),
                        ("train_loss", ArgValue::F64(train_loss)),
                        ("rows", ArgValue::U64(rows.len() as u64)),
                        ("arena_rows", ArgValue::U64(arena.len() as u64)),
                    ]
                });
            }

            // Early stopping on validation loss (LOSS_INCREASE).
            if let (Some(_v), EarlyStopping::LossIncrease { patience }) =
                (valid, cfg.early_stopping)
            {
                let vloss = match &targets {
                    BoostTargets::Binary { valid_labels: Some(vl), .. } => {
                        let mut loss_sum = 0.0;
                        for i in 0..n_valid {
                            let p = sigmoid(valid_scores[i]).clamp(1e-12, 1.0 - 1e-12);
                            loss_sum -= if vl[i] == 1 { p.ln() } else { (1.0 - p).ln() };
                        }
                        loss_sum / n_valid.max(1) as f64
                    }
                    BoostTargets::Multiclass { valid_labels: Some(vl), num_classes, .. } => {
                        let mut loss_sum = 0.0;
                        for i in 0..n_valid {
                            let mut probs: Vec<f64> = (0..*num_classes)
                                .map(|c| valid_scores[i * dim + c])
                                .collect();
                            softmax_in_place(&mut probs);
                            loss_sum -= probs[vl[i] as usize].max(1e-12).ln();
                        }
                        loss_sum / n_valid.max(1) as f64
                    }
                    BoostTargets::Regression { valid_targets: Some(vt), .. } => {
                        let mut loss_sum = 0.0;
                        for i in 0..n_valid {
                            let e = valid_scores[i] - vt[i] as f64;
                            loss_sum += e * e;
                        }
                        loss_sum / n_valid.max(1) as f64
                    }
                    _ => f64::INFINITY,
                };
                last_valid_loss = Some(vloss);
                if vloss < best_valid_loss - 1e-9 {
                    best_valid_loss = vloss;
                    best_num_trees = trees.len();
                    since_best = 0;
                } else {
                    since_best += 1;
                    if since_best >= patience {
                        break 'outer;
                    }
                }
            }
        }

        // Truncate to the best validated iteration.
        if best_num_trees > 0 && best_num_trees < trees.len() {
            trees.truncate(best_num_trees);
        }
        let validation_loss = if best_valid_loss.is_finite() {
            Some(best_valid_loss)
        } else {
            last_valid_loss
        };

        let self_eval = validation_loss.map(|v| SelfEvaluation {
            metric: "validation loss".to_string(),
            value: v,
            num_examples: n_valid as u64,
        });

        Ok(Box::new(GradientBoostedTreesModel {
            spec: train.spec.clone(),
            label_col,
            task: cfg.task,
            loss,
            trees,
            trees_per_iter: dim,
            initial_predictions: initial,
            validation_loss,
            self_eval,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::evaluation_free_accuracy;

    fn small_gbt(label: &str, trees: usize) -> GradientBoostedTreesLearner {
        let mut cfg = GbtConfig::new(label);
        cfg.num_trees = trees;
        cfg.max_depth = 4;
        GradientBoostedTreesLearner::new(cfg)
    }

    #[test]
    fn learns_binary_classification() {
        let ds = synthetic::adult_like(600, 21);
        let model = small_gbt("income", 30).train(&ds).unwrap();
        let acc = evaluation_free_accuracy(model.as_ref(), &ds);
        assert!(acc > 0.78, "train accuracy {acc}");
        assert!(model.self_evaluation().is_some());
    }

    #[test]
    fn learns_multiclass() {
        let spec = synthetic::spec_by_name("Iris").unwrap();
        let ds = synthetic::generate(spec, 3, &synthetic::GenOptions::default());
        let model = small_gbt("label", 25).train(&ds).unwrap();
        assert_eq!(model.num_classes(), 3);
        let p = model.predict_ds_row(&ds, 0);
        assert_eq!(p.len(), 3);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let acc = evaluation_free_accuracy(model.as_ref(), &ds);
        assert!(acc > 0.5, "accuracy {acc}");
    }

    #[test]
    fn learns_regression() {
        let ds = synthetic::adult_like(400, 9);
        let mut cfg = GbtConfig::new("capital_gain");
        cfg.task = Task::Regression;
        cfg.num_trees = 10;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        assert_eq!(model.task(), Task::Regression);
        let p = model.predict_ds_row(&ds, 0);
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn early_stopping_truncates() {
        let ds = synthetic::adult_like(300, 13);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 200;
        cfg.max_depth = 3;
        cfg.early_stopping = EarlyStopping::LossIncrease { patience: 5 };
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let gbt = model
            .as_any()
            .downcast_ref::<GradientBoostedTreesModel>()
            .unwrap();
        // On 300 examples the model overfits long before 200 trees.
        assert!(gbt.trees.len() < 200, "kept {} trees", gbt.trees.len());
        assert!(gbt.validation_loss.is_some());
    }

    #[test]
    fn deterministic() {
        let ds = synthetic::adult_like(200, 17);
        let m1 = small_gbt("income", 8).train(&ds).unwrap();
        let m2 = small_gbt("income", 8).train(&ds).unwrap();
        assert_eq!(m1.to_json().to_string(), m2.to_json().to_string());
    }

    #[test]
    fn benchmark_template_improves_or_matches_default() {
        // Not a strict inequality in general; check it trains and predicts.
        let ds = synthetic::adult_like(400, 29);
        let mut cfg = GbtConfig::benchmark_rank1("income");
        cfg.num_trees = 20;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let acc = evaluation_free_accuracy(model.as_ref(), &ds);
        assert!(acc > 0.7, "accuracy {acc}");
    }

    #[test]
    fn explicit_validation_dataset_used() {
        let ds = synthetic::adult_like(300, 31);
        let valid = synthetic::adult_like(100, 32);
        let model = small_gbt("income", 10).train_with_valid(&ds, Some(&valid)).unwrap();
        let gbt = model
            .as_any()
            .downcast_ref::<GradientBoostedTreesModel>()
            .unwrap();
        assert!(gbt.validation_loss.is_some());
    }

    #[test]
    fn tiny_dataset_rejected() {
        let ds = synthetic::adult_like(3, 1);
        let err = match small_gbt("income", 5).train(&ds) {
            Err(e) => e,
            Ok(_) => panic!(),
        };
        assert!(err.contains("at least 4 examples"), "{err}");
    }
}
