//! Figure 3: composed meta-learners — a calibrator containing an
//! ensembler, which contains a hyper-parameter tuner around a Random
//! Forest plus a vanilla Gradient Boosted Trees learner (§3.2).
//!
//! Run: `cargo run --release --example metalearners`

use ydf::dataset::synthetic;
use ydf::evaluation::evaluate_model;
use ydf::learner::gbt::GbtConfig;
use ydf::learner::random_forest::RandomForestConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};
use ydf::metalearner::{
    CalibratorLearner, EnsemblerLearner, FeatureSelectorLearner, TunerLearner, TunerScoring,
};

fn main() {
    let train = synthetic::adult_like(1500, 21);
    let test = synthetic::adult_like(800, 22);

    // Inner learner 1: hyper-parameter tuner optimising a Random Forest.
    let mut rf = RandomForestConfig::new("income");
    rf.num_trees = 20;
    rf.compute_oob = false;
    let tuner = TunerLearner::new_rf(rf, 4, TunerScoring::Accuracy);

    // Inner learner 2: vanilla GBT.
    let mut gbt = GbtConfig::new("income");
    gbt.num_trees = 30;
    gbt.max_depth = 4;
    let gbt_learner = GradientBoostedTreesLearner::new(gbt);

    // Ensembler over both; calibrator on top (Figure 3's exact nesting).
    let ensembler = EnsemblerLearner::new(vec![Box::new(tuner), Box::new(gbt_learner)]);
    let calibrated = CalibratorLearner::new(Box::new(ensembler));

    println!("training calibrator(ensembler(tuner(RF), GBT)) ...");
    let model = calibrated.train(&train).expect("training");
    let ev = evaluate_model(model.as_ref(), &test, "income").unwrap();
    println!("composed meta-learner:\n{}", ev.report());

    // Bonus composition: feature selector around a Random Forest using
    // out-of-bag self-evaluation (§3.6's example).
    let mut rf = RandomForestConfig::new("income");
    rf.num_trees = 15;
    let selector = FeatureSelectorLearner::new(Box::new(RandomForestLearner::new(rf)));
    let model = selector.train(&train).expect("feature selection");
    let ev = evaluate_model(model.as_ref(), &test, "income").unwrap();
    println!(
        "feature-selected RF: accuracy {:.4} (features kept: {})",
        ev.accuracy,
        model.input_features().len()
    );
}
