//! LEARNER abstraction (§3.1): a learner is a function that takes a dataset
//! and returns a [`Model`]. Learners are registered by name (§3.5's
//! REGISTER mechanism) so the CLI, meta-learners and the benchmark harness
//! can instantiate them generically — [`create_learner`] resolves
//! `"GRADIENT_BOOSTED_TREES"`, `"RANDOM_FOREST"`, `"CART"` and `"LINEAR"`.
//!
//! Each learner pairs a plain config struct (defaults from Appendix C.1)
//! with a [`Learner`] impl. Training a Random Forest on a synthetic
//! dataset:
//!
//! ```
//! use ydf::learner::random_forest::RandomForestConfig;
//! use ydf::learner::{Learner, RandomForestLearner};
//! use ydf::model::Model;
//!
//! let data = ydf::dataset::synthetic::adult_like(120, 42);
//! let mut config = RandomForestConfig::new("income"); // label column
//! config.num_trees = 3;
//! config.compute_oob = false;
//! let model = RandomForestLearner::new(config).train(&data).unwrap();
//! // Classification models predict one probability per class.
//! assert_eq!(model.predict_ds_row(&data, 0).len(), 2);
//! ```
//!
//! Batch prediction goes through the compiled engines of
//! [`crate::inference`] (see [`crate::inference::predict_flat`]) rather
//! than the per-row loop above.

pub mod cart;
pub mod decision_tree;
pub mod gbt;
pub mod hparams;
pub mod linear;
pub mod random_forest;

pub use gbt::GradientBoostedTreesLearner;
pub use linear::LinearLearner;
pub use random_forest::RandomForestLearner;

use crate::dataset::{ColumnData, Dataset, FeatureSemantic, MISSING_CAT};
use crate::model::Model;
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// A learning algorithm (§3.1). `train_with_valid` supports the optional
/// validation dataset of §3.3; the default implementation delegates to
/// `train`, and learners that support early stopping override it.
pub trait Learner: Send + Sync {
    fn name(&self) -> &'static str;
    /// The label column this learner is configured for.
    fn label(&self) -> &str;
    fn train(&self, ds: &Dataset) -> Result<Box<dyn Model>, String> {
        self.train_with_valid(ds, None)
    }
    fn train_with_valid(
        &self,
        ds: &Dataset,
        valid: Option<&Dataset>,
    ) -> Result<Box<dyn Model>, String>;
}

/// Extracts classification labels (dense class indices) from a dataset.
/// Fails with a §2.1-style actionable message when the label is unusable.
pub fn classification_labels(ds: &Dataset, label: &str) -> Result<(usize, Vec<u32>), String> {
    let label_col = ds.column_index(label).ok_or_else(|| {
        format!(
            "the label column \"{label}\" does not exist in the dataset. Available columns: \
             [{}].",
            ds.spec.columns.iter().map(|c| c.name.as_str()).collect::<Vec<_>>().join(", ")
        )
    })?;
    let spec = &ds.spec.columns[label_col];
    crate::dataset::dataspec::check_classification_label(spec, ds.num_rows(), false)?;
    if spec.semantic != FeatureSemantic::Categorical {
        return Err(format!(
            "classification training requires a CATEGORICAL label; column \"{label}\" is {}.",
            spec.semantic.name()
        ));
    }
    let values = match &ds.columns[label_col] {
        ColumnData::Categorical(v) => v,
        _ => unreachable!(),
    };
    if values.iter().any(|&v| v == MISSING_CAT) {
        return Err(format!(
            "the label column \"{label}\" contains missing values. Remove or impute the \
             affected examples before training."
        ));
    }
    Ok((label_col, values.clone()))
}

/// Extracts regression targets.
pub fn regression_targets(ds: &Dataset, label: &str) -> Result<(usize, Vec<f32>), String> {
    let label_col = ds
        .column_index(label)
        .ok_or_else(|| format!("the label column \"{label}\" does not exist in the dataset."))?;
    let values = ds.columns[label_col].as_numerical().ok_or_else(|| {
        format!(
            "regression training requires a NUMERICAL label; column \"{label}\" is {}. \
             Possible solution: configure the training as a classification with \
             task=CLASSIFICATION.",
            ds.spec.columns[label_col].semantic.name()
        )
    })?;
    if values.iter().any(|v| v.is_nan()) {
        return Err(format!("the label column \"{label}\" contains missing values."));
    }
    Ok((label_col, values.to_vec()))
}

/// Feature columns = all columns except the label.
pub fn feature_columns(ds: &Dataset, label_col: usize) -> Vec<usize> {
    (0..ds.num_columns()).filter(|&c| c != label_col).collect()
}

/// Default training thread count: `YDF_TRAIN_THREADS` when set to a
/// positive integer, otherwise 1. This seeds
/// `RandomForestConfig::num_threads` (tree-level parallelism) and
/// `GbtConfig::num_threads` (per-node feature-parallel split search);
/// both are bit-identical to single-threaded training, so the knob is
/// pure throughput. A set-but-invalid value (unparsable, or `0`) falls
/// back to 1 with a one-time warning naming the bad value (via
/// `utils::env`) — the same contract as `YDF_INFER_THREADS` on the
/// inference side.
pub fn train_threads() -> usize {
    crate::utils::env::positive_usize("YDF_TRAIN_THREADS").unwrap_or(1)
}

/// Binary-classification sanity guard used by GBT's binomial loss.
pub fn require_binary(ds: &Dataset, label_col: usize) -> Result<(), String> {
    let spec = &ds.spec.columns[label_col];
    let n = spec.vocab_size();
    if n != 2 {
        return Err(format!(
            "Binary classification training (task=BINARY_CLASSIFICATION) requires a training \
             dataset with a label having 2 classes, however, {n} classe(s) were found in the \
             label column \"{}\". Those {n} classe(s) are [{}]. Possible solutions: (1) Use a \
             training dataset with two classes, or (2) use a learning algorithm that supports \
             single-class or multi-class classification e.g. learner='RANDOM_FOREST'.",
            spec.name,
            spec.dictionary.join(", ")
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Learner registry (§3.5): REGISTER_AbstractLearner equivalent.
// ---------------------------------------------------------------------------

/// Factory signature: (label name, hyper-parameter overrides) -> learner.
pub type LearnerFactory =
    fn(label: &str, params: &HashMap<String, String>) -> Result<Box<dyn Learner>, String>;

fn registry() -> &'static Mutex<HashMap<String, LearnerFactory>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, LearnerFactory>>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut m: HashMap<String, LearnerFactory> = HashMap::new();
        // Built-in learners (§3.1).
        m.insert("GRADIENT_BOOSTED_TREES".into(), gbt::factory);
        m.insert("RANDOM_FOREST".into(), random_forest::factory);
        m.insert("CART".into(), cart::factory);
        m.insert("LINEAR".into(), linear::factory);
        Mutex::new(m)
    })
}

/// Registers a custom learner under `name` (custom modules can live outside
/// the library code base, §3.5).
pub fn register_learner(name: &str, factory: LearnerFactory) {
    registry().lock().unwrap().insert(name.to_string(), factory);
}

/// Instantiates a registered learner.
pub fn create_learner(
    name: &str,
    label: &str,
    params: &HashMap<String, String>,
) -> Result<Box<dyn Learner>, String> {
    let reg = registry().lock().unwrap();
    let factory = reg.get(name).ok_or_else(|| {
        let mut known: Vec<&str> = reg.keys().map(|s| s.as_str()).collect();
        known.sort_unstable();
        format!(
            "unknown learner '{name}'. Registered learners: [{}].",
            known.join(", ")
        )
    })?;
    factory(label, params)
}

/// Parses a hyper-parameter with a typed error message.
pub fn parse_param<T: std::str::FromStr>(
    params: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T, String> {
    match params.get(key) {
        None => Ok(default),
        Some(v) => v.parse::<T>().map_err(|_| {
            format!("hyper-parameter '{key}' has invalid value '{v}' (expected {}).",
                std::any::type_name::<T>())
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;

    #[test]
    fn label_extraction() {
        let ds = synthetic::adult_like(100, 1);
        let (col, labels) = classification_labels(&ds, "income").unwrap();
        assert_eq!(col, 8);
        assert_eq!(labels.len(), 100);
        assert!(labels.iter().all(|&l| l < 2));
    }

    #[test]
    fn unknown_label_lists_columns() {
        let ds = synthetic::adult_like(10, 1);
        let err = classification_labels(&ds, "nope").unwrap_err();
        assert!(err.contains("Available columns"), "{err}");
        assert!(err.contains("income"), "{err}");
    }

    #[test]
    fn regression_on_categorical_label_fails_actionably() {
        let ds = synthetic::adult_like(10, 1);
        let err = regression_targets(&ds, "income").unwrap_err();
        assert!(err.contains("task=CLASSIFICATION"), "{err}");
    }

    #[test]
    fn binary_guard_message_matches_table1() {
        let ds = synthetic::generate(
            synthetic::spec_by_name("Iris").unwrap(),
            1,
            &synthetic::GenOptions::default(),
        );
        let (label_col, _) = classification_labels(&ds, "label").unwrap();
        let err = require_binary(&ds, label_col).unwrap_err();
        assert!(err.contains("requires a training dataset with a label having 2 classes"));
        assert!(err.contains("learner='RANDOM_FOREST'"));
    }

    #[test]
    fn registry_has_builtins_and_rejects_unknown() {
        let params = HashMap::new();
        assert!(create_learner("GRADIENT_BOOSTED_TREES", "income", &params).is_ok());
        assert!(create_learner("RANDOM_FOREST", "income", &params).is_ok());
        assert!(create_learner("CART", "income", &params).is_ok());
        assert!(create_learner("LINEAR", "income", &params).is_ok());
        let err = match create_learner("DOES_NOT_EXIST", "y", &params) {
            Err(e) => e,
            Ok(_) => panic!(),
        };
        assert!(err.contains("Registered learners"), "{err}");
    }

    #[test]
    fn custom_registration() {
        fn f(
            label: &str,
            _p: &HashMap<String, String>,
        ) -> Result<Box<dyn Learner>, String> {
            Ok(Box::new(gbt::GradientBoostedTreesLearner::default_config(label)))
        }
        register_learner("MY_LEARNER", f);
        assert!(create_learner("MY_LEARNER", "y", &HashMap::new()).is_ok());
    }

    #[test]
    fn param_parsing() {
        let mut p = HashMap::new();
        p.insert("num_trees".to_string(), "25".to_string());
        assert_eq!(parse_param(&p, "num_trees", 300usize).unwrap(), 25);
        assert_eq!(parse_param(&p, "other", 7usize).unwrap(), 7);
        p.insert("bad".to_string(), "xyz".to_string());
        assert!(parse_param(&p, "bad", 1.0f64).is_err());
    }
}
