//! Property-based testing helpers (proptest is unavailable offline).
//!
//! `run_cases` drives a property over many random inputs with a fixed seed
//! per test (reproducible failures); generators produce random datasets,
//! vectors and label assignments. On failure the failing case index and a
//! compact debug description are reported.

use crate::utils::rng::Rng;

/// Runs `prop(case_rng, case_index)` for `cases` deterministic cases.
/// Panics with the case index on the first failure so the case can be
/// replayed by seeding `Rng::seed_from_u64(seed ^ index)`.
pub fn run_cases<F: FnMut(&mut Rng, usize)>(seed: u64, cases: usize, mut prop: F) {
    for i in 0..cases {
        let mut rng = Rng::seed_from_u64(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        prop(&mut rng, i);
    }
}

/// Random f64 vector with occasional extreme values — exercises splitter
/// edge cases (constants, duplicates, infinities are excluded by design:
/// the dataset layer rejects non-finite input).
pub fn gen_f64_vec(rng: &mut Rng, len: usize) -> Vec<f64> {
    let style = rng.uniform_usize(4);
    (0..len)
        .map(|_| match style {
            0 => rng.uniform_range(-1.0, 1.0),
            1 => rng.uniform_range(-1e6, 1e6),
            2 => (rng.uniform_usize(5) as f64) - 2.0, // heavy ties
            _ => rng.normal_ms(0.0, 10.0),
        })
        .collect()
}

/// Random binary label vector.
pub fn gen_labels(rng: &mut Rng, len: usize, classes: usize) -> Vec<u32> {
    (0..len).map(|_| rng.uniform_usize(classes) as u32).collect()
}

/// Random weights, strictly positive.
pub fn gen_weights(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.uniform_range(0.1, 3.0) as f32).collect()
}

/// Asserts two floats are close with a relative+absolute tolerance.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let scale = a.abs().max(b.abs()).max(1.0);
    assert!(
        (a - b).abs() <= tol * scale,
        "assert_close failed: {a} vs {b} (tol {tol})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic() {
        let mut first = Vec::new();
        run_cases(7, 5, |rng, _| first.push(rng.next_u64()));
        let mut second = Vec::new();
        run_cases(7, 5, |rng, _| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }

    #[test]
    fn generators_in_bounds() {
        run_cases(3, 20, |rng, _| {
            let xs = gen_f64_vec(rng, 50);
            assert_eq!(xs.len(), 50);
            assert!(xs.iter().all(|x| x.is_finite()));
            let ys = gen_labels(rng, 30, 4);
            assert!(ys.iter().all(|&y| y < 4));
            let ws = gen_weights(rng, 10);
            assert!(ws.iter().all(|&w| w > 0.0));
        });
    }

    #[test]
    fn assert_close_accepts_close() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9);
        assert_close(1e9, 1e9 * (1.0 + 1e-10), 1e-9);
    }

    #[test]
    #[should_panic]
    fn assert_close_rejects_far() {
        assert_close(1.0, 2.0, 1e-9);
    }
}
