//! KDD'23 benchmark harness (§5): runs the 16 learners over the synthetic
//! Table 5 suite with consistent 10-fold cross-validation and regenerates
//! Figure 6 and Tables 2, 3, 4, 5, 6 and 7.

pub mod learners;

use crate::dataset::synthetic::{self, GenOptions, SyntheticSpec};
use crate::evaluation::comparison::PairwiseComparison;
use crate::evaluation::cv::cross_validate;
use crate::utils::bench::{bar_chart, Table};
use crate::utils::stats;
use learners::{benchmark_learners, untuned_learner_names, LearnerScale};

/// Suite configuration. The default is scaled for a single-core budget;
/// `SuiteConfig::full()` mirrors the paper's protocol (70 datasets, 10
/// folds, 500 trees, 300 trials) and takes correspondingly long.
#[derive(Clone, Debug)]
pub struct SuiteConfig {
    /// Dataset names from Table 5 (`synthetic::TABLE5`).
    pub datasets: Vec<&'static str>,
    pub folds: usize,
    pub max_examples: usize,
    pub max_features: usize,
    pub scale: LearnerScale,
    pub seed: u64,
}

impl Default for SuiteConfig {
    fn default() -> Self {
        SuiteConfig {
            datasets: vec![
                "Iris",
                "Blood_Transfusion",
                "Diabetes",
                "Banknote_Authentication",
                "Credit_Approval",
                "Balance_Scale",
                "TicTacToe",
                "Dresses_Sales",
                "ILPD",
                "Vowel",
            ],
            folds: 3,
            max_examples: 400,
            max_features: 24,
            scale: LearnerScale { num_trees: 15, tuner_trials: 3 },
            seed: 20230806, // KDD'23 started 2023-08-06
        }
    }
}

impl SuiteConfig {
    /// The paper-faithful configuration (hours of compute on this testbed).
    pub fn full() -> SuiteConfig {
        SuiteConfig {
            datasets: synthetic::TABLE5.iter().map(|s| s.name).collect(),
            folds: 10,
            max_examples: usize::MAX,
            max_features: usize::MAX,
            scale: LearnerScale { num_trees: 500, tuner_trials: 300 },
            seed: 20230806,
        }
    }
}

/// Raw per-(dataset × learner × fold) results.
pub struct SuiteResult {
    pub config: SuiteConfig,
    pub learner_names: Vec<&'static str>,
    pub dataset_names: Vec<&'static str>,
    /// `accuracy[dataset][learner][fold]`
    pub accuracy: Vec<Vec<Vec<f64>>>,
    /// mean seconds per fold
    pub train_seconds: Vec<Vec<f64>>,
    pub inference_seconds: Vec<Vec<f64>>,
}

/// Runs the suite. `progress` receives one line per (dataset, learner).
pub fn run_suite(config: &SuiteConfig, mut progress: impl FnMut(&str)) -> SuiteResult {
    let learner_names: Vec<&'static str> =
        benchmark_learners("label", config.scale).into_iter().map(|(n, _)| n).collect();
    let mut accuracy = Vec::new();
    let mut train_seconds = Vec::new();
    let mut inference_seconds = Vec::new();
    let gen_opts = GenOptions {
        max_examples: config.max_examples,
        max_features: config.max_features,
        ..Default::default()
    };
    for ds_name in &config.datasets {
        let spec: &SyntheticSpec =
            synthetic::spec_by_name(ds_name).unwrap_or_else(|| panic!("unknown dataset {ds_name}"));
        let ds = synthetic::generate(spec, config.seed, &gen_opts);
        let mut ds_acc = Vec::new();
        let mut ds_train = Vec::new();
        let mut ds_infer = Vec::new();
        for (name, learner) in benchmark_learners("label", config.scale) {
            let cv = cross_validate(learner.as_ref(), &ds, config.folds, config.seed)
                .unwrap_or_else(|e| panic!("{ds_name}/{name}: {e}"));
            progress(&format!(
                "{ds_name:>24} {name:<28} acc={:.4} train={:.2}s",
                cv.mean_accuracy(),
                cv.mean_train_seconds()
            ));
            ds_acc.push(cv.fold_evaluations.iter().map(|e| e.accuracy).collect());
            ds_train.push(cv.mean_train_seconds());
            ds_infer.push(cv.mean_inference_seconds());
        }
        accuracy.push(ds_acc);
        train_seconds.push(ds_train);
        inference_seconds.push(ds_infer);
    }
    SuiteResult {
        config: config.clone(),
        learner_names,
        dataset_names: config.datasets.clone(),
        accuracy,
        train_seconds,
        inference_seconds,
    }
}

impl SuiteResult {
    fn mean_accuracy(&self, dataset: usize, learner: usize) -> f64 {
        stats::mean(&self.accuracy[dataset][learner])
    }

    /// Mean rank per learner (Figure 6): rank learners per dataset by mean
    /// CV accuracy (rank 1 = best), average over datasets.
    pub fn mean_ranks(&self) -> Vec<(String, f64)> {
        let nl = self.learner_names.len();
        let mut rank_sum = vec![0.0; nl];
        for d in 0..self.dataset_names.len() {
            // Negate accuracy so rank 1 = highest accuracy.
            let neg_acc: Vec<f64> = (0..nl).map(|l| -self.mean_accuracy(d, l)).collect();
            let ranks = stats::fractional_ranks(&neg_acc);
            for (s, r) in rank_sum.iter_mut().zip(&ranks) {
                *s += r;
            }
        }
        let nd = self.dataset_names.len().max(1) as f64;
        let mut out: Vec<(String, f64)> = self
            .learner_names
            .iter()
            .zip(&rank_sum)
            .map(|(n, &s)| (n.to_string(), s / nd))
            .collect();
        out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        out
    }

    /// Figure 6: mean learner ranks as an ASCII bar chart (smaller =
    /// better).
    pub fn fig6_report(&self) -> String {
        let ranks = self.mean_ranks();
        format!(
            "Figure 6 — Mean learner ranks over {} datasets ({} folds). Smaller is better.\n{}",
            self.dataset_names.len(),
            self.config.folds,
            bar_chart(&ranks, 30)
        )
    }

    /// Table 2: mean training and inference seconds of the untuned
    /// learners, ordered by quality rank.
    pub fn table2_report(&self) -> String {
        let ranks = self.mean_ranks();
        let mut t = Table::new(&["Learner", "training (s)", "inference (s)"]);
        for untuned in untuned_learner_names() {
            // Order rows by the rank computed above, as the paper does.
            let _ = &ranks;
            let l = self.learner_names.iter().position(|n| *n == untuned).unwrap();
            let train = stats::mean(
                &(0..self.dataset_names.len())
                    .map(|d| self.train_seconds[d][l])
                    .collect::<Vec<_>>(),
            );
            let infer = stats::mean(
                &(0..self.dataset_names.len())
                    .map(|d| self.inference_seconds[d][l])
                    .collect::<Vec<_>>(),
            );
            t.row(vec![untuned.to_string(), format!("{train:.3}"), format!("{infer:.4}")]);
        }
        format!("Table 2 — Mean training and inference duration (untuned learners)\n{}", t.render())
    }

    /// Table 3: pairwise wins/losses over all (dataset, fold) pairs.
    pub fn table3_report(&self) -> String {
        let nl = self.learner_names.len();
        let order: Vec<usize> = {
            let ranks = self.mean_ranks();
            ranks
                .iter()
                .map(|(n, _)| self.learner_names.iter().position(|x| x == n).unwrap())
                .collect()
        };
        let mut header = vec!["row \\ col"];
        let idx_label: Vec<String> = (1..=nl).map(|i| format!("{i}")).collect();
        header.extend(idx_label.iter().map(|s| s.as_str()));
        let mut t = Table::new(&header);
        for (ri, &l_row) in order.iter().enumerate() {
            let mut cells = vec![format!("{} {}", ri + 1, self.learner_names[l_row])];
            for &l_col in &order {
                if l_row == l_col {
                    cells.push("-".to_string());
                    continue;
                }
                let a: Vec<f64> = self.accuracy.iter().flat_map(|d| d[l_row].clone()).collect();
                let b: Vec<f64> = self.accuracy.iter().flat_map(|d| d[l_col].clone()).collect();
                let cmp = PairwiseComparison::from_paired(&a, &b);
                cells.push(cmp.cell());
            }
            t.row(cells);
        }
        format!(
            "Table 3 — Pairwise wins/losses (row vs column) over all dataset x fold pairs\n{}",
            t.render()
        )
    }

    /// Table 4: per-dataset mean accuracy, learners sorted by rank.
    pub fn table4_report(&self) -> String {
        let ranks = self.mean_ranks();
        let mut header = vec!["Learner".to_string(), "Avg.Rank".to_string()];
        header.extend(self.dataset_names.iter().map(|n| n.to_string()));
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&header_refs);
        for (name, rank) in &ranks {
            let l = self.learner_names.iter().position(|n| n == name).unwrap();
            let mut cells = vec![name.clone(), format!("{rank:.1}")];
            for d in 0..self.dataset_names.len() {
                cells.push(format!("{:.3}", self.mean_accuracy(d, l)));
            }
            t.row(cells);
        }
        format!("Table 4 — Accuracy per learner per dataset (mean over folds)\n{}", t.render())
    }

    /// Tables 6/7: per-dataset training / inference time of untuned
    /// learners.
    pub fn time_table_report(&self, inference: bool) -> String {
        let mut header = vec!["Learner".to_string()];
        header.extend(self.dataset_names.iter().map(|n| n.to_string()));
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&header_refs);
        for untuned in untuned_learner_names() {
            let l = self.learner_names.iter().position(|n| *n == untuned).unwrap();
            let mut cells = vec![untuned.to_string()];
            for d in 0..self.dataset_names.len() {
                let v = if inference {
                    self.inference_seconds[d][l]
                } else {
                    self.train_seconds[d][l]
                };
                cells.push(format!("{v:.4}"));
            }
            t.row(cells);
        }
        let which = if inference { "7 — Inference" } else { "6 — Training" };
        format!("Table {which} time in seconds per dataset (untuned learners)\n{}", t.render())
    }
}

/// Table 5: the dataset inventory.
pub fn table5_report() -> String {
    let mut t =
        Table::new(&["Dataset", "Examples", "Features", "Categorical", "Numerical", "Classes"]);
    for s in synthetic::TABLE5 {
        t.row(vec![
            s.name.to_string(),
            s.examples.to_string(),
            s.features().to_string(),
            s.categorical.to_string(),
            s.numerical.to_string(),
            s.classes.to_string(),
        ]);
    }
    format!("Table 5 — Name and size of the datasets (synthetic suite)\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_suite_runs_end_to_end() {
        let config = SuiteConfig {
            datasets: vec!["Iris", "Blood_Transfusion"],
            folds: 2,
            max_examples: 120,
            max_features: 8,
            scale: LearnerScale { num_trees: 3, tuner_trials: 1 },
            seed: 1,
        };
        let result = run_suite(&config, |_| {});
        assert_eq!(result.dataset_names.len(), 2);
        assert_eq!(result.learner_names.len(), 16);
        let ranks = result.mean_ranks();
        assert_eq!(ranks.len(), 16);
        // Ranks average to (1 + 16) / 2.
        let mean_of_ranks: f64 = ranks.iter().map(|(_, r)| r).sum::<f64>() / 16.0;
        assert!((mean_of_ranks - 8.5).abs() < 1e-9, "{mean_of_ranks}");
        // All report renderers produce non-empty output.
        assert!(result.fig6_report().contains("Figure 6"));
        assert!(result.table2_report().contains("Table 2"));
        assert!(result.table3_report().contains("Table 3"));
        assert!(result.table4_report().contains("Table 4"));
        assert!(result.time_table_report(false).contains("Table 6"));
        assert!(result.time_table_report(true).contains("Table 7"));
        assert!(table5_report().contains("Adult"));
    }
}
