//! Naive engine: Algorithm 1 of the paper — per-tree root-to-leaf pointer
//! chasing through the model's own structures. Always compatible; the
//! baseline the optimized engines are validated against.

use super::InferenceEngine;
use crate::dataset::{Dataset, Observation};
use crate::model::forest::{GradientBoostedTreesModel, RandomForestModel};
use crate::model::linear::LinearModel;
use crate::model::Model;
use std::ops::Range;

/// Holds a deep copy of the model (engines are self-contained so the
/// source model can be dropped after compilation, §3.7).
pub enum NaiveEngine {
    Rf(RandomForestModel),
    Gbt(GradientBoostedTreesModel),
    Linear(LinearModel),
    /// Fallback for wrapper models (ensembles, calibrated): boxed clone is
    /// unavailable, so this variant is not constructed for them — see
    /// `compile`.
    Unsupported,
}

impl NaiveEngine {
    pub fn compile(model: &dyn Model) -> NaiveEngine {
        if let Some(m) = model.as_any().downcast_ref::<RandomForestModel>() {
            NaiveEngine::Rf(m.clone())
        } else if let Some(m) = model.as_any().downcast_ref::<GradientBoostedTreesModel>() {
            NaiveEngine::Gbt(m.clone())
        } else if let Some(m) = model.as_any().downcast_ref::<LinearModel>() {
            NaiveEngine::Linear(m.clone())
        } else {
            NaiveEngine::Unsupported
        }
    }

    fn as_model(&self) -> &dyn Model {
        match self {
            NaiveEngine::Rf(m) => m,
            NaiveEngine::Gbt(m) => m,
            NaiveEngine::Linear(m) => m,
            NaiveEngine::Unsupported => {
                panic!("naive engine compiled from an unsupported model type")
            }
        }
    }
}

impl InferenceEngine for NaiveEngine {
    fn name(&self) -> String {
        let kind = match self {
            NaiveEngine::Rf(_) => "RandomForest",
            NaiveEngine::Gbt(_) => "GradientBoostedTrees",
            NaiveEngine::Linear(_) => "Linear",
            NaiveEngine::Unsupported => "Unsupported",
        };
        format!("{kind}Generic")
    }

    fn output_dim(&self) -> usize {
        self.as_model().num_classes().max(1)
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        self.as_model().predict_row(obs)
    }

    /// Columnar row loop: no `Observation` materialization, predictions
    /// written straight into the caller's buffer (the per-tree traversal
    /// itself stays Algorithm 1).
    fn predict_batch(&self, ds: &Dataset, rows: Range<usize>, out: &mut [f64]) {
        let dim = self.output_dim();
        debug_assert_eq!(out.len(), rows.len() * dim);
        let model = self.as_model();
        for (i, r) in rows.enumerate() {
            out[i * dim..(i + 1) * dim].copy_from_slice(&model.predict_ds_row(ds, r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::{Learner, RandomForestLearner};

    #[test]
    fn naive_matches_model() {
        let ds = synthetic::adult_like(100, 121);
        let mut cfg = crate::learner::random_forest::RandomForestConfig::new("income");
        cfg.num_trees = 4;
        cfg.compute_oob = false;
        let model = RandomForestLearner::new(cfg).train(&ds).unwrap();
        let engine = NaiveEngine::compile(model.as_ref());
        for r in [0usize, 7, 42] {
            assert_eq!(engine.predict_row(&ds.row(r)), model.predict_ds_row(&ds, r));
        }
    }
}
