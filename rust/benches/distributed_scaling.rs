//! §3.9: feature-parallel distributed training — exactness, per-worker
//! scaling and the network IO the delta-bit encoding would transfer.
//!
//! Run: cargo bench --bench distributed_scaling

use std::sync::atomic::Ordering;
use ydf::dataset::synthetic;
use ydf::distributed::{DistributedGbtLearner, InProcessBackend};
use ydf::learner::gbt::{EarlyStopping, GbtConfig};
use ydf::learner::{GradientBoostedTreesLearner, Learner};
use ydf::utils::bench::Table;

fn main() {
    let ds = synthetic::adult_like(3000, 20230806);
    let config = || {
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 10;
        cfg.max_depth = 5;
        cfg.validation_ratio = 0.0;
        cfg.early_stopping = EarlyStopping::None;
        cfg
    };
    let t0 = std::time::Instant::now();
    let reference = GradientBoostedTreesLearner::new(config()).train(&ds).unwrap();
    let single_secs = t0.elapsed().as_secs_f64();
    let reference_json = reference.to_json().to_string();

    let mut t = Table::new(&["workers", "train (s)", "exact", "net KiB", "messages"]);
    t.row(vec!["single".into(), format!("{single_secs:.2}"), "-".into(), "-".into(), "-".into()]);
    for workers in [1usize, 2, 4, 8] {
        let learner = DistributedGbtLearner::new(config(), workers, InProcessBackend);
        let t0 = std::time::Instant::now();
        let model = learner.train(&ds).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let exact = model.to_json().to_string() == reference_json;
        t.row(vec![
            workers.to_string(),
            format!("{secs:.2}"),
            exact.to_string(),
            format!("{:.1}", learner.net.bytes_sent.load(Ordering::Relaxed) as f64 / 1024.0),
            learner.net.messages.load(Ordering::Relaxed).to_string(),
        ]);
    }
    println!(
        "Distributed feature-parallel GBT (3000 examples; single-core testbed — workers \
         measure algorithmic overhead, not speedup)\n{}",
        t.render()
    );
}
