//! Chaos test for the serving control plane: injected scorer panics,
//! artificial flush latency and stalled/slow connections under concurrent
//! multi-model load, with a hot swap landing mid-traffic.
//!
//! The acceptance contract (`docs/serving.md`, "Control plane & failure
//! modes"):
//!
//! * every failure surfaces as an **in-band** `{"error": …}` reply — a
//!   fault never closes a healthy connection or takes the server down;
//! * a hot swap drops **zero** accepted requests (the old generation
//!   drains to `Retired`);
//! * a model untouched by the chaos keeps answering **bit-identically**
//!   to its offline `predict_block`;
//! * silent connections are reaped by the deadline, with one final
//!   in-band notice, and counted in `timed_out_conns`.

use super::batcher::BatcherConfig;
use super::faults::FaultPlan;
use super::registry::{Lifecycle, Registry};
use super::server::{serve_shared, ServerConfig};
use super::session::Session;
use crate::dataset::synthetic;
use crate::learner::gbt::GbtConfig;
use crate::learner::{GradientBoostedTreesLearner, Learner};
use crate::utils::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn session(seed: u64, trees: usize) -> Session {
    let ds = synthetic::adult_like(200, seed);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = trees;
    cfg.max_depth = 3;
    Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    let reader = BufReader::new(s.try_clone().unwrap());
                    return Client { reader, writer: s };
                }
                Err(e) => {
                    assert!(Instant::now() < deadline, "server never came up: {e}");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
    }

    /// One request line → one reply line, always — the wire contract
    /// this whole test leans on.
    fn rpc(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").expect("connection stays writable");
        self.writer.flush().unwrap();
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp).expect("connection stays readable");
        assert!(n > 0, "server closed the connection instead of replying in-band");
        Json::parse(resp.trim()).expect("every reply is one JSON line")
    }
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn chaos_panics_stalls_and_hot_swap_never_take_the_server_down() {
    // score_threads: 1 keeps flush scoring on each batcher's own scorer
    // thread — injected panics land exactly at the panic boundary under
    // test, and the test does not contend on a shared scoring pool.
    let registry = Arc::new(Registry::new(BatcherConfig {
        max_delay: Duration::from_millis(1),
        score_threads: 1,
        ..Default::default()
    }));
    registry.register("stable", session(11, 5)).unwrap();
    registry.register("volatile", session(22, 4)).unwrap();

    // Offline reference for the stable model over a fixed probe batch.
    let probe: Vec<String> = (0..8).map(|i| format!(r#"{{"age": {}}}"#, 18 + 6 * i)).collect();
    let stable_request = format!(r#"{{"model": "stable", "rows": [{}]}}"#, probe.join(", "));
    let stable_entry = registry.resolve(Some("stable")).unwrap();
    let dim = stable_entry.session().output_dim();
    let reference = {
        let mut block = stable_entry.session().new_block();
        for r in &probe {
            let row = Json::parse(r).unwrap();
            stable_entry.session().decode_row(&mut block, &row).unwrap();
        }
        stable_entry.session().predict_block(&mut block)
    };

    // Arm the chaos BEFORE traffic: the volatile model's next flushes
    // slow down then panic; the server stalls its first request lines.
    let old_volatile = registry.resolve(Some("volatile")).unwrap();
    let volatile_faults = Arc::clone(old_volatile.batcher().faults());
    volatile_faults.arm_flush_delay(2, 30);
    volatile_faults.arm_scorer_panics(3);
    let server_faults = Arc::new(FaultPlan::new());
    server_faults.arm_conn_stalls(2, 40);

    let probe_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe_listener.local_addr().unwrap();
    drop(probe_listener);
    let config = ServerConfig {
        addr: addr.to_string(),
        workers: 6,
        // Short deadline so the stalled-connection sub-case reaps fast;
        // live clients reply-turnaround far inside it.
        conn_timeout: Some(Duration::from_millis(300)),
        faults: Some(Arc::clone(&server_faults)),
    };
    let server_registry = Arc::clone(&registry);
    let server = std::thread::spawn(move || serve_shared(server_registry, &config));

    let stop = Arc::new(AtomicBool::new(false));
    let volatile_ok = Arc::new(AtomicUsize::new(0));
    let volatile_err = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|scope| {
        // Two clients hammer the untouched model: bit-identity on every
        // single reply, throughout panics, stalls and the swap.
        for client in 0..2usize {
            let (stop, stable_request, reference) = (Arc::clone(&stop), &stable_request, &reference);
            scope.spawn(move || {
                let mut c = Client::connect(addr);
                let mut req = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let resp = c.rpc(stable_request);
                    let preds = resp
                        .req_arr("predictions")
                        .unwrap_or_else(|e| panic!("client {client} req {req}: {e} in {resp}"));
                    assert_eq!(preds.len(), probe_len(reference, dim));
                    for (i, p) in preds.iter().enumerate() {
                        let got: Vec<f64> =
                            p.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
                        assert_eq!(
                            got.as_slice(),
                            &reference[i * dim..(i + 1) * dim],
                            "stable model drifted under chaos (client {client} req {req} row {i})"
                        );
                    }
                    req += 1;
                }
            });
        }
        // Two clients hammer the faulted model: replies are predictions
        // or in-band errors — never a dropped line, never a dead socket.
        for _ in 0..2usize {
            let (stop, ok, err) =
                (Arc::clone(&stop), Arc::clone(&volatile_ok), Arc::clone(&volatile_err));
            scope.spawn(move || {
                let mut c = Client::connect(addr);
                while !stop.load(Ordering::Relaxed) {
                    let resp = c.rpc(r#"{"model": "volatile", "rows": [{"age": 33}]}"#);
                    if resp.get("error").is_some() {
                        err.fetch_add(1, Ordering::Relaxed);
                    } else {
                        assert_eq!(resp.req_arr("predictions").unwrap().len(), 1);
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }

        // Stalled-connection sub-case: a client that never completes a
        // request line is reaped at the deadline with one in-band notice.
        let slow = Client::connect(addr);
        let mut slow_reader = slow.reader;
        let mut notice = String::new();
        slow_reader.read_line(&mut notice).expect("reaper sends a final line");
        assert!(notice.contains("timed out"), "unexpected reap notice: {notice:?}");
        drop(slow_reader);

        // The armed faults demonstrably fired, answered in-band.
        wait_until("injected connection stalls", || server_faults.fired_stalls() >= 2);
        wait_until("injected scorer panics", || {
            volatile_faults.fired_panics() >= 3 && volatile_err.load(Ordering::Relaxed) >= 1
        });
        wait_until("post-panic recovery of the volatile batcher", || {
            volatile_ok.load(Ordering::Relaxed) >= 1
        });

        // Hot swap mid-traffic: the volatile model is replaced while its
        // clients keep sending.
        let ok_before_swap = volatile_ok.load(Ordering::Relaxed);
        let generation = registry.swap("volatile", session(99, 6)).unwrap();
        assert!(generation > old_volatile.generation());
        wait_until("old generation drained to Retired", || {
            old_volatile.state() == Lifecycle::Retired
        });
        wait_until("clients served by the new generation", || {
            volatile_ok.load(Ordering::Relaxed) > ok_before_swap + 3
        });

        stop.store(true, Ordering::Relaxed);
    });

    // Post-chaos control-plane view, over the wire.
    let mut c = Client::connect(addr);
    let health = c.rpc(r#"{"cmd": "health"}"#);
    let states = health.req("states").unwrap();
    assert_eq!(states.req_str("stable").unwrap(), "Serving");
    assert_eq!(states.req_str("volatile").unwrap(), "Serving");
    let transitions = health.req("transitions").unwrap().to_string();
    assert!(transitions.contains("Retired"), "{transitions}");

    let stats = c.rpc(r#"{"cmd": "stats"}"#);
    assert!(stats.req_f64("timed_out_conns").unwrap() >= 1.0, "{stats}");
    assert_eq!(stats.req_f64("reloads").unwrap(), 1.0, "{stats}");
    assert!(stats.req_f64("errors").unwrap() >= 1.0, "{stats}");

    // The server still serves — bit-identically — and shuts down clean.
    let resp = c.rpc(&stable_request);
    let preds = resp.req_arr("predictions").unwrap();
    let got: Vec<f64> = preds[0].as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
    assert_eq!(got.as_slice(), &reference[..dim]);
    let bye = c.rpc(r#"{"cmd": "shutdown"}"#);
    assert_eq!(bye.get("ok"), Some(&Json::Bool(true)));
    server.join().unwrap().expect("server exits cleanly after the chaos");
}

/// Rows in the reference prediction vector.
fn probe_len(reference: &[f64], dim: usize) -> usize {
    reference.len() / dim
}
