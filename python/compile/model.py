"""L2 JAX compute graphs (build-time only; never on the request path).

Two graphs are AOT-compiled to HLO text for the Rust runtime:

* `forest_predict` — the accelerated GBT inference engine: the L1 Pallas
  traversal kernel plus score accumulation and the binomial link. Loaded
  by `rust/src/inference/pjrt.rs` as the `GradientBoostedTreesPjrtXla`
  engine (§3.7).
* `linear_train_step` / `linear_predict` — the "TF Linear" baseline's
  forward and SGD train step (fwd/bwd in one graph), demonstrating the
  full fwd+bwd lowering path.
"""

import jax
import jax.numpy as jnp

from compile.kernels import forest as forest_kernel


def forest_predict(features, node_feature, node_threshold, node_pos,
                   node_neg, leaf_value, initial):
    """Binary-GBT batched inference.

    Args:
      features:  f32[B, F] imputed (NaN-free) examples
      node_*:    padded forest tensors, see kernels.forest
      initial:   f32[1] initial log-odds
    Returns:
      (probs,): f32[B] positive-class probability.
    """
    per_tree = forest_kernel.forest_traverse(
        features, node_feature, node_threshold, node_pos, node_neg, leaf_value,
        depth=forest_kernel.MAX_DEPTH)
    scores = initial[0] + jnp.sum(per_tree, axis=0)
    return (jax.nn.sigmoid(scores),)


def linear_predict(x, w, b):
    """Multinomial logistic forward: softmax(x @ w + b).

    x: f32[B, D], w: f32[D, K], b: f32[K] -> (f32[B, K],)
    """
    return (jax.nn.softmax(x @ w + b, axis=-1),)


def _linear_loss(params, x, y_onehot):
    w, b = params
    logits = x @ w + b
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def linear_train_step(x, y_onehot, w, b, lr):
    """One SGD step on the cross-entropy loss (fwd + bwd in one graph).

    Returns (new_w, new_b, loss).
    """
    loss, grads = jax.value_and_grad(_linear_loss)((w, b), x, y_onehot)
    gw, gb = grads
    return (w - lr[0] * gw, b - lr[0] * gb, loss)
