//! Regenerates the paper artifact via the shared scaled suite.
//! Run: cargo bench --bench table_fig6_ranks

#[path = "suite_common/mod.rs"]
mod suite_common;

fn main() {
    let t0 = std::time::Instant::now();
    let result = suite_common::run();
    println!("{}", result.fig6_report());
    eprintln!("[suite] completed in {:.1}s", t0.elapsed().as_secs_f64());
}
