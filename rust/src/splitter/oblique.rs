//! Sparse oblique splits (Tomita et al. 2020, "Sparse Projection Oblique
//! Randomer Forests") — `split_axis: SPARSE_OBLIQUE` of the paper's
//! benchmark_rank1@v1 template (§3.11, Appendix C.1).
//!
//! Each candidate is a sparse ±1 projection over a random subset of the
//! numerical features, optionally normalized per node (MIN_MAX), scanned
//! exactly like a numerical feature. The normalization is folded into the
//! stored weights so inference needs no extra state.

use super::score::Labels;
use super::{
    scan_sorted_pairs, NodeScratch, ObliqueNormalization, SplitCandidate, SplitterConfig,
};
use crate::dataset::{ColumnData, Dataset};
use crate::model::tree::Condition;
use crate::utils::rng::Rng;

/// Finds the best sparse oblique split over `num_cols` numerical columns.
/// The projection buffer lives in the per-thread [`NodeScratch`] (its
/// reusable pair buffer), so repeated projections allocate nothing.
#[allow(clippy::too_many_arguments)]
pub fn split_oblique(
    ds: &Dataset,
    num_cols: &[usize],
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    num_projections_exponent: f64,
    normalization: ObliqueNormalization,
    scratch: &mut NodeScratch,
    rng: &mut Rng,
) -> Option<SplitCandidate> {
    let p = num_cols.len();
    if p == 0 || rows.len() < 2 * cfg.min_examples.max(1) {
        return None;
    }
    // num_projections = ceil(p ^ exponent), clamped (Tomita et al. §5;
    // exponent 1 in benchmark_rank1@v1).
    let num_projections = ((p as f64).powf(num_projections_exponent).ceil() as usize)
        .clamp(1, 200);

    let mut best: Option<SplitCandidate> = None;
    let projected = &mut scratch.pairs;
    for _ in 0..num_projections {
        // Sparse projection: expected 2-3 nonzero coordinates.
        let nnz = 1 + rng.uniform_usize(3.min(p));
        let mut attrs: Vec<usize> = rng
            .sample_without_replacement(p, nnz)
            .into_iter()
            .map(|i| num_cols[i])
            .collect();
        attrs.sort_unstable();
        // Raw ±1 weights, then fold in per-node normalization.
        let mut weights: Vec<f32> = (0..attrs.len())
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        match normalization {
            ObliqueNormalization::None => {}
            ObliqueNormalization::MinMax => {
                for (w, &a) in weights.iter_mut().zip(&attrs) {
                    let (lo, hi) = node_min_max(ds, a, rows);
                    let range = hi - lo;
                    if range > 1e-12 {
                        *w /= range;
                    }
                }
            }
            ObliqueNormalization::StandardDeviation => {
                for (w, &a) in weights.iter_mut().zip(&attrs) {
                    let std = node_std(ds, a, rows);
                    if std > 1e-12 {
                        *w /= std;
                    }
                }
            }
        }
        // Project. Missing coordinates contribute 0 (the same convention
        // Condition::Oblique uses at inference).
        projected.clear();
        for &r in rows {
            let mut acc = 0.0f32;
            for (&a, &w) in attrs.iter().zip(&weights) {
                if let ColumnData::Numerical(v) = &ds.columns[a] {
                    let x = v[r as usize];
                    if !x.is_nan() {
                        acc += w * x;
                    }
                }
            }
            projected.push((acc, r));
        }
        projected.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        if let Some(scan) = scan_sorted_pairs(projected, &[], labels, cfg.min_examples) {
            if scan.gain > best.as_ref().map(|b| b.gain).unwrap_or(0.0) {
                best = Some(SplitCandidate {
                    condition: Condition::Oblique {
                        attrs: attrs.clone(),
                        weights: weights.clone(),
                        threshold: scan.threshold,
                    },
                    gain: scan.gain,
                    missing_to_positive: scan.missing_to_positive,
                });
            }
        }
    }
    best
}

fn node_min_max(ds: &Dataset, col: usize, rows: &[u32]) -> (f32, f32) {
    let values = ds.columns[col].as_numerical().expect("numerical");
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &r in rows {
        let v = values[r as usize];
        if !v.is_nan() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if lo > hi {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

fn node_std(ds: &Dataset, col: usize, rows: &[u32]) -> f32 {
    let values = ds.columns[col].as_numerical().expect("numerical");
    let mut m = crate::utils::stats::Moments::new();
    for &r in rows {
        let v = values[r as usize];
        if !v.is_nan() {
            m.add(v as f64);
        }
    }
    m.std() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::{ColumnSpec, DataSpec};

    fn two_col_ds(x0: Vec<f32>, x1: Vec<f32>) -> Dataset {
        let spec = DataSpec {
            columns: vec![ColumnSpec::numerical("x0"), ColumnSpec::numerical("x1")],
        };
        Dataset::new(spec, vec![ColumnData::Numerical(x0), ColumnData::Numerical(x1)])
            .unwrap()
    }

    #[test]
    fn oblique_separates_diagonal_boundary() {
        // Class = (x0 + x1 > 0): axis-aligned needs depth, oblique one cut.
        let mut rng = Rng::seed_from_u64(11);
        let n = 200;
        let x0: Vec<f32> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        let x1: Vec<f32> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        let labels_data: Vec<u32> =
            x0.iter().zip(&x1).map(|(&a, &b)| (a + b > 0.0) as u32).collect();
        let ds = two_col_ds(x0, x1);
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..n as u32).collect();
        let cfg = SplitterConfig { min_examples: 5, ..Default::default() };
        let mut scratch = NodeScratch::new(ds.num_rows());
        let cand = split_oblique(
            &ds,
            &[0, 1],
            &rows,
            &labels,
            &cfg,
            2.0, // enough projections to find the diagonal
            ObliqueNormalization::MinMax,
            &mut scratch,
            &mut Rng::seed_from_u64(3),
        )
        .unwrap();
        // The perfect diagonal yields near-total gain: n*ln2 is the max.
        assert!(
            cand.gain > 0.5 * n as f64 * std::f64::consts::LN_2,
            "gain {}",
            cand.gain
        );
        match &cand.condition {
            Condition::Oblique { attrs, weights, .. } => {
                assert!(!attrs.is_empty());
                assert_eq!(attrs.len(), weights.len());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn min_max_stats() {
        let ds = two_col_ds(vec![1.0, 5.0, f32::NAN, 3.0], vec![0.0; 4]);
        let rows: Vec<u32> = (0..4).collect();
        assert_eq!(node_min_max(&ds, 0, &rows), (1.0, 5.0));
        assert!(node_std(&ds, 0, &rows) > 0.0);
    }

    #[test]
    fn empty_feature_list_yields_none() {
        let ds = two_col_ds(vec![1.0, 2.0], vec![3.0, 4.0]);
        let labels_data = vec![0u32, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let cfg = SplitterConfig::default();
        let mut scratch = NodeScratch::new(ds.num_rows());
        assert!(split_oblique(
            &ds,
            &[],
            &[0, 1],
            &labels,
            &cfg,
            1.0,
            ObliqueNormalization::None,
            &mut scratch,
            &mut Rng::seed_from_u64(1)
        )
        .is_none());
    }
}
