//! Dataset layer: feature semantics, dataspec, column-wise storage, CSV
//! readers/writers and the synthetic benchmark suite.
//!
//! YDF stores training data column-wise ("vertical dataset"): splitters scan
//! one feature across all examples, so column-major layout is the natural
//! cache-friendly representation (§3.5 READERS, §3.8 SPLITTERS).

pub mod csv;
pub mod dataspec;
pub mod synthetic;

pub use dataspec::{ColumnSpec, DataSpec, FeatureSemantic};

use crate::utils::rng::Rng;

/// Missing-value sentinel for categorical columns.
pub const MISSING_CAT: u32 = u32::MAX;
/// Missing-value sentinel for boolean columns.
pub const MISSING_BOOL: u8 = 2;

/// Typed column storage. Numerical missing values are `f32::NAN`.
#[derive(Clone, Debug)]
pub enum ColumnData {
    /// Continuous or discrete values with order and scale (§3.4).
    Numerical(Vec<f32>),
    /// Dictionary-encoded categories; `MISSING_CAT` = missing.
    Categorical(Vec<u32>),
    /// 0/1 with `MISSING_BOOL` = missing.
    Boolean(Vec<u8>),
    /// Ragged sets of categories (categorical-set semantic, used for
    /// tokenized text). `offsets.len() == rows + 1`.
    CategoricalSet { offsets: Vec<u32>, values: Vec<u32> },
}

impl ColumnData {
    pub fn len(&self) -> usize {
        match self {
            ColumnData::Numerical(v) => v.len(),
            ColumnData::Categorical(v) => v.len(),
            ColumnData::Boolean(v) => v.len(),
            ColumnData::CategoricalSet { offsets, .. } => offsets.len().saturating_sub(1),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn semantic(&self) -> FeatureSemantic {
        match self {
            ColumnData::Numerical(_) => FeatureSemantic::Numerical,
            ColumnData::Categorical(_) => FeatureSemantic::Categorical,
            ColumnData::Boolean(_) => FeatureSemantic::Boolean,
            ColumnData::CategoricalSet { .. } => FeatureSemantic::CategoricalSet,
        }
    }

    pub fn as_numerical(&self) -> Option<&[f32]> {
        match self {
            ColumnData::Numerical(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_categorical(&self) -> Option<&[u32]> {
        match self {
            ColumnData::Categorical(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_boolean(&self) -> Option<&[u8]> {
        match self {
            ColumnData::Boolean(v) => Some(v),
            _ => None,
        }
    }

    /// Set values of row `i` for categorical-set columns.
    pub fn set_values(&self, i: usize) -> Option<&[u32]> {
        match self {
            ColumnData::CategoricalSet { offsets, values } => {
                Some(&values[offsets[i] as usize..offsets[i + 1] as usize])
            }
            _ => None,
        }
    }

    /// Appends every row of `other` to this column. Both columns must have
    /// the same semantic. Used by the serving batcher to coalesce decoded
    /// request blocks into one scoring block without re-decoding.
    pub fn extend_from(&mut self, other: &ColumnData) -> Result<(), String> {
        match (self, other) {
            (ColumnData::Numerical(a), ColumnData::Numerical(b)) => a.extend_from_slice(b),
            (ColumnData::Categorical(a), ColumnData::Categorical(b)) => a.extend_from_slice(b),
            (ColumnData::Boolean(a), ColumnData::Boolean(b)) => a.extend_from_slice(b),
            (
                ColumnData::CategoricalSet { offsets, values },
                ColumnData::CategoricalSet { offsets: o2, values: v2 },
            ) => {
                let base = values.len() as u32;
                values.extend_from_slice(v2);
                offsets.extend(o2.iter().skip(1).map(|&w| base + w));
            }
            (a, b) => {
                return Err(format!(
                    "cannot append a {:?} column to a {:?} column",
                    b.semantic(),
                    a.semantic()
                ))
            }
        }
        Ok(())
    }

    /// Appends rows `start..end` of `other` to this column. Both columns
    /// must have the same semantic. Used by the serving batcher's
    /// deadline-shed pass to re-pack the surviving rows of a flush into a
    /// fresh block without re-decoding the original requests.
    pub fn extend_from_range(
        &mut self,
        other: &ColumnData,
        start: usize,
        end: usize,
    ) -> Result<(), String> {
        match (self, other) {
            (ColumnData::Numerical(a), ColumnData::Numerical(b)) => {
                a.extend_from_slice(&b[start..end])
            }
            (ColumnData::Categorical(a), ColumnData::Categorical(b)) => {
                a.extend_from_slice(&b[start..end])
            }
            (ColumnData::Boolean(a), ColumnData::Boolean(b)) => a.extend_from_slice(&b[start..end]),
            (
                ColumnData::CategoricalSet { offsets, values },
                ColumnData::CategoricalSet { offsets: o2, values: v2 },
            ) => {
                // Row r of `other` spans values o2[r]..o2[r+1]; rebase that
                // window onto the end of this column's value buffer.
                let base = values.len() as u32;
                let shift = o2[start];
                values.extend_from_slice(&v2[o2[start] as usize..o2[end] as usize]);
                offsets.extend(o2[start + 1..=end].iter().map(|&w| base + (w - shift)));
            }
            (a, b) => {
                return Err(format!(
                    "cannot append a {:?} column to a {:?} column",
                    b.semantic(),
                    a.semantic()
                ))
            }
        }
        Ok(())
    }

    /// Removes all rows, keeping the allocation (serving decode scratch).
    pub fn clear(&mut self) {
        match self {
            ColumnData::Numerical(v) => v.clear(),
            ColumnData::Categorical(v) => v.clear(),
            ColumnData::Boolean(v) => v.clear(),
            ColumnData::CategoricalSet { offsets, values } => {
                values.clear();
                offsets.clear();
                offsets.push(0);
            }
        }
    }

    pub fn is_missing(&self, i: usize) -> bool {
        match self {
            ColumnData::Numerical(v) => v[i].is_nan(),
            ColumnData::Categorical(v) => v[i] == MISSING_CAT,
            ColumnData::Boolean(v) => v[i] == MISSING_BOOL,
            // A missing set is encoded as a sentinel single-element set
            // containing MISSING_CAT (semantically different from empty,
            // as the paper stresses in §3.4).
            ColumnData::CategoricalSet { .. } => {
                self.set_values(i).map(|s| s == [MISSING_CAT]).unwrap_or(false)
            }
        }
    }
}

/// A single attribute value, used for row-wise inference input.
#[derive(Clone, Debug, PartialEq)]
pub enum AttrValue {
    Num(f32),
    Cat(u32),
    Bool(bool),
    CatSet(Vec<u32>),
    Missing,
}

/// One observation in row form (an "example" minus the label, §3.1).
pub type Observation = Vec<AttrValue>;

/// Column-wise dataset: the training-side container.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub spec: DataSpec,
    pub columns: Vec<ColumnData>,
    num_rows: usize,
}

impl Dataset {
    pub fn new(spec: DataSpec, columns: Vec<ColumnData>) -> Result<Dataset, String> {
        if spec.columns.len() != columns.len() {
            return Err(format!(
                "dataspec declares {} columns but {} columns of data were provided. \
                 Re-run dataspec inference (`infer_dataspec`) on this dataset or pass a \
                 matching dataspec.",
                spec.columns.len(),
                columns.len()
            ));
        }
        let num_rows = columns.first().map(|c| c.len()).unwrap_or(0);
        for (i, c) in columns.iter().enumerate() {
            if c.len() != num_rows {
                return Err(format!(
                    "column '{}' has {} values but the first column has {}. All columns \
                     must have the same number of rows.",
                    spec.columns[i].name,
                    c.len(),
                    num_rows
                ));
            }
            if c.semantic() != spec.columns[i].semantic {
                return Err(format!(
                    "column '{}' is stored as {:?} but the dataspec declares {:?}.",
                    spec.columns[i].name,
                    c.semantic(),
                    spec.columns[i].semantic
                ));
            }
        }
        Ok(Dataset { spec, columns, num_rows })
    }

    pub fn num_rows(&self) -> usize {
        self.num_rows
    }

    /// Re-derives the cached row count after callers mutate `columns` in
    /// place (the serving layer reuses one `Dataset` as columnar decode
    /// scratch across requests). Errors if the columns disagree on length.
    pub fn sync_num_rows(&mut self) -> Result<usize, String> {
        let n = self.columns.first().map(|c| c.len()).unwrap_or(0);
        for (i, c) in self.columns.iter().enumerate() {
            if c.len() != n {
                return Err(format!(
                    "column '{}' has {} rows but the first column has {n} after in-place \
                     mutation; every column must receive one value per decoded row.",
                    self.spec.columns[i].name,
                    c.len()
                ));
            }
        }
        self.num_rows = n;
        Ok(n)
    }

    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    pub fn column_index(&self, name: &str) -> Option<usize> {
        self.spec.columns.iter().position(|c| c.name == name)
    }

    pub fn column(&self, idx: usize) -> &ColumnData {
        &self.columns[idx]
    }

    /// Extracts row `i` as an observation (all columns; callers mask the
    /// label themselves).
    pub fn row(&self, i: usize) -> Observation {
        self.columns
            .iter()
            .map(|c| {
                if c.is_missing(i) {
                    AttrValue::Missing
                } else {
                    match c {
                        ColumnData::Numerical(v) => AttrValue::Num(v[i]),
                        ColumnData::Categorical(v) => AttrValue::Cat(v[i]),
                        ColumnData::Boolean(v) => AttrValue::Bool(v[i] == 1),
                        ColumnData::CategoricalSet { .. } => {
                            AttrValue::CatSet(c.set_values(i).unwrap().to_vec())
                        }
                    }
                }
            })
            .collect()
    }

    /// Returns a new dataset containing the given rows (duplicates allowed:
    /// used by bootstrap and fold extraction).
    pub fn subset(&self, rows: &[usize]) -> Dataset {
        let columns = self
            .columns
            .iter()
            .map(|c| match c {
                ColumnData::Numerical(v) => {
                    ColumnData::Numerical(rows.iter().map(|&r| v[r]).collect())
                }
                ColumnData::Categorical(v) => {
                    ColumnData::Categorical(rows.iter().map(|&r| v[r]).collect())
                }
                ColumnData::Boolean(v) => {
                    ColumnData::Boolean(rows.iter().map(|&r| v[r]).collect())
                }
                ColumnData::CategoricalSet { .. } => {
                    let mut offsets = Vec::with_capacity(rows.len() + 1);
                    let mut values = Vec::new();
                    offsets.push(0u32);
                    for &r in rows {
                        values.extend_from_slice(c.set_values(r).unwrap());
                        offsets.push(values.len() as u32);
                    }
                    ColumnData::CategoricalSet { offsets, values }
                }
            })
            .collect();
        Dataset { spec: self.spec.clone(), columns, num_rows: rows.len() }
    }

    /// Deterministic K-fold split: returns `folds` lists of row indices.
    /// Fold assignments depend only on the seed so fold splits are
    /// "consistent across learners" as required by the protocol (§5.2).
    pub fn kfold_indices(&self, folds: usize, seed: u64) -> Vec<Vec<usize>> {
        let mut idx: Vec<usize> = (0..self.num_rows).collect();
        let mut rng = Rng::seed_from_u64(seed);
        rng.shuffle(&mut idx);
        let mut out = vec![Vec::new(); folds];
        for (i, r) in idx.into_iter().enumerate() {
            out[i % folds].push(r);
        }
        out
    }

    /// Train/valid split (used for GBT early stopping when no validation
    /// dataset is given — §3.3: learners extract it themselves).
    pub fn train_valid_split(&self, valid_ratio: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
        let mut idx: Vec<usize> = (0..self.num_rows).collect();
        let mut rng = Rng::seed_from_u64(seed);
        rng.shuffle(&mut idx);
        let n_valid = ((self.num_rows as f64) * valid_ratio).round() as usize;
        let n_valid = n_valid.clamp(1.min(self.num_rows), self.num_rows.saturating_sub(1));
        let valid = idx[..n_valid].to_vec();
        let train = idx[n_valid..].to_vec();
        (train, valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::{ColumnSpec, DataSpec};

    fn tiny() -> Dataset {
        let spec = DataSpec {
            columns: vec![
                ColumnSpec::numerical("x"),
                ColumnSpec::categorical("c", vec!["a".into(), "b".into()]),
            ],
        };
        Dataset::new(
            spec,
            vec![
                ColumnData::Numerical(vec![1.0, f32::NAN, 3.0, 4.0]),
                ColumnData::Categorical(vec![0, 1, MISSING_CAT, 0]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn construction_and_access() {
        let d = tiny();
        assert_eq!(d.num_rows(), 4);
        assert_eq!(d.num_columns(), 2);
        assert!(d.column(0).is_missing(1));
        assert!(d.column(1).is_missing(2));
        assert_eq!(d.column_index("c"), Some(1));
    }

    #[test]
    fn row_extraction() {
        let d = tiny();
        let r = d.row(0);
        assert_eq!(r[0], AttrValue::Num(1.0));
        assert_eq!(r[1], AttrValue::Cat(0));
        let r1 = d.row(1);
        assert_eq!(r1[0], AttrValue::Missing);
    }

    #[test]
    fn subset_with_duplicates() {
        let d = tiny();
        let s = d.subset(&[3, 3, 0]);
        assert_eq!(s.num_rows(), 3);
        assert_eq!(s.column(0).as_numerical().unwrap(), &[4.0, 4.0, 1.0]);
    }

    #[test]
    fn mismatched_columns_rejected() {
        let spec = DataSpec { columns: vec![ColumnSpec::numerical("x")] };
        let err = Dataset::new(
            spec,
            vec![
                ColumnData::Numerical(vec![1.0]),
                ColumnData::Numerical(vec![2.0]),
            ],
        )
        .unwrap_err();
        assert!(err.contains("dataspec declares 1 columns"), "{err}");
    }

    #[test]
    fn kfold_partitions_all_rows() {
        let d = tiny();
        let folds = d.kfold_indices(2, 13);
        let mut all: Vec<usize> = folds.concat();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3]);
        // Deterministic.
        assert_eq!(folds, d.kfold_indices(2, 13));
    }

    #[test]
    fn train_valid_split_covers() {
        let d = tiny();
        let (tr, va) = d.train_valid_split(0.25, 3);
        assert_eq!(tr.len() + va.len(), 4);
        assert!(!va.is_empty());
    }

    #[test]
    fn extend_from_appends_and_clear_resets() {
        let mut a = ColumnData::Numerical(vec![1.0, 2.0]);
        a.extend_from(&ColumnData::Numerical(vec![3.0])).unwrap();
        assert_eq!(a.as_numerical().unwrap(), &[1.0, 2.0, 3.0]);
        a.clear();
        assert_eq!(a.len(), 0);

        let mut s = ColumnData::CategoricalSet { offsets: vec![0, 2], values: vec![5, 6] };
        let other = ColumnData::CategoricalSet { offsets: vec![0, 1, 1], values: vec![7] };
        s.extend_from(&other).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.set_values(0).unwrap(), &[5, 6]);
        assert_eq!(s.set_values(1).unwrap(), &[7]);
        assert_eq!(s.set_values(2).unwrap(), &[] as &[u32]);
        s.clear();
        assert_eq!(s.len(), 0); // offsets reset to [0]

        let mut b = ColumnData::Boolean(vec![1]);
        let err = b.extend_from(&ColumnData::Numerical(vec![0.0])).unwrap_err();
        assert!(err.contains("cannot append"), "{err}");
    }

    #[test]
    fn extend_from_range_slices_and_rebases() {
        let mut a = ColumnData::Numerical(vec![1.0]);
        a.extend_from_range(&ColumnData::Numerical(vec![10.0, 11.0, 12.0, 13.0]), 1, 3).unwrap();
        assert_eq!(a.as_numerical().unwrap(), &[1.0, 11.0, 12.0]);

        // CategoricalSet rows: [5,6] | [7] | [] | [MISSING]; take rows 1..3.
        let src = ColumnData::CategoricalSet {
            offsets: vec![0, 2, 3, 3, 4],
            values: vec![5, 6, 7, MISSING_CAT],
        };
        let mut s = ColumnData::CategoricalSet { offsets: vec![0, 1], values: vec![4] };
        s.extend_from_range(&src, 1, 3).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.set_values(0).unwrap(), &[4]);
        assert_eq!(s.set_values(1).unwrap(), &[7]);
        assert_eq!(s.set_values(2).unwrap(), &[] as &[u32]);
        // The missing-sentinel row survives a ranged copy too.
        s.extend_from_range(&src, 3, 4).unwrap();
        assert!(s.is_missing(3));

        // Empty range is a no-op.
        let before = s.len();
        s.extend_from_range(&src, 2, 2).unwrap();
        assert_eq!(s.len(), before);

        let mut b = ColumnData::Boolean(vec![1]);
        let err = b.extend_from_range(&ColumnData::Numerical(vec![0.0]), 0, 1).unwrap_err();
        assert!(err.contains("cannot append"), "{err}");
    }

    #[test]
    fn sync_num_rows_tracks_mutation() {
        let mut d = tiny();
        assert_eq!(d.num_rows(), 4);
        for c in &mut d.columns {
            c.clear();
        }
        assert_eq!(d.sync_num_rows().unwrap(), 0);
        assert_eq!(d.num_rows(), 0);
        // Uneven columns are rejected with the column name.
        if let ColumnData::Numerical(v) = &mut d.columns[0] {
            v.push(1.0);
        }
        let err = d.sync_num_rows().unwrap_err();
        assert!(err.contains('x') || err.contains('c'), "{err}");
    }

    #[test]
    fn catset_missing_vs_empty() {
        let spec = DataSpec {
            columns: vec![ColumnSpec::catset("s", vec!["t1".into(), "t2".into()])],
        };
        let d = Dataset::new(
            spec,
            vec![ColumnData::CategoricalSet {
                offsets: vec![0, 2, 2, 3],
                values: vec![0, 1, MISSING_CAT],
            }],
        )
        .unwrap();
        assert!(!d.column(0).is_missing(0));
        assert!(!d.column(0).is_missing(1)); // empty set is NOT missing
        assert!(d.column(0).is_missing(2)); // sentinel set IS missing
        assert_eq!(d.column(0).set_values(1).unwrap(), &[] as &[u32]);
    }
}
