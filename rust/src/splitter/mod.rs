//! SPLITTERS (§3.8): algorithms that find the best split condition for a
//! node. Organized as the paper describes (§2.3): one module per feature
//! type (numerical, categorical, boolean, categorical-set, oblique), all
//! generic over the label type through [`score::Labels`].
//!
//! Numerical splitters are *exact* by default (no discretization), like
//! XGBoost; the histogram splitter provides LightGBM-style approximate
//! splitting. `Auto` picks in-sorting vs pre-sorting per node, the dynamic
//! choice §2.3 credits to the modular design.
//!
//! ## Training-state layering (PR 5)
//!
//! The split search is structured for concurrency and zero per-node
//! allocation (§3.10's work division across features):
//!
//! * [`ColumnIndex`] — shared, read-only after construction: global
//!   per-feature sort orders and histogram binnings, built lazily behind
//!   `OnceLock`s so concurrent searchers (RF tree threads, the feature
//!   pool) build each column at most once.
//! * [`NodeScratch`] — per-thread mutable scratch: epoch-stamped node
//!   membership, reusable `(value, row)` / missing-row buffers and pooled
//!   [`score::ScoreAcc`] histograms. Splitters take
//!   `(&ColumnIndex, &mut NodeScratch)` instead of one exclusive cache.
//! * [`RowArena`] — one `Vec<u32>` per tree, partitioned in place (stable
//!   pass); nodes hold `(start, len)` spans, so growing a tree performs no
//!   per-node row-set allocation.
//! * [`SplitEngine`] — bundles an `Arc<ColumnIndex>`, a
//!   [`crate::utils::pool::WorkerPool`] and one `NodeScratch` per worker;
//!   [`SplitEngine::find_best_split`] fans candidate features out across
//!   the pool. Results are bit-identical to the sequential
//!   [`find_best_split`]: candidates are scored independently (randomized
//!   splitters get per-candidate seeds derived from one node seed) and
//!   reduced with the deterministic `(gain, lowest feature index)`
//!   tie-break.

pub mod categorical;
pub mod numerical;
pub mod oblique;
pub mod score;

use crate::dataset::{ColumnData, Dataset, FeatureSemantic};
use crate::model::tree::Condition;
use crate::utils::pool::WorkerPool;
use crate::utils::rng::{splitmix64, Rng};
use score::{Labels, ScoreAcc};
use std::sync::{Arc, OnceLock};

/// A proposed split.
#[derive(Clone, Debug)]
pub struct SplitCandidate {
    pub condition: Condition,
    pub gain: f64,
    pub missing_to_positive: bool,
}

/// Numerical splitter selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NumericalSplit {
    /// Sort the node's values at each node (simple, good for deep trees).
    ExactInSort,
    /// Reuse a global per-feature sort (good for top/shallow nodes).
    Presorted,
    /// Per-node dynamic choice between the two (§2.3).
    Auto,
    /// LightGBM-style quantile histogram (approximate, fast).
    Histogram { bins: usize },
}

/// Categorical splitter selection (§3.8).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CategoricalSplit {
    /// Exact one-vs-rest ordering trick (Fisher/Breiman; LightGBM-like).
    Cart,
    /// Random set sampling (Breiman's random projections).
    Random { trials: usize },
    /// One category vs rest (XGBoost/scikit-learn one-hot emulation).
    OneHot,
}

/// Axis handling for numerical features.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SplitAxis {
    AxisAligned,
    /// Sparse oblique projections (Tomita et al.; benchmark_rank1@v1).
    SparseOblique { num_projections_exponent: f64, normalization: ObliqueNormalization },
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ObliqueNormalization {
    None,
    /// Weights scaled by 1/(max-min) of the node (benchmark hp default).
    MinMax,
    /// Weights scaled by 1/std of the node.
    StandardDeviation,
}

/// Splitter configuration, shared by all tree learners.
#[derive(Clone, Debug)]
pub struct SplitterConfig {
    pub numerical: NumericalSplit,
    pub categorical: CategoricalSplit,
    pub axis: SplitAxis,
    pub min_examples: usize,
}

impl Default for SplitterConfig {
    fn default() -> Self {
        SplitterConfig {
            numerical: NumericalSplit::ExactInSort,
            categorical: CategoricalSplit::Cart,
            axis: SplitAxis::AxisAligned,
            min_examples: 5,
        }
    }
}

// ---------------------------------------------------------------------------
// ColumnIndex: shared, read-only per-feature structures.
// ---------------------------------------------------------------------------

/// Global per-feature training structures, built once per learner and
/// shared (read-only) by every tree and every split-search thread: the
/// pre-sorted row order and the quantile-histogram binning of each
/// numerical column. Construction is lazy — each slot is a `OnceLock`
/// filled on first use, so columns the splitter configuration never
/// touches cost nothing, and concurrent first uses build exactly once.
pub struct ColumnIndex {
    /// Per column: rows sorted by value, missing rows excluded.
    sorted: Vec<OnceLock<Vec<u32>>>,
    /// Per column: (bin upper edges, per-row bin index). The bin count is
    /// captured on first use (one binning per column per index — the bin
    /// count is a per-learner constant).
    binned: Vec<OnceLock<(Vec<f32>, Vec<u16>)>>,
    num_rows: usize,
}

impl ColumnIndex {
    pub fn new(ds: &Dataset) -> ColumnIndex {
        ColumnIndex {
            sorted: (0..ds.num_columns()).map(|_| OnceLock::new()).collect(),
            binned: (0..ds.num_columns()).map(|_| OnceLock::new()).collect(),
            num_rows: ds.num_rows(),
        }
    }

    pub fn num_rows(&self) -> usize {
        self.num_rows
    }

    /// The global sort order of a numerical column (built on first use).
    pub fn sorted_order(&self, ds: &Dataset, col: usize) -> &[u32] {
        self.sorted[col].get_or_init(|| {
            let values = ds.columns[col].as_numerical().expect("presort on non-numerical");
            let mut idx: Vec<u32> =
                (0..values.len() as u32).filter(|&r| !values[r as usize].is_nan()).collect();
            idx.sort_by(|&a, &b| {
                values[a as usize].partial_cmp(&values[b as usize]).unwrap()
            });
            idx
        })
    }

    /// The quantile binning (bin upper edges, per-row bin index) of a
    /// numerical column (built on first use with `bins` buckets).
    pub fn binned_column(&self, ds: &Dataset, col: usize, bins: usize) -> (&[f32], &[u16]) {
        let b = self.binned[col].get_or_init(|| {
            let values = ds.columns[col].as_numerical().expect("binning non-numerical");
            let mut sorted: Vec<f32> =
                values.iter().copied().filter(|v| !v.is_nan()).collect();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut edges = Vec::with_capacity(bins);
            if !sorted.is_empty() {
                for b in 1..bins {
                    let pos = b * (sorted.len() - 1) / bins;
                    let e = sorted[pos];
                    if edges.last().map(|&l| e > l).unwrap_or(true) {
                        edges.push(e);
                    }
                }
            }
            // Edge semantics: bin i = values <= edges[i]; last bin = rest.
            let bin_of = |v: f32| -> u16 {
                match edges.binary_search_by(|e| e.partial_cmp(&v).unwrap()) {
                    Ok(i) => i as u16,
                    Err(i) => i as u16,
                }
            };
            let assigned: Vec<u16> = values
                .iter()
                .map(|&v| if v.is_nan() { u16::MAX } else { bin_of(v) })
                .collect();
            (edges, assigned)
        });
        (b.0.as_slice(), b.1.as_slice())
    }
}

// ---------------------------------------------------------------------------
// NodeScratch: per-thread reusable buffers.
// ---------------------------------------------------------------------------

/// Per-thread split-search scratch. Buffers grow to the largest node seen
/// and are reused for every subsequent candidate, so the steady-state
/// split search allocates nothing. One `NodeScratch` must not be shared
/// across concurrent searches; [`SplitEngine`] owns one per worker.
pub struct NodeScratch {
    /// Node membership stamp per row (epoch-stamped to avoid clearing).
    member_epoch: Vec<u32>,
    epoch: u32,
    /// Reusable (value, row) pairs of the numerical splitters and the
    /// oblique projection buffer.
    pub(crate) pairs: Vec<(f32, u32)>,
    /// Reusable missing-row buffer of the numerical splitters.
    pub(crate) missing: Vec<u32>,
    /// Pooled per-bin accumulators of the histogram splitter.
    pub(crate) bin_accs: Vec<ScoreAcc>,
    pub(crate) bin_counts: Vec<usize>,
    /// Pooled suffix accumulators (`suffix[b]` = union of bins `b..`).
    pub(crate) suffix_accs: Vec<ScoreAcc>,
}

impl NodeScratch {
    pub fn new(num_rows: usize) -> NodeScratch {
        NodeScratch {
            member_epoch: vec![0; num_rows],
            epoch: 0,
            pairs: Vec::new(),
            missing: Vec::new(),
            bin_accs: Vec::new(),
            bin_counts: Vec::new(),
            suffix_accs: Vec::new(),
        }
    }

    /// Marks `rows` as the current node; returns the epoch token and the
    /// number of *distinct* rows stamped (fewer than `rows.len()` exactly
    /// when `rows` contains bootstrap duplicates, which the membership
    /// stamps cannot express).
    pub(crate) fn mark_members(&mut self, rows: &[u32]) -> (u32, usize) {
        self.epoch += 1;
        let mut distinct = 0usize;
        for &r in rows {
            if self.member_epoch[r as usize] != self.epoch {
                self.member_epoch[r as usize] = self.epoch;
                distinct += 1;
            }
        }
        (self.epoch, distinct)
    }

    /// Borrow the membership stamps alongside the pair buffer (disjoint
    /// fields; the presorted splitter filters the global order through the
    /// stamps while pushing into the reusable pair buffer).
    #[inline]
    pub(crate) fn members_and_pairs(
        &mut self,
    ) -> (&[u32], &mut Vec<(f32, u32)>, &mut Vec<u32>) {
        (&self.member_epoch, &mut self.pairs, &mut self.missing)
    }

    /// Prepares the pooled histogram accumulators: the first `num_bins`
    /// bin accumulators (+ counts) and `num_bins + 1` suffix accumulators
    /// are zeroed and type-checked against the label view. The pools keep
    /// their high-water-mark length — columns have different deduped bin
    /// counts, and shrinking to fit would reallocate on nearly every
    /// candidate; callers must index only `[..num_bins]`.
    pub(crate) fn ensure_bins(&mut self, labels: &Labels, num_bins: usize) {
        let prepare = |accs: &mut Vec<ScoreAcc>, want: usize| {
            if accs.first().map(|a| !a.compatible(labels)).unwrap_or(false) {
                accs.clear();
            }
            for a in accs.iter_mut().take(want) {
                a.reset();
            }
            while accs.len() < want {
                accs.push(labels.new_acc());
            }
        };
        prepare(&mut self.bin_accs, num_bins);
        prepare(&mut self.suffix_accs, num_bins + 1);
        self.bin_counts.clear();
        self.bin_counts.resize(num_bins, 0);
    }
}

// ---------------------------------------------------------------------------
// RowArena: per-tree row storage, partitioned in place.
// ---------------------------------------------------------------------------

/// The row set of one growing tree, partitioned in place. Nodes address
/// their examples as `(start, len)` spans of the arena instead of owning
/// `Vec<u32>`s, which removes the two fresh vectors `partition_rows`
/// allocated per node (LightGBM keeps its `data_indices` the same way).
/// The `scratch` buffer makes the partition stable — both sides keep the
/// original relative row order, matching [`partition_rows`] exactly —
/// and is reused across nodes and trees.
#[derive(Default)]
pub struct RowArena {
    rows: Vec<u32>,
    scratch: Vec<u32>,
}

impl RowArena {
    pub fn new() -> RowArena {
        RowArena::default()
    }

    /// Loads a tree's row set (bootstrap duplicates allowed), reusing the
    /// arena's storage.
    pub fn reset(&mut self, rows: &[u32]) {
        self.rows.clear();
        self.rows.extend_from_slice(rows);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The rows of a node span.
    pub fn span(&self, start: usize, len: usize) -> &[u32] {
        &self.rows[start..start + len]
    }

    /// Partitions the span `[start, start+len)` in place by `condition`
    /// (missing values follow `missing_to_positive`): positives first,
    /// then negatives, both in their original relative order (stable).
    /// Returns the number of positive rows. Other spans are untouched, so
    /// disjoint open leaves (best-first growth) stay valid.
    pub fn partition_span(
        &mut self,
        ds: &Dataset,
        condition: &Condition,
        missing_to_positive: bool,
        start: usize,
        len: usize,
    ) -> usize {
        let span = &mut self.rows[start..start + len];
        self.scratch.clear();
        let mut n_pos = 0usize;
        for i in 0..span.len() {
            let r = span[i];
            let goes_pos =
                condition.evaluate_ds(ds, r as usize).unwrap_or(missing_to_positive);
            if goes_pos {
                span[n_pos] = r;
                n_pos += 1;
            } else {
                self.scratch.push(r);
            }
        }
        span[n_pos..].copy_from_slice(&self.scratch);
        n_pos
    }
}

// ---------------------------------------------------------------------------
// Deterministic candidate scoring and reduction.
// ---------------------------------------------------------------------------

/// The tie-break key of a candidate: the lowest attribute index of its
/// condition (conditions store attributes sorted; no allocation).
fn candidate_key(c: &SplitCandidate) -> usize {
    c.condition.first_attribute().unwrap_or(usize::MAX)
}

/// `(gain, lowest feature index)` ordering: higher gain wins; exact gain
/// ties break toward the smaller attribute index. This makes the split
/// choice independent of candidate scan order, which is what lets the
/// parallel search ([`SplitEngine`]), the sequential search and the
/// distributed leader reduction all pick the same split. (The seed's
/// `c.gain > b.gain` kept whichever tied feature was scanned first.)
pub fn better_candidate(c: &SplitCandidate, best: &SplitCandidate) -> bool {
    c.gain > best.gain || (c.gain == best.gain && candidate_key(c) < candidate_key(best))
}

/// Folds one candidate result into the running best, applying the
/// minimum-gain floor and the `(gain, lowest feature index)` order.
fn consider(best: &mut Option<SplitCandidate>, cand: Option<SplitCandidate>) {
    if let Some(c) = cand {
        if c.gain > 1e-12 && best.as_ref().map(|b| better_candidate(&c, b)).unwrap_or(true) {
            *best = Some(c);
        }
    }
}

/// Reduces per-candidate results (in candidate order) to the best split.
fn reduce_candidates<I: IntoIterator<Item = Option<SplitCandidate>>>(
    results: I,
) -> Option<SplitCandidate> {
    let mut best: Option<SplitCandidate> = None;
    for cand in results {
        consider(&mut best, cand);
    }
    best
}

/// Does this configuration consume randomness during split scoring?
/// (Random categorical subsets and sparse oblique projections do; the
/// exact splitters don't, and then `find_best_split` leaves the caller's
/// RNG untouched — which keeps distributed and single-machine training
/// bit-identical under the default configuration.)
fn scoring_uses_rng(cfg: &SplitterConfig) -> bool {
    matches!(cfg.categorical, CategoricalSplit::Random { .. })
        || matches!(cfg.axis, SplitAxis::SparseOblique { .. })
}

/// Per-candidate RNG, derived from the node seed and a salt (the column
/// index, or [`OBLIQUE_SALT`] for the combined oblique candidate).
/// Candidates draw from independent streams, so scoring order — and
/// thread count — cannot change any candidate's result.
fn candidate_rng(node_seed: u64, salt: u64) -> Rng {
    let mut s = node_seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    Rng::seed_from_u64(splitmix64(&mut s))
}

const OBLIQUE_SALT: u64 = u64::MAX;

/// The work units of one node's split search: every non-oblique candidate
/// column, plus (under sparse-oblique axes) one combined unit over all
/// numerical candidates.
fn split_units(
    ds: &Dataset,
    candidates: &[usize],
    cfg: &SplitterConfig,
) -> (Vec<usize>, Vec<usize>) {
    let oblique = matches!(cfg.axis, SplitAxis::SparseOblique { .. });
    let mut unit_cols = Vec::with_capacity(candidates.len());
    let mut oblique_cols = Vec::new();
    for &col in candidates {
        if oblique && ds.spec.columns[col].semantic == FeatureSemantic::Numerical {
            oblique_cols.push(col);
        } else {
            unit_cols.push(col);
        }
    }
    (unit_cols, oblique_cols)
}

/// Scores one candidate column (any semantic except the combined oblique
/// unit).
#[allow(clippy::too_many_arguments)]
fn eval_column(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    index: &ColumnIndex,
    scratch: &mut NodeScratch,
    node_seed: u64,
) -> Option<SplitCandidate> {
    match ds.spec.columns[col].semantic {
        FeatureSemantic::Numerical => {
            numerical::split_numerical(ds, col, rows, labels, cfg, index, scratch)
        }
        FeatureSemantic::Categorical => categorical::split_categorical(
            ds,
            col,
            rows,
            labels,
            cfg,
            &mut candidate_rng(node_seed, col as u64),
        ),
        FeatureSemantic::Boolean => categorical::split_boolean(ds, col, rows, labels, cfg),
        FeatureSemantic::CategoricalSet => {
            categorical::split_categorical_set(ds, col, rows, labels, cfg)
        }
    }
}

/// Scores the combined sparse-oblique unit over the numerical candidates.
fn eval_oblique(
    ds: &Dataset,
    oblique_cols: &[usize],
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    scratch: &mut NodeScratch,
    node_seed: u64,
) -> Option<SplitCandidate> {
    match cfg.axis {
        SplitAxis::SparseOblique { num_projections_exponent, normalization } => {
            oblique::split_oblique(
                ds,
                oblique_cols,
                rows,
                labels,
                cfg,
                num_projections_exponent,
                normalization,
                scratch,
                &mut candidate_rng(node_seed, OBLIQUE_SALT),
            )
        }
        SplitAxis::AxisAligned => None,
    }
}

/// Finds the best split over the candidate columns, sequentially.
///
/// `rows` are the examples in the node (duplicates allowed under
/// bootstrap); `candidates` are column indices to consider. This is the
/// single-threaded core; [`SplitEngine::find_best_split`] is the
/// thread-parallel front end and produces bit-identical results.
#[allow(clippy::too_many_arguments)]
pub fn find_best_split(
    ds: &Dataset,
    rows: &[u32],
    labels: &Labels,
    candidates: &[usize],
    cfg: &SplitterConfig,
    index: &ColumnIndex,
    scratch: &mut NodeScratch,
    rng: &mut Rng,
) -> Option<SplitCandidate> {
    let node_seed = if scoring_uses_rng(cfg) { rng.next_u64() } else { 0 };
    find_best_split_seeded(ds, rows, labels, candidates, cfg, index, scratch, node_seed)
}

#[allow(clippy::too_many_arguments)]
fn find_best_split_seeded(
    ds: &Dataset,
    rows: &[u32],
    labels: &Labels,
    candidates: &[usize],
    cfg: &SplitterConfig,
    index: &ColumnIndex,
    scratch: &mut NodeScratch,
    node_seed: u64,
) -> Option<SplitCandidate> {
    // Fold each candidate as it is scored, in candidate order with the
    // oblique unit last — the exact reduction order of the parallel
    // path, with no per-node result buffer.
    let oblique = matches!(cfg.axis, SplitAxis::SparseOblique { .. });
    let mut oblique_cols: Vec<usize> = Vec::new();
    let mut best: Option<SplitCandidate> = None;
    for &col in candidates {
        if oblique && ds.spec.columns[col].semantic == FeatureSemantic::Numerical {
            oblique_cols.push(col);
        } else {
            consider(
                &mut best,
                eval_column(ds, col, rows, labels, cfg, index, &mut *scratch, node_seed),
            );
        }
    }
    if !oblique_cols.is_empty() {
        consider(
            &mut best,
            eval_oblique(ds, &oblique_cols, rows, labels, cfg, scratch, node_seed),
        );
    }
    best
}

// ---------------------------------------------------------------------------
// SplitEngine: thread-parallel split search.
// ---------------------------------------------------------------------------

/// The split-search engine one tree grower drives: the shared
/// [`ColumnIndex`], an optional persistent worker pool, and one
/// [`NodeScratch`] per worker. With `threads <= 1` every call runs inline
/// on the caller's thread; with more, candidate features are divided into
/// contiguous chunks scattered over the pool
/// ([`WorkerPool::run_scoped`]), each chunk scoring with its own scratch.
/// The reduction is performed on the caller's thread in candidate order,
/// so the result is bit-identical to [`find_best_split`] for any thread
/// count.
pub struct SplitEngine {
    index: Arc<ColumnIndex>,
    pool: Option<WorkerPool>,
    scratches: Vec<NodeScratch>,
}

impl SplitEngine {
    /// `threads <= 1` builds a sequential engine (no pool, one scratch).
    pub fn new(index: Arc<ColumnIndex>, threads: usize) -> SplitEngine {
        let threads = threads.max(1);
        let num_rows = index.num_rows();
        SplitEngine {
            index,
            pool: if threads > 1 { Some(WorkerPool::new(threads)) } else { None },
            scratches: (0..threads).map(|_| NodeScratch::new(num_rows)).collect(),
        }
    }

    /// Sequential engine (the common per-tree worker in a parallel RF).
    pub fn sequential(index: Arc<ColumnIndex>) -> SplitEngine {
        SplitEngine::new(index, 1)
    }

    pub fn index(&self) -> &ColumnIndex {
        &self.index
    }

    pub fn num_threads(&self) -> usize {
        self.scratches.len()
    }

    /// Finds the best split over `candidates`, fanning the per-feature
    /// scoring out across the engine's workers when it has any.
    pub fn find_best_split(
        &mut self,
        ds: &Dataset,
        rows: &[u32],
        labels: &Labels,
        candidates: &[usize],
        cfg: &SplitterConfig,
        rng: &mut Rng,
    ) -> Option<SplitCandidate> {
        let node_seed = if scoring_uses_rng(cfg) { rng.next_u64() } else { 0 };
        let (unit_cols, oblique_cols) = split_units(ds, candidates, cfg);
        let n_units = unit_cols.len() + usize::from(!oblique_cols.is_empty());
        // Deep-tree leaves are tiny; below this much total work the
        // scatter/drain round trip costs more than it buys. Both paths
        // are bit-identical, so the cutoff is pure throughput tuning.
        const PAR_MIN_WORK: usize = 512;
        if self.pool.is_none()
            || n_units < 2
            || rows.len().saturating_mul(n_units) < PAR_MIN_WORK
        {
            return find_best_split_seeded(
                ds,
                rows,
                labels,
                candidates,
                cfg,
                &self.index,
                &mut self.scratches[0],
                node_seed,
            );
        }

        let mut results: Vec<Option<SplitCandidate>> = Vec::new();
        results.resize_with(n_units, || None);
        let chunk = n_units.div_ceil(self.scratches.len());
        let index: &ColumnIndex = &self.index;
        let unit_cols_ref: &[usize] = &unit_cols;
        let oblique_cols_ref: &[usize] = &oblique_cols;
        let mut jobs = Vec::with_capacity(n_units.div_ceil(chunk));
        for ((out_chunk, scratch), start) in results
            .chunks_mut(chunk)
            .zip(self.scratches.iter_mut())
            .zip((0..n_units).step_by(chunk))
        {
            jobs.push(move || {
                for (j, slot) in out_chunk.iter_mut().enumerate() {
                    let u = start + j;
                    *slot = if u < unit_cols_ref.len() {
                        eval_column(
                            ds,
                            unit_cols_ref[u],
                            rows,
                            labels,
                            cfg,
                            index,
                            &mut *scratch,
                            node_seed,
                        )
                    } else {
                        eval_oblique(
                            ds,
                            oblique_cols_ref,
                            rows,
                            labels,
                            cfg,
                            &mut *scratch,
                            node_seed,
                        )
                    };
                }
            });
        }
        self.pool.as_ref().expect("pool checked above").run_scoped(jobs);
        reduce_candidates(results)
    }
}

/// Partitions `rows` into (positive, negative) according to a condition,
/// applying the missing policy. The growers use [`RowArena`] spans
/// instead; this allocating form remains for the distributed leader (the
/// broadcast wants owned vectors) and as the arena's reference semantics.
pub fn partition_rows(
    ds: &Dataset,
    rows: &[u32],
    condition: &Condition,
    missing_to_positive: bool,
) -> (Vec<u32>, Vec<u32>) {
    let mut pos = Vec::new();
    let mut neg = Vec::new();
    for &r in rows {
        let goes_pos =
            condition.evaluate_ds(ds, r as usize).unwrap_or(missing_to_positive);
        if goes_pos {
            pos.push(r);
        } else {
            neg.push(r);
        }
    }
    (pos, neg)
}

/// Helper used by the numerical splitters: scan sorted (value, row) pairs,
/// evaluating every distinct-value boundary. Missing-value examples follow
/// the node mean (local imputation, §3.4).
pub(crate) struct ScanResult {
    pub threshold: f32,
    pub gain: f64,
    pub missing_to_positive: bool,
}

pub(crate) fn scan_sorted_pairs(
    pairs: &[(f32, u32)],
    missing_rows: &[u32],
    labels: &Labels,
    min_examples: usize,
) -> Option<ScanResult> {
    let n = pairs.len();
    if n < 2 * min_examples.max(1) {
        return None;
    }
    // Node accumulators: all non-missing start on the positive (>=) side.
    let mut left = labels.new_acc();
    let mut right = labels.new_acc();
    for &(_, r) in pairs {
        right.add(labels, r as usize);
    }
    let mut miss = labels.new_acc();
    for &r in missing_rows {
        miss.add(labels, r as usize);
    }
    let has_missing = miss.count() > 0.0;
    // Mean of the feature over the node: where missing values impute.
    let mean = pairs.iter().map(|&(v, _)| v as f64).sum::<f64>() / n as f64;

    let mut parent = right.clone();
    parent.merge(&miss);

    let mut best: Option<ScanResult> = None;
    for i in 0..n - 1 {
        let (v, r) = pairs[i];
        left.add(labels, r as usize);
        right.remove(labels, r as usize);
        let next_v = pairs[i + 1].0;
        if next_v <= v {
            continue; // not a boundary between distinct values
        }
        let n_left = i + 1;
        let n_right = n - n_left;
        if n_left < min_examples || n_right < min_examples {
            continue;
        }
        // Threshold at the midpoint (condition is x >= t, so the right
        // block is positive).
        let threshold = v + (next_v - v) / 2.0;
        let missing_to_positive = (mean as f32) >= threshold;
        let gain = if has_missing {
            // Merge missing into the side it would impute to.
            if missing_to_positive {
                let mut r2 = right.clone();
                r2.merge(&miss);
                score::ScoreAcc::gain(&parent, &left, &r2, labels)
            } else {
                let mut l2 = left.clone();
                l2.merge(&miss);
                score::ScoreAcc::gain(&parent, &l2, &right, labels)
            }
        } else {
            score::ScoreAcc::gain(&parent, &left, &right, labels)
        };
        if best.as_ref().map(|b| gain > b.gain).unwrap_or(true) {
            best = Some(ScanResult { threshold, gain, missing_to_positive });
        }
    }
    best
}

/// Collects the non-missing (value, row) pairs and missing rows of a
/// numerical column restricted to `rows`, into reusable buffers (cleared
/// first).
pub(crate) fn collect_numerical(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    pairs: &mut Vec<(f32, u32)>,
    missing: &mut Vec<u32>,
) {
    let values = match &ds.columns[col] {
        ColumnData::Numerical(v) => v,
        _ => panic!("collect_numerical on non-numerical column"),
    };
    pairs.clear();
    missing.clear();
    for &r in rows {
        let v = values[r as usize];
        if v.is_nan() {
            missing.push(r);
        } else {
            pairs.push((v, r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::{ColumnSpec, DataSpec};

    /// Two identical feature columns: their best splits tie exactly, and
    /// the `(gain, lowest feature index)` rule must pick column 0 no
    /// matter which order the candidates are scanned in.
    fn twin_column_ds() -> (Dataset, Vec<u32>) {
        let v = vec![1.0f32, 2.0, 3.0, 10.0, 11.0, 12.0];
        let spec = DataSpec {
            columns: vec![ColumnSpec::numerical("a"), ColumnSpec::numerical("b")],
        };
        let ds = Dataset::new(
            spec,
            vec![ColumnData::Numerical(v.clone()), ColumnData::Numerical(v)],
        )
        .unwrap();
        (ds, vec![0, 0, 0, 1, 1, 1])
    }

    #[test]
    fn tie_break_picks_lowest_feature_index_in_any_scan_order() {
        let (ds, y) = twin_column_ds();
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = SplitterConfig { min_examples: 1, ..Default::default() };
        let index = ColumnIndex::new(&ds);
        let mut scratch = NodeScratch::new(ds.num_rows());
        let rows: Vec<u32> = (0..6).collect();
        for candidates in [[0usize, 1], [1usize, 0]] {
            let best = find_best_split(
                &ds,
                &rows,
                &labels,
                &candidates,
                &cfg,
                &index,
                &mut scratch,
                &mut Rng::seed_from_u64(1),
            )
            .unwrap();
            match best.condition {
                Condition::Higher { attr, .. } => {
                    assert_eq!(attr, 0, "candidates {candidates:?} must tie-break to col 0")
                }
                _ => panic!("wrong condition"),
            }
        }
    }

    #[test]
    fn parallel_engine_matches_sequential_bitwise() {
        // Big enough (rows × units ≥ the parallel cutoff) that the pooled
        // engine really scatters; three numerical columns with noisy
        // signal plus NaNs so the candidates have distinct gains.
        let n = 300usize;
        let mut rng = Rng::seed_from_u64(21);
        let cols: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                (0..n)
                    .map(|_| {
                        if rng.bernoulli(0.05) {
                            f32::NAN
                        } else {
                            rng.uniform_range(-4.0, 4.0) as f32
                        }
                    })
                    .collect()
            })
            .collect();
        let y: Vec<u32> = (0..n)
            .map(|i| {
                let v = cols[0][i];
                ((v.is_nan() || v > 0.0) as u32) ^ (rng.bernoulli(0.15) as u32)
            })
            .collect();
        let spec = DataSpec {
            columns: (0..3).map(|i| ColumnSpec::numerical(&format!("x{i}"))).collect(),
        };
        let ds = Dataset::new(
            spec,
            cols.into_iter().map(ColumnData::Numerical).collect(),
        )
        .unwrap();
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = SplitterConfig { min_examples: 2, ..Default::default() };
        let rows: Vec<u32> = (0..n as u32).collect();
        let index = Arc::new(ColumnIndex::new(&ds));
        let mut seq = SplitEngine::sequential(Arc::clone(&index));
        let mut par = SplitEngine::new(index, 3);
        assert_eq!(par.num_threads(), 3);
        for candidates in [vec![0usize, 1, 2], vec![2usize, 1, 0], vec![1usize, 2]] {
            let a = seq
                .find_best_split(&ds, &rows, &labels, &candidates, &cfg, &mut Rng::seed_from_u64(7))
                .unwrap();
            let b = par
                .find_best_split(&ds, &rows, &labels, &candidates, &cfg, &mut Rng::seed_from_u64(7))
                .unwrap();
            assert_eq!(a.gain.to_bits(), b.gain.to_bits(), "candidates {candidates:?}");
            assert_eq!(
                format!("{:?}", a.condition),
                format!("{:?}", b.condition),
                "candidates {candidates:?}"
            );
            assert_eq!(a.missing_to_positive, b.missing_to_positive);
        }
    }

    #[test]
    fn arena_partition_is_stable_and_in_place() {
        let (ds, _y) = twin_column_ds();
        let mut arena = RowArena::new();
        // Duplicates (bootstrap) and unsorted order on purpose.
        arena.reset(&[5, 0, 3, 0, 2, 4, 1, 5]);
        let cond = Condition::Higher { attr: 0, threshold: 6.5 };
        let (pos, neg) = partition_rows(&ds, &[5, 0, 3, 0, 2, 4, 1, 5], &cond, false);
        let n_pos = arena.partition_span(&ds, &cond, false, 0, 8);
        assert_eq!(arena.span(0, n_pos), pos.as_slice());
        assert_eq!(arena.span(n_pos, 8 - n_pos), neg.as_slice());
        assert_eq!(n_pos, 4); // rows 5,3,4,5 have values >= 6.5
    }

    #[test]
    fn arena_partition_leaves_other_spans_untouched() {
        let (ds, _y) = twin_column_ds();
        let mut arena = RowArena::new();
        arena.reset(&[0, 1, 2, 3, 4, 5]);
        let cond = Condition::Higher { attr: 0, threshold: 6.5 };
        // Partition only [2, 6); the prefix span must not move.
        let n_pos = arena.partition_span(&ds, &cond, false, 2, 4);
        assert_eq!(arena.span(0, 2), &[0, 1]);
        assert_eq!(n_pos, 3);
        assert_eq!(arena.span(2, 3), &[3, 4, 5]);
        assert_eq!(arena.span(5, 1), &[2]);
    }

    #[test]
    fn column_index_is_shared_and_lazy() {
        let (ds, _) = twin_column_ds();
        let index = Arc::new(ColumnIndex::new(&ds));
        let a = index.sorted_order(&ds, 0);
        assert_eq!(a, &[0, 1, 2, 3, 4, 5]);
        // Same allocation on the second call (built once).
        let b = index.sorted_order(&ds, 0);
        assert_eq!(a.as_ptr(), b.as_ptr());
        let (edges, assigned) = index.binned_column(&ds, 1, 4);
        assert!(!edges.is_empty());
        assert_eq!(assigned.len(), 6);
    }
}
