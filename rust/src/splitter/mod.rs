//! SPLITTERS (§3.8): algorithms that find the best split condition for a
//! node. Organized as the paper describes (§2.3): one module per feature
//! type (numerical, categorical, boolean, categorical-set, oblique), all
//! generic over the label type through [`score::Labels`].
//!
//! Numerical splitters are *exact* by default (no discretization), like
//! XGBoost; the histogram splitter provides LightGBM-style approximate
//! splitting. `Auto` picks in-sorting vs pre-sorting per node, the dynamic
//! choice §2.3 credits to the modular design.

pub mod categorical;
pub mod numerical;
pub mod oblique;
pub mod score;

use crate::dataset::{ColumnData, Dataset, FeatureSemantic};
use crate::model::tree::Condition;
use crate::utils::rng::Rng;
use score::Labels;

/// A proposed split.
#[derive(Clone, Debug)]
pub struct SplitCandidate {
    pub condition: Condition,
    pub gain: f64,
    pub missing_to_positive: bool,
}

/// Numerical splitter selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NumericalSplit {
    /// Sort the node's values at each node (simple, good for deep trees).
    ExactInSort,
    /// Reuse a global per-feature sort (good for top/shallow nodes).
    Presorted,
    /// Per-node dynamic choice between the two (§2.3).
    Auto,
    /// LightGBM-style quantile histogram (approximate, fast).
    Histogram { bins: usize },
}

/// Categorical splitter selection (§3.8).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CategoricalSplit {
    /// Exact one-vs-rest ordering trick (Fisher/Breiman; LightGBM-like).
    Cart,
    /// Random set sampling (Breiman's random projections).
    Random { trials: usize },
    /// One category vs rest (XGBoost/scikit-learn one-hot emulation).
    OneHot,
}

/// Axis handling for numerical features.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SplitAxis {
    AxisAligned,
    /// Sparse oblique projections (Tomita et al.; benchmark_rank1@v1).
    SparseOblique { num_projections_exponent: f64, normalization: ObliqueNormalization },
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ObliqueNormalization {
    None,
    /// Weights scaled by 1/(max-min) of the node (benchmark hp default).
    MinMax,
    /// Weights scaled by 1/std of the node.
    StandardDeviation,
}

/// Splitter configuration, shared by all tree learners.
#[derive(Clone, Debug)]
pub struct SplitterConfig {
    pub numerical: NumericalSplit,
    pub categorical: CategoricalSplit,
    pub axis: SplitAxis,
    pub min_examples: usize,
}

impl Default for SplitterConfig {
    fn default() -> Self {
        SplitterConfig {
            numerical: NumericalSplit::ExactInSort,
            categorical: CategoricalSplit::Cart,
            axis: SplitAxis::AxisAligned,
            min_examples: 5,
        }
    }
}

/// Per-training caches: lazily built global sort orders and histogram bin
/// assignments, plus node-membership scratch (epoch-stamped to avoid
/// clearing).
pub struct TrainingCache {
    /// Per column: rows sorted by value, missing rows excluded.
    sorted: Vec<Option<Vec<u32>>>,
    /// Per column: (bin upper edges, per-row bin index).
    binned: Vec<Option<(Vec<f32>, Vec<u16>)>>,
    /// Node membership stamp per row.
    member_epoch: Vec<u32>,
    epoch: u32,
    num_rows: usize,
}

impl TrainingCache {
    pub fn new(ds: &Dataset) -> TrainingCache {
        TrainingCache {
            sorted: vec![None; ds.num_columns()],
            binned: vec![None; ds.num_columns()],
            member_epoch: vec![0; ds.num_rows()],
            epoch: 0,
            num_rows: ds.num_rows(),
        }
    }

    /// Marks `rows` as the current node; returns the epoch token and the
    /// number of *distinct* rows stamped (fewer than `rows.len()` exactly
    /// when `rows` contains bootstrap duplicates, which the membership
    /// stamps cannot express).
    fn mark_members(&mut self, rows: &[u32]) -> (u32, usize) {
        self.epoch += 1;
        let mut distinct = 0usize;
        for &r in rows {
            if self.member_epoch[r as usize] != self.epoch {
                self.member_epoch[r as usize] = self.epoch;
                distinct += 1;
            }
        }
        (self.epoch, distinct)
    }

    #[inline]
    fn is_member(&self, row: u32, epoch: u32) -> bool {
        self.member_epoch[row as usize] == epoch
    }

    /// Builds the global sort order of a numerical column on first use.
    /// Split from the accessor so callers can hold the `&self` borrow of
    /// [`TrainingCache::sorted_order`] alongside `is_member` — the seed
    /// cloned the full O(N) order per node to work around the `&mut`
    /// borrow instead.
    fn ensure_sorted(&mut self, ds: &Dataset, col: usize) {
        if self.sorted[col].is_none() {
            let values = ds.columns[col].as_numerical().expect("presort on non-numerical");
            let mut idx: Vec<u32> =
                (0..values.len() as u32).filter(|&r| !values[r as usize].is_nan()).collect();
            idx.sort_by(|&a, &b| {
                values[a as usize].partial_cmp(&values[b as usize]).unwrap()
            });
            self.sorted[col] = Some(idx);
        }
    }

    /// Borrows the prebuilt global sort order (`ensure_sorted` first).
    fn sorted_order(&self, col: usize) -> &[u32] {
        self.sorted[col].as_ref().expect("ensure_sorted must be called before sorted_order")
    }

    /// Builds the histogram binning of a numerical column on first use
    /// (same two-phase pattern as `ensure_sorted`: the seed cloned the
    /// per-row bin assignment per node).
    fn ensure_binned(&mut self, ds: &Dataset, col: usize, bins: usize) {
        if self.binned[col].is_none() {
            let values = ds.columns[col].as_numerical().expect("binning non-numerical");
            let mut sorted: Vec<f32> =
                values.iter().copied().filter(|v| !v.is_nan()).collect();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut edges = Vec::with_capacity(bins);
            if !sorted.is_empty() {
                for b in 1..bins {
                    let pos = b * (sorted.len() - 1) / bins;
                    let e = sorted[pos];
                    if edges.last().map(|&l| e > l).unwrap_or(true) {
                        edges.push(e);
                    }
                }
            }
            // Edge semantics: bin i = values <= edges[i]; last bin = rest.
            let bin_of = |v: f32| -> u16 {
                match edges.binary_search_by(|e| e.partial_cmp(&v).unwrap()) {
                    Ok(i) => i as u16,
                    Err(i) => i as u16,
                }
            };
            let assigned: Vec<u16> = values
                .iter()
                .map(|&v| if v.is_nan() { u16::MAX } else { bin_of(v) })
                .collect();
            self.binned[col] = Some((edges, assigned));
        }
    }

    /// Borrows the prebuilt (bin edges, per-row bin index) of a column
    /// (`ensure_binned` first).
    fn binned_column(&self, col: usize) -> (&[f32], &[u16]) {
        let b =
            self.binned[col].as_ref().expect("ensure_binned must be called before binned_column");
        (b.0.as_slice(), b.1.as_slice())
    }
}

/// Finds the best split over the candidate columns.
///
/// `rows` are the examples in the node (duplicates allowed under
/// bootstrap); `candidates` are column indices to consider.
#[allow(clippy::too_many_arguments)]
pub fn find_best_split(
    ds: &Dataset,
    rows: &[u32],
    labels: &Labels,
    candidates: &[usize],
    cfg: &SplitterConfig,
    cache: &mut TrainingCache,
    rng: &mut Rng,
) -> Option<SplitCandidate> {
    let mut best: Option<SplitCandidate> = None;
    let mut consider = |cand: Option<SplitCandidate>, best: &mut Option<SplitCandidate>| {
        if let Some(c) = cand {
            if c.gain > 1e-12 && best.as_ref().map(|b| c.gain > b.gain).unwrap_or(true) {
                *best = Some(c);
            }
        }
    };

    let oblique = matches!(cfg.axis, SplitAxis::SparseOblique { .. });
    let mut numerical_candidates = Vec::new();
    for &col in candidates {
        match ds.spec.columns[col].semantic {
            FeatureSemantic::Numerical => {
                if oblique {
                    numerical_candidates.push(col);
                } else {
                    consider(
                        numerical::split_numerical(ds, col, rows, labels, cfg, cache),
                        &mut best,
                    );
                }
            }
            FeatureSemantic::Categorical => {
                consider(
                    categorical::split_categorical(ds, col, rows, labels, cfg, rng),
                    &mut best,
                );
            }
            FeatureSemantic::Boolean => {
                consider(categorical::split_boolean(ds, col, rows, labels, cfg), &mut best);
            }
            FeatureSemantic::CategoricalSet => {
                consider(
                    categorical::split_categorical_set(ds, col, rows, labels, cfg),
                    &mut best,
                );
            }
        }
    }
    if oblique && !numerical_candidates.is_empty() {
        if let SplitAxis::SparseOblique { num_projections_exponent, normalization } = cfg.axis {
            consider(
                oblique::split_oblique(
                    ds,
                    &numerical_candidates,
                    rows,
                    labels,
                    cfg,
                    num_projections_exponent,
                    normalization,
                    rng,
                ),
                &mut best,
            );
        }
    }
    best
}

/// Partitions `rows` into (positive, negative) according to a condition,
/// applying the missing policy.
pub fn partition_rows(
    ds: &Dataset,
    rows: &[u32],
    condition: &Condition,
    missing_to_positive: bool,
) -> (Vec<u32>, Vec<u32>) {
    let mut pos = Vec::new();
    let mut neg = Vec::new();
    for &r in rows {
        let goes_pos =
            condition.evaluate_ds(ds, r as usize).unwrap_or(missing_to_positive);
        if goes_pos {
            pos.push(r);
        } else {
            neg.push(r);
        }
    }
    (pos, neg)
}

/// Helper used by the numerical splitters: scan sorted (value, row) pairs,
/// evaluating every distinct-value boundary. Missing-value examples follow
/// the node mean (local imputation, §3.4).
pub(crate) struct ScanResult {
    pub threshold: f32,
    pub gain: f64,
    pub missing_to_positive: bool,
}

pub(crate) fn scan_sorted_pairs(
    pairs: &[(f32, u32)],
    missing_rows: &[u32],
    labels: &Labels,
    min_examples: usize,
) -> Option<ScanResult> {
    let n = pairs.len();
    if n < 2 * min_examples.max(1) {
        return None;
    }
    // Node accumulators: all non-missing start on the positive (>=) side.
    let mut left = labels.new_acc();
    let mut right = labels.new_acc();
    for &(_, r) in pairs {
        right.add(labels, r as usize);
    }
    let mut miss = labels.new_acc();
    for &r in missing_rows {
        miss.add(labels, r as usize);
    }
    let has_missing = miss.count() > 0.0;
    // Mean of the feature over the node: where missing values impute.
    let mean = pairs.iter().map(|&(v, _)| v as f64).sum::<f64>() / n as f64;

    let mut parent = right.clone();
    parent.merge(&miss);

    let mut best: Option<ScanResult> = None;
    for i in 0..n - 1 {
        let (v, r) = pairs[i];
        left.add(labels, r as usize);
        right.remove(labels, r as usize);
        let next_v = pairs[i + 1].0;
        if next_v <= v {
            continue; // not a boundary between distinct values
        }
        let n_left = i + 1;
        let n_right = n - n_left;
        if n_left < min_examples || n_right < min_examples {
            continue;
        }
        // Threshold at the midpoint (condition is x >= t, so the right
        // block is positive).
        let threshold = v + (next_v - v) / 2.0;
        let missing_to_positive = (mean as f32) >= threshold;
        let gain = if has_missing {
            // Merge missing into the side it would impute to.
            if missing_to_positive {
                let mut r2 = right.clone();
                r2.merge(&miss);
                score::ScoreAcc::gain(&parent, &left, &r2, labels)
            } else {
                let mut l2 = left.clone();
                l2.merge(&miss);
                score::ScoreAcc::gain(&parent, &l2, &right, labels)
            }
        } else {
            score::ScoreAcc::gain(&parent, &left, &right, labels)
        };
        if best.as_ref().map(|b| gain > b.gain).unwrap_or(true) {
            best = Some(ScanResult { threshold, gain, missing_to_positive });
        }
    }
    best
}

/// Collects the non-missing (value, row) pairs and missing rows of a
/// numerical column restricted to `rows`.
pub(crate) fn collect_numerical(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
) -> (Vec<(f32, u32)>, Vec<u32>) {
    let values = match &ds.columns[col] {
        ColumnData::Numerical(v) => v,
        _ => panic!("collect_numerical on non-numerical column"),
    };
    let mut pairs = Vec::with_capacity(rows.len());
    let mut missing = Vec::new();
    for &r in rows {
        let v = values[r as usize];
        if v.is_nan() {
            missing.push(r);
        } else {
            pairs.push((v, r));
        }
    }
    (pairs, missing)
}
