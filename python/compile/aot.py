"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts for the Rust
PJRT runtime.

NOTE: the native compiled-forest path supersedes this pipeline for
production AOT serving — `ydf compile` (rust/src/inference/compiled.rs)
lowers a trained forest to a checksummed, mmap-able `.bin` artifact
with exact (bit-identical) semantics and no Python/XLA dependency.
This module stays as the cross-backend escape hatch for the
feature-gated PJRT engine; see the compiled-forest item in ROADMAP.md.

HLO text — not `lowered.compile().serialize()` — is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids that
the runtime's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import forest as fk


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_forest():
    f32 = jnp.float32
    i32 = jnp.int32
    spec = jax.ShapeDtypeStruct
    args = (
        spec((fk.BATCH, fk.MAX_FEATURES), f32),      # features
        spec((fk.MAX_TREES, fk.MAX_NODES), i32),     # node_feature
        spec((fk.MAX_TREES, fk.MAX_NODES), f32),     # node_threshold
        spec((fk.MAX_TREES, fk.MAX_NODES), i32),     # node_pos
        spec((fk.MAX_TREES, fk.MAX_NODES), i32),     # node_neg
        spec((fk.MAX_TREES, fk.MAX_NODES), f32),     # leaf_value
        spec((1,), f32),                             # initial
    )
    return jax.jit(model.forest_predict).lower(*args)


LINEAR_DIM = 32
LINEAR_CLASSES = 8
LINEAR_BATCH = 64


def lower_linear_predict():
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    return jax.jit(model.linear_predict).lower(
        spec((LINEAR_BATCH, LINEAR_DIM), f32),
        spec((LINEAR_DIM, LINEAR_CLASSES), f32),
        spec((LINEAR_CLASSES,), f32),
    )


def lower_linear_train_step():
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    return jax.jit(model.linear_train_step).lower(
        spec((LINEAR_BATCH, LINEAR_DIM), f32),
        spec((LINEAR_BATCH, LINEAR_CLASSES), f32),
        spec((LINEAR_DIM, LINEAR_CLASSES), f32),
        spec((LINEAR_CLASSES,), f32),
        spec((1,), f32),
    )


ARTIFACTS = {
    "forest.hlo.txt": lower_forest,
    "linear.hlo.txt": lower_linear_predict,
    "linear_train_step.hlo.txt": lower_linear_train_step,
}


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    parser.add_argument("--only", default=None, help="single artifact name")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, lower in ARTIFACTS.items():
        if args.only and name != args.only:
            continue
        text = to_hlo_text(lower())
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")


if __name__ == "__main__":
    main()
