//! Leveled logging facade: `YDF_LOG=off|warn|info|debug`, default `warn`.
//!
//! One facade replaces the library's ad-hoc `eprintln!` calls so a
//! deployment controls verbosity with a single knob: `off` silences the
//! library entirely (the CLI's own command output and error reporting are
//! not log lines and stay), `warn` (the default) keeps misconfiguration
//! diagnostics, `info` adds training progress (per-iteration GBT loss,
//! per-model RF/CART summaries, serving lifecycle), `debug` adds
//! per-tree and per-engine detail.
//!
//! Call through the macros — they gate the *formatting* cost behind one
//! relaxed atomic load, so a disabled level costs no allocation:
//!
//! ```
//! ydf::ydf_info!("trained {} trees", 42);
//! ```
//!
//! The level resolves lazily from `YDF_LOG` on first use;
//! [`set_level`] overrides it programmatically (tests, benches, and
//! embedders that configure logging themselves).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered: a configured level enables itself and
/// everything less verbose.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

const UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

/// Whether `level` is currently enabled — one relaxed load on the fast
/// path (the first call resolves `YDF_LOG`).
#[inline]
pub fn enabled(level: Level) -> bool {
    let mut current = LEVEL.load(Ordering::Relaxed);
    if current == UNSET {
        current = init_from_env();
    }
    level as u8 <= current
}

/// Resolves `YDF_LOG` once. An unknown value falls back to the default
/// with a one-time warning (emitted *after* the level is stored, so the
/// warning itself obeys the resolved level — and the `utils::env`
/// warn-once path cannot recurse into an unset level).
#[cold]
fn init_from_env() -> u8 {
    let raw = crate::utils::env::string("YDF_LOG");
    let lowered = raw.as_deref().map(str::to_ascii_lowercase);
    let (level, bad) = match lowered.as_deref() {
        None => (Level::Warn, None),
        Some("off" | "none" | "0") => (Level::Off, None),
        Some("warn" | "warning") => (Level::Warn, None),
        Some("info") => (Level::Info, None),
        Some("debug") => (Level::Debug, None),
        Some(_) => (Level::Warn, raw),
    };
    LEVEL.store(level as u8, Ordering::Relaxed);
    if let Some(bad) = bad {
        crate::utils::env::warn_once(
            "YDF_LOG",
            &format!("ignoring YDF_LOG='{bad}': expected off, warn, info or debug"),
        );
    }
    level as u8
}

/// Sets the level programmatically, overriding `YDF_LOG`.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Writes one log line to stderr. Does **not** check [`enabled`] — the
/// macros do, before paying for formatting; direct callers must too.
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    let tag = match level {
        Level::Off => return,
        Level::Warn => "warn",
        Level::Info => "info",
        Level::Debug => "debug",
    };
    eprintln!("[ydf {tag}] {args}");
}

/// Logs at `warn` — misconfiguration and degraded-mode diagnostics.
#[macro_export]
macro_rules! ydf_warn {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::emit($crate::obs::log::Level::Warn, format_args!($($arg)*));
        }
    };
}

/// Logs at `info` — training progress and serving lifecycle.
#[macro_export]
macro_rules! ydf_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit($crate::obs::log::Level::Info, format_args!($($arg)*));
        }
    };
}

/// Logs at `debug` — per-tree / per-engine detail.
#[macro_export]
macro_rules! ydf_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit($crate::obs::log::Level::Debug, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered_and_gated() {
        // Note: the level is process-global; tests that care set it
        // explicitly rather than relying on the environment.
        set_level(Level::Info);
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Off);
        assert!(!enabled(Level::Warn));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        // Restore the default so concurrently running tests that log at
        // info/debug stay quiet.
        set_level(Level::Warn);
    }
}
