//! Ensembler meta-learner (§3.2): trains several base learners and
//! averages their predictions.

use crate::dataset::{DataSpec, Dataset, Observation};
use crate::learner::Learner;
use crate::model::{Model, Task};
use crate::utils::json::Json;

/// Prediction-averaging ensemble of heterogeneous models.
pub struct EnsembleModel {
    pub members: Vec<Box<dyn Model>>,
}

impl Model for EnsembleModel {
    fn model_type(&self) -> &'static str {
        "ENSEMBLE"
    }
    fn task(&self) -> Task {
        self.members[0].task()
    }
    fn spec(&self) -> &DataSpec {
        self.members[0].spec()
    }
    fn label_col(&self) -> usize {
        self.members[0].label_col()
    }

    fn input_features(&self) -> Vec<usize> {
        let mut all: Vec<usize> =
            self.members.iter().flat_map(|m| m.input_features()).collect();
        all.sort_unstable();
        all.dedup();
        all
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        average(self.members.iter().map(|m| m.predict_row(obs)))
    }

    fn predict_ds_row(&self, ds: &Dataset, row: usize) -> Vec<f64> {
        average(self.members.iter().map(|m| m.predict_ds_row(ds, row)))
    }

    fn describe(&self) -> String {
        let mut s = format!("Type: \"ENSEMBLE\" ({} members)\n", self.members.len());
        for (i, m) in self.members.iter().enumerate() {
            s.push_str(&format!("--- member {} ---\n{}\n", i, m.describe()));
        }
        s
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("format_version", Json::Num(crate::model::io::MODEL_FORMAT_VERSION as f64))
            .set("model_type", Json::Str("ENSEMBLE".into()))
            .set(
                "members",
                Json::Arr(self.members.iter().map(|m| m.to_json()).collect()),
            );
        j
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

fn average<I: Iterator<Item = Vec<f64>>>(preds: I) -> Vec<f64> {
    let mut acc: Vec<f64> = Vec::new();
    let mut count = 0usize;
    for p in preds {
        if acc.is_empty() {
            acc = p;
        } else {
            for (a, b) in acc.iter_mut().zip(&p) {
                *a += b;
            }
        }
        count += 1;
    }
    for a in acc.iter_mut() {
        *a /= count.max(1) as f64;
    }
    acc
}

/// Trains each member learner on the full dataset and ensembles them.
pub struct EnsemblerLearner {
    pub members: Vec<Box<dyn Learner>>,
}

impl EnsemblerLearner {
    pub fn new(members: Vec<Box<dyn Learner>>) -> EnsemblerLearner {
        EnsemblerLearner { members }
    }
}

impl Learner for EnsemblerLearner {
    fn name(&self) -> &'static str {
        "ENSEMBLER"
    }

    fn label(&self) -> &str {
        self.members[0].label()
    }

    fn train_with_valid(
        &self,
        ds: &Dataset,
        valid: Option<&Dataset>,
    ) -> Result<Box<dyn Model>, String> {
        if self.members.is_empty() {
            return Err("the ensembler requires at least one member learner.".to_string());
        }
        let mut models = Vec::with_capacity(self.members.len());
        for m in &self.members {
            models.push(m.train_with_valid(ds, valid)?);
        }
        // Sanity: all members must agree on the task and label.
        let t0 = models[0].task();
        if models.iter().any(|m| m.task() != t0) {
            return Err("ensemble members disagree on the task.".to_string());
        }
        Ok(Box::new(EnsembleModel { members: models }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::evaluation_free_accuracy;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, LinearLearner};

    #[test]
    fn ensemble_of_gbt_and_linear() {
        let ds = synthetic::adult_like(300, 91);
        let mut gbt = GbtConfig::new("income");
        gbt.num_trees = 10;
        gbt.max_depth = 3;
        let ens = EnsemblerLearner::new(vec![
            Box::new(GradientBoostedTreesLearner::new(gbt)),
            Box::new(LinearLearner::default_config("income")),
        ]);
        let model = ens.train(&ds).unwrap();
        assert_eq!(model.model_type(), "ENSEMBLE");
        let acc = evaluation_free_accuracy(model.as_ref(), &ds);
        assert!(acc > 0.72, "ensemble accuracy {acc}");
        let p = model.predict_ds_row(&ds, 0);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_ensemble_rejected() {
        let ds = synthetic::adult_like(50, 93);
        let ens = EnsemblerLearner::new(vec![]);
        assert!(ens.train(&ds).is_err());
    }

    #[test]
    fn average_helper() {
        let out = average(vec![vec![0.2, 0.8], vec![0.6, 0.4]].into_iter());
        assert_eq!(out, vec![0.4, 0.6000000000000001]);
    }
}
