"""Pure-numpy correctness oracle for the forest-traversal kernel.

Deliberately written as the naive per-example, per-tree pointer-chasing
loop (Algorithm 1 of the paper) so it shares no code or vectorization
structure with the Pallas kernel it validates — the "simple module as
ground truth for the optimized module" pattern of §2.3.
"""

import numpy as np


def forest_traverse_ref(features, node_feature, node_threshold, node_pos,
                        node_neg, leaf_value, depth):
    """Reference traversal. Same contract as kernels.forest.forest_traverse.

    Note: `depth` bounds the number of traversal steps exactly like the
    kernel's fori_loop, so trees deeper than `depth` produce the same
    (truncated) result in both implementations.
    """
    num_trees, _ = node_feature.shape
    batch = features.shape[0]
    out = np.zeros((num_trees, batch), dtype=np.float32)
    for t in range(num_trees):
        for b in range(batch):
            idx = 0
            for _ in range(depth):
                f = node_feature[t, idx]
                if f < 0:
                    break
                if features[b, f] >= node_threshold[t, idx]:
                    idx = node_pos[t, idx]
                else:
                    idx = node_neg[t, idx]
            out[t, b] = leaf_value[t, idx]
    return out


def random_forest_tensors(rng, num_trees, num_nodes, num_features, *,
                          max_depth=8):
    """Generates valid random padded forest tensors for testing.

    Trees are built top-down with contiguous child allocation, so every
    index is in range and every path terminates within `max_depth`.
    """
    node_feature = -np.ones((num_trees, num_nodes), dtype=np.int32)
    node_threshold = np.zeros((num_trees, num_nodes), dtype=np.float32)
    node_pos = np.zeros((num_trees, num_nodes), dtype=np.int32)
    node_neg = np.zeros((num_trees, num_nodes), dtype=np.int32)
    leaf_value = rng.normal(size=(num_trees, num_nodes)).astype(np.float32)

    for t in range(num_trees):
        next_free = [1]
        frontier = [(0, 0)]  # (node, depth)
        while frontier:
            node, depth = frontier.pop()
            # Leaf if too deep, out of space, or by chance.
            if depth >= max_depth or next_free[0] + 2 > num_nodes or rng.random() < 0.3:
                continue  # stays a leaf (node_feature == -1)
            node_feature[t, node] = rng.integers(0, num_features)
            node_threshold[t, node] = rng.normal()
            pos, neg = next_free[0], next_free[0] + 1
            next_free[0] += 2
            node_pos[t, node] = pos
            node_neg[t, node] = neg
            frontier.append((pos, depth + 1))
            frontier.append((neg, depth + 1))
    return node_feature, node_threshold, node_pos, node_neg, leaf_value
