//! Minimal JSON value model, serializer and parser.
//!
//! Used for model export/import and report emission (serde is unavailable
//! in this offline environment). Supports the full JSON grammar with the
//! usual Rust conveniences.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic —
/// which the model-format backwards-compatibility tests rely on.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors returning descriptive errors — part of the
    /// "well-written error messages" principle (§2.1): a malformed model
    /// file reports *which* field is missing.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key)
            .ok_or_else(|| format!("missing required JSON field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| format!("JSON field '{key}' is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| format!("JSON field '{key}' is not a string"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], String> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| format!("JSON field '{key}' is not an array"))
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        // RFC 8785-ish shortest roundtrip via Rust default.
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no NaN/Inf; encode as null (documented).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Nesting cap for the recursive-descent parser. Any legitimate payload
/// in this codebase is a handful of levels deep; without the cap a
/// hostile input of `[[[[…` recurses once per byte and overflows the
/// stack — which in the TCP server would abort the whole process from a
/// single malformed request line.
const MAX_PARSE_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting while parsing (see [`MAX_PARSE_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_PARSE_DEPTH {
            return Err(self.err("JSON nested deeper than 128 levels"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let v = self.object_inner()?;
        self.depth -= 1;
        Ok(v)
    }

    fn object_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let v = self.array_inner()?;
        self.depth -= 1;
        Ok(v)
    }

    fn array_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        // Surrogate pairs: join if a high surrogate.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let d =
                                    self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                                low = low * 16
                                    + (d as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex digit"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multibyte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_depth_is_capped_not_stack_overflowed() {
        // Hostile nesting errors out instead of recursing per byte and
        // overflowing the stack (the TCP server parses untrusted lines).
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.to_string().contains("128 levels"), "{err}");
        let deep_obj = r#"{"a":"#.repeat(1000) + "1" + &"}".repeat(1000);
        assert!(Json::parse(&deep_obj).is_err());
        // At the cap still parses; siblings do not accumulate depth.
        let ok = "[".repeat(128) + &"]".repeat(128);
        assert!(Json::parse(&ok).is_ok(), "128 levels must parse");
        let wide = format!("[{}[1]]", "[1],".repeat(500));
        assert!(Json::parse(&wide).is_ok(), "wide-but-shallow must parse");
    }

    #[test]
    fn roundtrip_compound() {
        let mut obj = Json::obj();
        obj.set("name", Json::Str("gbt \"v1\"\n".into()))
            .set("trees", Json::Num(186.0))
            .set("loss", Json::Num(0.578763))
            .set("ok", Json::Bool(true))
            .set("nothing", Json::Null)
            .set("arr", Json::from_f64s(&[1.0, -2.5, 3e-4]));
        let text = obj.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, obj);
        // Compact form round-trips too.
        let parsed2 = Json::parse(&obj.to_string()).unwrap();
        assert_eq!(parsed2, obj);
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":[true,false,null]}],"c":-1.5e3}"#).unwrap();
        assert_eq!(v.req_f64("c").unwrap(), -1500.0);
        let a = v.req_arr("a").unwrap();
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""café 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn errors_are_positioned() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn missing_field_error_names_field() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        let err = v.req_str("version").unwrap_err();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-0.5).to_string(), "-0.5");
    }
}
