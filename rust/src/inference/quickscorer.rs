//! QuickScorer engine (Lucchese et al., SIGIR 2015): branch-free forest
//! traversal for trees with up to 64 leaves.
//!
//! Leaves are numbered in positive-first DFS order; every internal node
//! carries a 64-bit mask clearing the leaves of its *positive* subtree.
//! Scoring an example ANDs the masks of all *false* nodes; the exit leaf
//! is the lowest surviving bit. Numerical conditions are grouped per
//! feature and sorted by threshold so the false set is a suffix found by
//! binary search — the property that makes QuickScorer fast.
//!
//! The batch path is block-wise, as the paper intends (their "BWQS"
//! variant): bitvectors for a whole [`BLOCK_SIZE`]-row block live in one
//! scratch array, and the engine iterates feature-major — each feature's
//! sorted node list and the feature's *column* are streamed once per
//! block, so both stay cache-resident while the 64 examples are scored.
//!
//! Two block kernels exist. The *scalar* kernel (`score_block`) walks
//! rows inside each feature, binary-searching the sorted node list per
//! row; it is the correctness reference. The *lane* kernel
//! (`score_block_lanes`) flips to node-major: bitvectors are held
//! tree-major (`vt[tree * BLOCK_SIZE + row]`) so each node's threshold
//! sweep is one branch-free compare-select over the 64-row block and its
//! mask lands with one contiguous AND-reduction over 64 words — both
//! straight-line loops the compiler auto-vectorizes. The block's min/max
//! feature value prunes the node list first: nodes at or below the min
//! are true everywhere (skipped), nodes above the max are false
//! everywhere (unconditional AND). Bitwise AND commutes, so the two
//! kernels produce bit-identical bitvectors. The `simd` cargo feature
//! selects the default kernel; [`QuickScorerEngine::set_simd`] overrides
//! it at runtime.

use super::{Aggregate, BLOCK_SIZE, ColumnAccess, InferenceEngine};
use crate::dataset::{AttrValue, Dataset, Observation, MISSING_BOOL, MISSING_CAT};
use crate::model::forest::{GradientBoostedTreesModel, RandomForestModel};
use crate::model::tree::{bitmap_contains, Condition, DecisionTree};
use crate::model::{Model, Task};
use std::ops::Range;

/// A numerical (Higher) node: false iff `x < threshold`.
struct NumericalNode {
    threshold: f32,
    tree: u32,
    mask: u64,
    missing_to_positive: bool,
}

/// A categorical (ContainsBitmap) node.
struct CategoricalNode {
    tree: u32,
    mask: u64,
    bitmap: Vec<u64>,
    missing_to_positive: bool,
}

/// A boolean (IsTrue) node.
struct BooleanNode {
    tree: u32,
    mask: u64,
    missing_to_positive: bool,
}

/// ANDs `mask` into every lane of `tree`'s row in the tree-major
/// bitvector scratch — the "false for the whole block" case shared by the
/// missing-column and unconditional sweeps of `score_block_lanes`.
#[inline]
fn and_all_lanes(vt: &mut [u64], tree: u32, bs: usize, mask: u64) {
    for slot in &mut vt[tree as usize * BLOCK_SIZE..][..bs] {
        *slot &= mask;
    }
}

pub struct QuickScorerEngine {
    /// Numerical nodes grouped by attribute, sorted by threshold asc.
    numerical: Vec<(usize, Vec<NumericalNode>)>,
    categorical: Vec<(usize, Vec<CategoricalNode>)>,
    boolean: Vec<(usize, Vec<BooleanNode>)>,
    /// `leaf_values[tree][leaf * leaf_dim .. +leaf_dim]`.
    leaf_values: Vec<Vec<f32>>,
    leaf_dim: usize,
    num_trees: usize,
    aggregate: Aggregate,
    /// Whether `predict_batch` scores blocks with the lane kernel.
    /// Defaults to the `simd` cargo feature.
    simd: bool,
}

impl QuickScorerEngine {
    /// Compiles the model if every tree has ≤ 64 leaves and only
    /// QuickScorer-compatible conditions (Higher/ContainsBitmap/IsTrue).
    pub fn compile(model: &dyn Model) -> Option<QuickScorerEngine> {
        let (trees, leaf_dim, aggregate): (&[DecisionTree], usize, Aggregate) =
            if let Some(m) = model.as_any().downcast_ref::<RandomForestModel>() {
                let classes = match m.task {
                    Task::Classification => m.spec.columns[m.label_col].vocab_size(),
                    Task::Regression => 1,
                };
                let agg = match m.task {
                    Task::Classification => Aggregate::RfAverage {
                        num_classes: classes,
                        winner_take_all: m.winner_take_all,
                    },
                    Task::Regression => Aggregate::RfRegression,
                };
                (&m.trees, classes, agg)
            } else if let Some(m) =
                model.as_any().downcast_ref::<GradientBoostedTreesModel>()
            {
                (
                    &m.trees,
                    1,
                    Aggregate::Gbt {
                        loss: m.loss,
                        dim: m.trees_per_iter,
                        initial: m.initial_predictions.clone(),
                    },
                )
            } else {
                return None;
            };

        let mut numerical: std::collections::BTreeMap<usize, Vec<NumericalNode>> =
            Default::default();
        let mut categorical: std::collections::BTreeMap<usize, Vec<CategoricalNode>> =
            Default::default();
        let mut boolean: std::collections::BTreeMap<usize, Vec<BooleanNode>> =
            Default::default();
        let mut leaf_values: Vec<Vec<f32>> = Vec::with_capacity(trees.len());

        for (tree_idx, t) in trees.iter().enumerate() {
            if t.num_leaves() > 64 {
                return None;
            }
            // Positive-first DFS: assign leaf numbers and positive-subtree
            // ranges.
            let mut values = vec![0.0f32; t.num_leaves() * leaf_dim];
            let mut next_leaf = 0u32;
            // Iterative DFS with explicit post-processing of ranges.
            // range_of[node] = (first_leaf, last_leaf_exclusive) of subtree.
            fn dfs(
                t: &DecisionTree,
                idx: usize,
                next_leaf: &mut u32,
                values: &mut [f32],
                leaf_dim: usize,
                out: &mut Vec<(usize, u32, u32)>, // (node, pos_start, pos_end)
            ) -> Result<(u32, u32), ()> {
                let node = &t.nodes[idx];
                match &node.condition {
                    None => {
                        let leaf = *next_leaf;
                        *next_leaf += 1;
                        for (k, &v) in node.value.iter().enumerate().take(leaf_dim) {
                            values[leaf as usize * leaf_dim + k] = v;
                        }
                        Ok((leaf, leaf + 1))
                    }
                    Some(c) => {
                        if !matches!(
                            c,
                            Condition::Higher { .. }
                                | Condition::ContainsBitmap { .. }
                                | Condition::IsTrue { .. }
                        ) {
                            return Err(());
                        }
                        let (ps, pe) =
                            dfs(t, node.positive as usize, next_leaf, values, leaf_dim, out)?;
                        let (_ns, ne) =
                            dfs(t, node.negative as usize, next_leaf, values, leaf_dim, out)?;
                        out.push((idx, ps, pe));
                        Ok((ps, ne))
                    }
                }
            }
            let mut internal = Vec::new();
            if dfs(t, 0, &mut next_leaf, &mut values, leaf_dim, &mut internal).is_err() {
                return None;
            }
            leaf_values.push(values);

            for (node_idx, ps, pe) in internal {
                let node = &t.nodes[node_idx];
                // Mask clears the positive-subtree leaves [ps, pe).
                let width = pe - ps;
                let bits = if width >= 64 { !0u64 } else { ((1u64 << width) - 1) << ps };
                let mask = !bits;
                match node.condition.as_ref().unwrap() {
                    Condition::Higher { attr, threshold } => {
                        numerical.entry(*attr).or_default().push(NumericalNode {
                            threshold: *threshold,
                            tree: tree_idx as u32,
                            mask,
                            missing_to_positive: node.missing_to_positive,
                        });
                    }
                    Condition::ContainsBitmap { attr, bitmap } => {
                        categorical.entry(*attr).or_default().push(CategoricalNode {
                            tree: tree_idx as u32,
                            mask,
                            bitmap: bitmap.clone(),
                            missing_to_positive: node.missing_to_positive,
                        });
                    }
                    Condition::IsTrue { attr } => {
                        boolean.entry(*attr).or_default().push(BooleanNode {
                            tree: tree_idx as u32,
                            mask,
                            missing_to_positive: node.missing_to_positive,
                        });
                    }
                    _ => unreachable!(),
                }
            }
        }

        let numerical: Vec<(usize, Vec<NumericalNode>)> = numerical
            .into_iter()
            .map(|(attr, mut nodes)| {
                nodes.sort_by(|a, b| a.threshold.partial_cmp(&b.threshold).unwrap());
                (attr, nodes)
            })
            .collect();

        Some(QuickScorerEngine {
            numerical,
            categorical: categorical.into_iter().collect(),
            boolean: boolean.into_iter().collect(),
            leaf_values,
            leaf_dim,
            num_trees: trees.len(),
            aggregate,
            simd: cfg!(feature = "simd"),
        })
    }

    /// Selects the lane-wise (`true`) or scalar (`false`) block kernel for
    /// `predict_batch`. The default follows the `simd` cargo feature; the
    /// scalar kernel always stays available as the correctness reference
    /// and the two are bit-identical (see `prop_simd_lanes_match_scalar`
    /// in `rust/tests/properties.rs`).
    pub fn set_simd(&mut self, on: bool) {
        self.simd = on;
    }

    /// Core scoring: caller supplies per-attribute accessors (per-row
    /// serving path).
    fn score<'a>(
        &self,
        get_num: impl Fn(usize) -> Option<f32>, // None = missing
        get_cat: impl Fn(usize) -> Option<u32>,
        get_bool: impl Fn(usize) -> Option<bool>,
        v: &'a mut [u64],
    ) -> &'a [u64] {
        v.fill(!0u64);
        for (attr, nodes) in &self.numerical {
            match get_num(*attr) {
                Some(x) => {
                    // Nodes are sorted by threshold; false iff x < thr, a
                    // suffix. Binary search for the first false node.
                    let start = nodes.partition_point(|n| n.threshold <= x);
                    for n in &nodes[start..] {
                        v[n.tree as usize] &= n.mask;
                    }
                }
                None => {
                    for n in nodes {
                        if !n.missing_to_positive {
                            v[n.tree as usize] &= n.mask;
                        }
                    }
                }
            }
        }
        for (attr, nodes) in &self.categorical {
            match get_cat(*attr) {
                Some(c) => {
                    for n in nodes {
                        if !bitmap_contains(&n.bitmap, c) {
                            v[n.tree as usize] &= n.mask;
                        }
                    }
                }
                None => {
                    for n in nodes {
                        if !n.missing_to_positive {
                            v[n.tree as usize] &= n.mask;
                        }
                    }
                }
            }
        }
        for (attr, nodes) in &self.boolean {
            match get_bool(*attr) {
                Some(true) => {}
                Some(false) => {
                    for n in nodes {
                        v[n.tree as usize] &= n.mask;
                    }
                }
                None => {
                    for n in nodes {
                        if !n.missing_to_positive {
                            v[n.tree as usize] &= n.mask;
                        }
                    }
                }
            }
        }
        v
    }

    /// Block-wise scoring over columnar storage: `v` holds `bs` bitvector
    /// rows of `num_trees` words each. Feature-major iteration streams
    /// each feature's node list and data column once per block.
    fn score_block(&self, cols: &ColumnAccess, start: usize, bs: usize, v: &mut [u64]) {
        let t = self.num_trees;
        v[..bs * t].fill(!0u64);
        for (attr, nodes) in &self.numerical {
            match cols.num[*attr] {
                Some(vals) => {
                    for bi in 0..bs {
                        let vrow = &mut v[bi * t..(bi + 1) * t];
                        let x = vals[start + bi];
                        if x.is_nan() {
                            for n in nodes {
                                if !n.missing_to_positive {
                                    vrow[n.tree as usize] &= n.mask;
                                }
                            }
                        } else {
                            let cut = nodes.partition_point(|n| n.threshold <= x);
                            for n in &nodes[cut..] {
                                vrow[n.tree as usize] &= n.mask;
                            }
                        }
                    }
                }
                None => {
                    for bi in 0..bs {
                        let vrow = &mut v[bi * t..(bi + 1) * t];
                        for n in nodes {
                            if !n.missing_to_positive {
                                vrow[n.tree as usize] &= n.mask;
                            }
                        }
                    }
                }
            }
        }
        for (attr, nodes) in &self.categorical {
            match cols.cat[*attr] {
                Some(vals) => {
                    for bi in 0..bs {
                        let vrow = &mut v[bi * t..(bi + 1) * t];
                        let c = vals[start + bi];
                        if c == MISSING_CAT {
                            for n in nodes {
                                if !n.missing_to_positive {
                                    vrow[n.tree as usize] &= n.mask;
                                }
                            }
                        } else {
                            for n in nodes {
                                if !bitmap_contains(&n.bitmap, c) {
                                    vrow[n.tree as usize] &= n.mask;
                                }
                            }
                        }
                    }
                }
                None => {
                    for bi in 0..bs {
                        let vrow = &mut v[bi * t..(bi + 1) * t];
                        for n in nodes {
                            if !n.missing_to_positive {
                                vrow[n.tree as usize] &= n.mask;
                            }
                        }
                    }
                }
            }
        }
        for (attr, nodes) in &self.boolean {
            match cols.boolean[*attr] {
                Some(vals) => {
                    for bi in 0..bs {
                        let vrow = &mut v[bi * t..(bi + 1) * t];
                        match vals[start + bi] {
                            1 => {}
                            0 => {
                                for n in nodes {
                                    vrow[n.tree as usize] &= n.mask;
                                }
                            }
                            _ => {
                                debug_assert_eq!(vals[start + bi], MISSING_BOOL);
                                for n in nodes {
                                    if !n.missing_to_positive {
                                        vrow[n.tree as usize] &= n.mask;
                                    }
                                }
                            }
                        }
                    }
                }
                None => {
                    for bi in 0..bs {
                        let vrow = &mut v[bi * t..(bi + 1) * t];
                        for n in nodes {
                            if !n.missing_to_positive {
                                vrow[n.tree as usize] &= n.mask;
                            }
                        }
                    }
                }
            }
        }
    }

    /// Lane-wise block scoring. Bitvectors are kept tree-major in the
    /// `vt` scratch (`vt[tree * BLOCK_SIZE + row]`) so every mask
    /// application is a contiguous AND over the block's words, and the
    /// per-node threshold sweep is one branch-free compare-select over
    /// the block's feature values; the result is transposed into the
    /// row-major `v` layout the aggregation reads. Applies exactly the
    /// same set of (tree, mask) ANDs as `score_block` — AND commutes, so
    /// the outputs are bit-identical.
    fn score_block_lanes(
        &self,
        cols: &ColumnAccess,
        start: usize,
        bs: usize,
        vt: &mut [u64],
        v: &mut [u64],
    ) {
        let t = self.num_trees;
        vt[..t * BLOCK_SIZE].fill(!0u64);
        for (attr, nodes) in &self.numerical {
            match cols.num[*attr] {
                Some(vals) => {
                    let xs = &vals[start..start + bs];
                    if xs.iter().any(|x| x.is_nan()) {
                        // NaN rows route by the per-node missing policy, so
                        // threshold pruning is off: branch-free select per
                        // lane over the full node list.
                        for n in nodes {
                            let lanes = &mut vt[n.tree as usize * BLOCK_SIZE..][..bs];
                            for (x, slot) in xs.iter().zip(lanes.iter_mut()) {
                                let falsify = if x.is_nan() {
                                    !n.missing_to_positive
                                } else {
                                    *x < n.threshold
                                };
                                // keep = all-ones (no-op) unless falsified.
                                *slot &= n.mask | (falsify as u64).wrapping_sub(1);
                            }
                        }
                    } else {
                        let mut min = xs[0];
                        let mut max = xs[0];
                        for &x in xs {
                            min = min.min(x);
                            max = max.max(x);
                        }
                        // Same predicate as the scalar kernel's per-row
                        // binary search: nodes[..lo] hold threshold <= min
                        // (true for every row, skipped); nodes[hi..] hold
                        // threshold > max (false for every row).
                        let lo = nodes.partition_point(|n| n.threshold <= min);
                        let hi = nodes.partition_point(|n| n.threshold <= max);
                        for n in &nodes[lo..hi] {
                            let thr = n.threshold;
                            let lanes = &mut vt[n.tree as usize * BLOCK_SIZE..][..bs];
                            for (x, slot) in xs.iter().zip(lanes.iter_mut()) {
                                *slot &= n.mask | ((*x < thr) as u64).wrapping_sub(1);
                            }
                        }
                        for n in &nodes[hi..] {
                            and_all_lanes(vt, n.tree, bs, n.mask);
                        }
                    }
                }
                None => {
                    for n in nodes {
                        if !n.missing_to_positive {
                            and_all_lanes(vt, n.tree, bs, n.mask);
                        }
                    }
                }
            }
        }
        for (attr, nodes) in &self.categorical {
            match cols.cat[*attr] {
                Some(vals) => {
                    let cs = &vals[start..start + bs];
                    for n in nodes {
                        let lanes = &mut vt[n.tree as usize * BLOCK_SIZE..][..bs];
                        for (c, slot) in cs.iter().zip(lanes.iter_mut()) {
                            let falsify = if *c == MISSING_CAT {
                                !n.missing_to_positive
                            } else {
                                !bitmap_contains(&n.bitmap, *c)
                            };
                            if falsify {
                                *slot &= n.mask;
                            }
                        }
                    }
                }
                None => {
                    for n in nodes {
                        if !n.missing_to_positive {
                            and_all_lanes(vt, n.tree, bs, n.mask);
                        }
                    }
                }
            }
        }
        for (attr, nodes) in &self.boolean {
            match cols.boolean[*attr] {
                Some(vals) => {
                    let bools = &vals[start..start + bs];
                    for n in nodes {
                        let lanes = &mut vt[n.tree as usize * BLOCK_SIZE..][..bs];
                        for (b, slot) in bools.iter().zip(lanes.iter_mut()) {
                            let falsify = match *b {
                                1 => false,
                                0 => true,
                                _ => {
                                    debug_assert_eq!(*b, MISSING_BOOL);
                                    !n.missing_to_positive
                                }
                            };
                            if falsify {
                                *slot &= n.mask;
                            }
                        }
                    }
                }
                None => {
                    for n in nodes {
                        if !n.missing_to_positive {
                            and_all_lanes(vt, n.tree, bs, n.mask);
                        }
                    }
                }
            }
        }
        // Transpose the tree-major scratch into the row-major layout the
        // aggregation reads.
        for bi in 0..bs {
            for ti in 0..t {
                v[bi * t + ti] = vt[ti * BLOCK_SIZE + bi];
            }
        }
    }

    /// Aggregates one example's bitvectors into `out`
    /// (`out.len() == output_dim()`); `scores` is `aggregate.score_dim()`
    /// scratch.
    fn aggregate_bitvectors_into(&self, v: &[u64], scores: &mut [f64], out: &mut [f64]) {
        match &self.aggregate {
            Aggregate::RfAverage { winner_take_all, .. } => {
                out.fill(0.0);
                for (t, &bits) in v.iter().enumerate() {
                    let leaf = bits.trailing_zeros() as usize;
                    let lv = &self.leaf_values[t]
                        [leaf * self.leaf_dim..(leaf + 1) * self.leaf_dim];
                    if *winner_take_all {
                        let mut best = 0usize;
                        for (i, &x) in lv.iter().enumerate().skip(1) {
                            if x > lv[best] {
                                best = i;
                            }
                        }
                        out[best] += 1.0;
                    } else {
                        for (a, &x) in out.iter_mut().zip(lv) {
                            *a += x as f64;
                        }
                    }
                }
                let n = v.len().max(1) as f64;
                for a in out.iter_mut() {
                    *a /= n;
                }
            }
            Aggregate::RfRegression => {
                let sum: f64 = v
                    .iter()
                    .enumerate()
                    .map(|(t, &bits)| {
                        self.leaf_values[t][bits.trailing_zeros() as usize] as f64
                    })
                    .sum();
                out[0] = sum / v.len().max(1) as f64;
            }
            Aggregate::Gbt { loss, dim, initial } => {
                scores.copy_from_slice(initial);
                for (t, &bits) in v.iter().enumerate() {
                    let leaf = bits.trailing_zeros() as usize;
                    scores[t % dim] += self.leaf_values[t][leaf] as f64;
                }
                Aggregate::apply_gbt_link(*loss, scores, out);
            }
        }
    }
}

impl InferenceEngine for QuickScorerEngine {
    fn name(&self) -> String {
        let kind = match self.aggregate {
            Aggregate::Gbt { .. } => "GradientBoostedTrees",
            _ => "RandomForest",
        };
        // Stable across kernel choice: `benchmark_inference` tags its
        // scalar-kernel variants itself, so BENCH_inference.json keys stay
        // comparable across feature configs.
        format!("{kind}QuickScorer")
    }

    fn output_dim(&self) -> usize {
        self.aggregate.output_dim()
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        let mut v = vec![!0u64; self.num_trees];
        self.score(
            |a| match &obs[a] {
                AttrValue::Num(x) if !x.is_nan() => Some(*x),
                _ => None,
            },
            |a| match &obs[a] {
                AttrValue::Cat(c) => Some(*c),
                _ => None,
            },
            |a| match &obs[a] {
                AttrValue::Bool(b) => Some(*b),
                _ => None,
            },
            &mut v,
        );
        let mut scores = vec![0.0f64; self.aggregate.score_dim()];
        let mut out = vec![0.0f64; self.output_dim()];
        self.aggregate_bitvectors_into(&v, &mut scores, &mut out);
        out
    }

    fn predict_batch(&self, ds: &Dataset, rows: Range<usize>, out: &mut [f64]) {
        let dim = self.output_dim();
        debug_assert_eq!(out.len(), rows.len() * dim);
        let cols = ColumnAccess::new(ds);
        let t = self.num_trees;
        // Per-batch scratch: bitvectors for a whole block (plus the lane
        // kernel's tree-major view) and the GBT score vector; the per-row
        // loop is allocation-free.
        let mut v = vec![!0u64; BLOCK_SIZE * t];
        let mut vt = if self.simd { vec![!0u64; t * BLOCK_SIZE] } else { Vec::new() };
        let mut scores = vec![0.0f64; self.aggregate.score_dim()];
        let mut start = rows.start;
        let mut out_off = 0usize;
        while start < rows.end {
            let bs = BLOCK_SIZE.min(rows.end - start);
            if self.simd {
                self.score_block_lanes(&cols, start, bs, &mut vt, &mut v);
            } else {
                self.score_block(&cols, start, bs, &mut v);
            }
            for bi in 0..bs {
                let o = out_off + bi * dim;
                self.aggregate_bitvectors_into(
                    &v[bi * t..(bi + 1) * t],
                    &mut scores,
                    &mut out[o..o + dim],
                );
            }
            start += bs;
            out_off += bs * dim;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::random_forest::RandomForestConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};

    fn close(a: &[f64], b: &[f64]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-9, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn quickscorer_matches_naive_gbt() {
        let ds = synthetic::adult_like(300, 141);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 12;
        cfg.max_depth = 5; // <= 32 leaves
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let qs = QuickScorerEngine::compile(model.as_ref()).expect("compatible");
        for r in 0..ds.num_rows() {
            close(&qs.predict_row(&ds.row(r)), &model.predict_ds_row(&ds, r));
        }
        let batch = qs.predict_dataset(&ds);
        for r in 0..ds.num_rows() {
            close(&batch[r], &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn quickscorer_batch_unaligned_range() {
        // 300 rows; score an offset range crossing block boundaries.
        let ds = synthetic::adult_like(300, 142);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 9;
        cfg.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let qs = QuickScorerEngine::compile(model.as_ref()).expect("compatible");
        let dim = qs.output_dim();
        let range = 31..230;
        let mut out = vec![0.0f64; (230 - 31) * dim];
        qs.predict_batch(&ds, range.clone(), &mut out);
        for (i, r) in range.enumerate() {
            close(&out[i * dim..(i + 1) * dim], &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn quickscorer_matches_small_rf() {
        let ds = synthetic::adult_like(200, 143);
        let mut cfg = RandomForestConfig::new("income");
        cfg.num_trees = 6;
        cfg.max_depth = 5;
        cfg.compute_oob = false;
        let model = RandomForestLearner::new(cfg).train(&ds).unwrap();
        let qs = QuickScorerEngine::compile(model.as_ref()).expect("compatible");
        for r in 0..ds.num_rows() {
            close(&qs.predict_row(&ds.row(r)), &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn lane_kernel_matches_scalar_bitwise() {
        let ds = synthetic::adult_like(300, 149);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 10;
        cfg.max_depth = 5;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let mut scalar = QuickScorerEngine::compile(model.as_ref()).expect("compatible");
        scalar.set_simd(false);
        let mut lanes = QuickScorerEngine::compile(model.as_ref()).expect("compatible");
        lanes.set_simd(true);
        let dim = scalar.output_dim();
        let n = ds.num_rows();
        let mut a = vec![0.0f64; n * dim];
        let mut b = vec![0.0f64; n * dim];
        scalar.predict_batch(&ds, 0..n, &mut a);
        lanes.predict_batch(&ds, 0..n, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "scalar vs lane kernel");
        }
    }

    #[test]
    fn deep_trees_rejected() {
        // Depth-16 RF trees typically exceed 64 leaves -> incompatible,
        // "with the obvious caveat that it does not extend to larger
        // trees" (§3.7).
        let ds = synthetic::adult_like(2000, 145);
        let mut cfg = RandomForestConfig::new("income");
        cfg.num_trees = 2;
        cfg.max_depth = 16;
        cfg.min_examples = 1;
        cfg.compute_oob = false;
        let model = RandomForestLearner::new(cfg).train(&ds).unwrap();
        let rf = model.as_any().downcast_ref::<RandomForestModel>().unwrap();
        if rf.trees.iter().any(|t| t.num_leaves() > 64) {
            assert!(QuickScorerEngine::compile(model.as_ref()).is_none());
        }
    }

    #[test]
    fn oblique_conditions_rejected() {
        let ds = synthetic::adult_like(150, 147);
        let mut cfg = GbtConfig::benchmark_rank1("income");
        cfg.num_trees = 3;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        assert!(QuickScorerEngine::compile(model.as_ref()).is_none());
    }

    #[test]
    fn multiclass_gbt() {
        let spec = synthetic::spec_by_name("Iris").unwrap();
        let ds = synthetic::generate(spec, 3, &synthetic::GenOptions::default());
        let mut cfg = GbtConfig::new("label");
        cfg.num_trees = 6;
        cfg.max_depth = 3;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let qs = QuickScorerEngine::compile(model.as_ref()).expect("compatible");
        for r in 0..ds.num_rows() {
            close(&qs.predict_row(&ds.row(r)), &model.predict_ds_row(&ds, r));
        }
    }
}
