//! Distributed computation backends (§3.9): the API is modular — the paper
//! ships gRPC, TF Parameter Server and an in-process debugging backend.
//! This reproduction ships the in-process backend (the paper's third
//! implementation, for development/debugging/unit-testing: breakpoints
//! work, execution is step-by-step deterministic) and a thread backend
//! that simulates concurrent multi-worker execution.

use super::WorkerState;

/// Runs one computation on every worker and returns the per-worker
/// results in worker order.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;
    fn map_workers<R: Send>(
        &self,
        workers: &mut [WorkerState],
        f: &(dyn Fn(&mut WorkerState) -> R + Sync),
    ) -> Vec<R>
    where
        Self: Sized;
}

/// Sequential in-process execution: "simulates multi-worker computation in
/// a single process, making it easy to use breakpoints or execute the
/// distributed algorithm step by step" (§3.9).
pub struct InProcessBackend;

impl Backend for InProcessBackend {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn map_workers<R: Send>(
        &self,
        workers: &mut [WorkerState],
        f: &(dyn Fn(&mut WorkerState) -> R + Sync),
    ) -> Vec<R> {
        workers.iter_mut().map(f).collect()
    }
}

/// Scoped-thread execution: each worker runs on its own OS thread per
/// round (synchronous rounds, like the paper's multi-round hierarchical
/// synchronization).
pub struct ThreadBackend;

impl Backend for ThreadBackend {
    fn name(&self) -> &'static str {
        "threads"
    }

    fn map_workers<R: Send>(
        &self,
        workers: &mut [WorkerState],
        f: &(dyn Fn(&mut WorkerState) -> R + Sync),
    ) -> Vec<R> {
        std::thread::scope(|s| {
            let handles: Vec<_> =
                workers.iter_mut().map(|w| s.spawn(move || f(w))).collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::splitter::NodeScratch;
    use crate::utils::rng::Rng;

    fn workers(n: usize) -> Vec<WorkerState> {
        let ds = crate::dataset::synthetic::adult_like(20, 1);
        (0..n)
            .map(|i| WorkerState {
                features: vec![i],
                scratch: NodeScratch::new(ds.num_rows()),
                rng: Rng::seed_from_u64(i as u64),
            })
            .collect()
    }

    #[test]
    fn in_process_order_preserved() {
        let mut ws = workers(4);
        let out = InProcessBackend.map_workers(&mut ws, &|w| w.features[0]);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn threads_order_preserved() {
        let mut ws = workers(4);
        let out = ThreadBackend.map_workers(&mut ws, &|w| w.features[0]);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }
}
