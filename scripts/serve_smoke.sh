#!/usr/bin/env bash
# Serving smoke test (`make serve-smoke`): train a tiny model, start
# `ydf serve` on an ephemeral port, fire single-row / multi-row /
# malformed requests plus the command set, check every response, and shut
# the server down through the protocol. Exits non-zero on any mismatch.
set -euo pipefail

BIN=${BIN:-./target/release/ydf}
if [ ! -x "$BIN" ]; then
    echo "serve-smoke: $BIN not found; run 'cargo build --release' first" >&2
    exit 1
fi

TMP=$(mktemp -d)
SERVER_PID=""
cleanup() {
    [ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null || true
    rm -rf "$TMP"
}
trap cleanup EXIT

echo "serve-smoke: training a tiny model"
"$BIN" synth --name=Iris --output=csv:"$TMP/iris.csv" >/dev/null
"$BIN" train --dataset=csv:"$TMP/iris.csv" --label=label \
    --learner=GRADIENT_BOOSTED_TREES --param:num_trees=5 \
    --output="$TMP/model.json" >/dev/null

echo "serve-smoke: starting server on an ephemeral port"
"$BIN" serve --model="$TMP/model.json" --port=0 --max-delay-ms=1 \
    >"$TMP/serve.log" 2>&1 &
SERVER_PID=$!

PORT=""
for _ in $(seq 100); do
    PORT=$(sed -n 's/^listening on .*:\([0-9][0-9]*\)$/\1/p' "$TMP/serve.log" | head -1)
    [ -n "$PORT" ] && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "serve-smoke: server died during startup:" >&2
        cat "$TMP/serve.log" >&2
        exit 1
    fi
    sleep 0.1
done
if [ -z "$PORT" ]; then
    echo "serve-smoke: server did not report its port:" >&2
    cat "$TMP/serve.log" >&2
    exit 1
fi
echo "serve-smoke: server is up on port $PORT"

python3 - "$PORT" <<'EOF'
import json, socket, sys

port = int(sys.argv[1])

def rpc(line):
    s = socket.create_connection(("127.0.0.1", port), timeout=10)
    s.sendall((line + "\n").encode())
    resp = s.makefile().readline()
    s.close()
    return json.loads(resp)

checks = 0
def check(cond, what):
    global checks
    if not cond:
        raise SystemExit(f"serve-smoke: FAILED: {what}")
    checks += 1
    print(f"serve-smoke: ok: {what}")

health = rpc(json.dumps({"cmd": "health"}))
check(health.get("ok") is True, "health reports ok")
check("engine" in health, "health names the engine")

spec = rpc(json.dumps({"cmd": "spec"}))
features = spec["features"]
classes = spec["classes"]
check(len(features) > 0 and len(classes) > 0, "spec lists features and classes")

# Build a generic valid row from the served dataspec: mean-ish numbers
# for numericals, the first dictionary entry for categoricals.
def sample_row():
    row = {}
    for f in features:
        if f["semantic"] == "NUMERICAL":
            row[f["name"]] = 1.0
        elif "dictionary" in f and f["dictionary"]:
            row[f["name"]] = f["dictionary"][0]
    return row

single = rpc(json.dumps({"rows": [sample_row()]}))
preds = single["predictions"]
check(len(preds) == 1 and len(preds[0]) == len(classes),
      "single-row request returns one prediction per class")
check(abs(sum(preds[0]) - 1.0) < 1e-9, "probabilities sum to 1")

multi = rpc(json.dumps({"rows": [sample_row(), {}, sample_row()]}))
check(len(multi["predictions"]) == 3,
      "multi-row request (incl. all-missing row) returns one prediction per row")

bad = rpc("this is { not json")
check("error" in bad, "malformed JSON answers with an in-band error")

unknown = rpc(json.dumps({"rows": [{"no_such_feature": 1}]}))
check("no_such_feature" in unknown.get("error", ""),
      "unknown feature error names the offender")

stats = rpc(json.dumps({"cmd": "stats"}))
check(stats["requests"] >= 2, "stats counted the successful requests")
check(stats["errors"] >= 2, "stats counted the error responses")

bye = rpc(json.dumps({"cmd": "shutdown"}))
check(bye.get("ok") is True, "shutdown acknowledged")
print(f"serve-smoke: all {checks} checks passed")
EOF

echo "serve-smoke: waiting for server to exit"
for _ in $(seq 100); do
    kill -0 "$SERVER_PID" 2>/dev/null || break
    sleep 0.1
done
if kill -0 "$SERVER_PID" 2>/dev/null; then
    echo "serve-smoke: server still running after shutdown command" >&2
    exit 1
fi
SERVER_PID=""
grep -q "server stopped" "$TMP/serve.log" || {
    echo "serve-smoke: server log missing clean-stop marker" >&2
    exit 1
}
echo "serve-smoke: PASS"
