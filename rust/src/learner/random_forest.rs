//! Random Forest learner (Breiman 2001) with out-of-bag self-evaluation
//! (§3.6). Default hyper-parameters follow Appendix C.1.

use super::decision_tree::{grow_tree, AttrSampling, GrowingStrategy, TreeConfig};
use super::{classification_labels, feature_columns, regression_targets, Learner};
use crate::dataset::Dataset;
use crate::model::forest::RandomForestModel;
use crate::model::{Model, SelfEvaluation, Task};
use crate::splitter::score::Labels;
use crate::splitter::{
    CategoricalSplit, ColumnIndex, ObliqueNormalization, RowArena, SplitAxis, SplitEngine,
    SplitterConfig,
};
use crate::utils::pool::parallel_map;
use crate::utils::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

/// Random Forest configuration. Defaults = Appendix C.1 "Random Forest
/// default hyper-parameters" (categorical CART, local growth, depth 16,
/// min 5 examples, √p attribute sampling, axis-aligned splits).
#[derive(Clone, Debug)]
pub struct RandomForestConfig {
    pub label: String,
    pub task: Task,
    pub num_trees: usize,
    pub max_depth: usize,
    pub min_examples: usize,
    pub attr_sampling: AttrSampling,
    pub splitter: SplitterConfig,
    pub growing: GrowingStrategy,
    /// Sample the training set with replacement per tree.
    pub bootstrap: bool,
    /// Majority vote (YDF default) vs probability averaging.
    pub winner_take_all: bool,
    /// Compute the OOB self-evaluation (§3.6).
    pub compute_oob: bool,
    /// Trees trained concurrently (`parallel_map` over trees; bit-identical
    /// to sequential — per-tree seeds, order-independent assembly).
    /// Defaults to [`super::train_threads`] (the `YDF_TRAIN_THREADS`
    /// override, else 1).
    pub num_threads: usize,
    pub seed: u64,
}

impl RandomForestConfig {
    pub fn new(label: &str) -> RandomForestConfig {
        RandomForestConfig {
            label: label.to_string(),
            task: Task::Classification,
            num_trees: 300,
            max_depth: 16,
            min_examples: 5,
            attr_sampling: AttrSampling::Sqrt, // Breiman's rule of thumb
            splitter: SplitterConfig::default(),
            growing: GrowingStrategy::Local,
            bootstrap: true,
            winner_take_all: true,
            compute_oob: true,
            num_threads: super::train_threads(),
            seed: 1234,
        }
    }

    /// benchmark_rank1@v1 template (Appendix C.1): random categorical
    /// splits + sparse oblique projections with min-max normalization.
    pub fn benchmark_rank1(label: &str) -> RandomForestConfig {
        let mut cfg = RandomForestConfig::new(label);
        cfg.splitter.categorical = CategoricalSplit::Random { trials: 32 };
        cfg.splitter.axis = SplitAxis::SparseOblique {
            num_projections_exponent: 1.0,
            normalization: ObliqueNormalization::MinMax,
        };
        cfg
    }
}

pub struct RandomForestLearner {
    pub config: RandomForestConfig,
}

impl RandomForestLearner {
    pub fn new(config: RandomForestConfig) -> Self {
        RandomForestLearner { config }
    }

    pub fn default_config(label: &str) -> Self {
        RandomForestLearner::new(RandomForestConfig::new(label))
    }
}

/// Registry factory (§3.5).
pub fn factory(
    label: &str,
    params: &HashMap<String, String>,
) -> Result<Box<dyn Learner>, String> {
    let mut cfg = RandomForestConfig::new(label);
    cfg.num_trees = super::parse_param(params, "num_trees", cfg.num_trees)?;
    cfg.max_depth = super::parse_param(params, "max_depth", cfg.max_depth)?;
    cfg.min_examples = super::parse_param(params, "min_examples", cfg.min_examples)?;
    cfg.seed = super::parse_param(params, "seed", cfg.seed)?;
    cfg.num_threads = super::parse_param(params, "num_threads", cfg.num_threads)?;
    cfg.winner_take_all =
        super::parse_param(params, "winner_take_all", cfg.winner_take_all)?;
    if let Some(t) = params.get("task") {
        cfg.task = match t.as_str() {
            "CLASSIFICATION" => Task::Classification,
            "REGRESSION" => Task::Regression,
            other => return Err(format!("unknown task '{other}'")),
        };
    }
    if params.get("template").map(|s| s.as_str()) == Some("benchmark_rank1@v1") {
        let label_owned = cfg.label.clone();
        let mut c = RandomForestConfig::benchmark_rank1(&label_owned);
        c.num_trees = cfg.num_trees;
        c.task = cfg.task;
        c.seed = cfg.seed;
        c.num_threads = cfg.num_threads;
        cfg = c;
    }
    Ok(Box::new(RandomForestLearner::new(cfg)))
}

impl Learner for RandomForestLearner {
    fn name(&self) -> &'static str {
        "RANDOM_FOREST"
    }

    fn label(&self) -> &str {
        &self.config.label
    }

    fn train_with_valid(
        &self,
        ds: &Dataset,
        _valid: Option<&Dataset>, // RF self-evaluates out-of-bag instead
    ) -> Result<Box<dyn Model>, String> {
        let cfg = &self.config;
        let n = ds.num_rows();
        if n == 0 {
            return Err("cannot train on an empty dataset.".to_string());
        }

        enum Targets {
            Class { labels: Vec<u32>, num_classes: usize, label_col: usize },
            Reg { targets: Vec<f32>, label_col: usize },
        }
        let targets = match cfg.task {
            Task::Classification => {
                let (label_col, labels) = classification_labels(ds, &cfg.label)?;
                let num_classes = ds.spec.columns[label_col].vocab_size();
                Targets::Class { labels, num_classes, label_col }
            }
            Task::Regression => {
                let (label_col, targets) = regression_targets(ds, &cfg.label)?;
                Targets::Reg { targets, label_col }
            }
        };
        let label_col = match &targets {
            Targets::Class { label_col, .. } | Targets::Reg { label_col, .. } => *label_col,
        };
        let features = feature_columns(ds, label_col);

        let tree_cfg = TreeConfig {
            max_depth: cfg.max_depth,
            min_examples: cfg.min_examples,
            splitter: cfg.splitter.clone(),
            growing: cfg.growing,
            attr_sampling: cfg.attr_sampling,
        };

        // Deterministic per-tree seeds (§3.11): derived from the config
        // seed; tree order is immaterial, so parallel training yields the
        // same model as sequential.
        let mut seed_rng = Rng::seed_from_u64(cfg.seed);
        let tree_seeds: Vec<u64> = (0..cfg.num_trees).map(|_| seed_rng.next_u64()).collect();

        // Shared read-only column index (sort orders / binnings built at
        // most once across all trees and threads); each tree worker gets
        // its own sequential split engine and row arena over it.
        let index = Arc::new(ColumnIndex::new(ds));
        // Training telemetry. The handles are resolved once here and the
        // closure below only touches relaxed atomics and (when enabled)
        // the trace buffer — no RNG draws, no ordering dependence — so
        // threaded training stays bit-identical to sequential (pinned by
        // `prop_threaded_training_bit_identical_to_sequential`).
        let obs_trees = crate::obs::metrics().counter_with(
            "ydf_train_trees_total",
            "Trees grown during training, by learner.",
            &[("learner", "rf")],
        );
        let obs_tree_us = crate::obs::metrics().counter_with(
            "ydf_train_tree_micros_total",
            "Wall-clock microseconds spent growing trees (split search included), by learner.",
            &[("learner", "rf")],
        );
        let trees_and_bags = parallel_map(cfg.num_trees, cfg.num_threads, |t| {
            let mut rng = Rng::seed_from_u64(tree_seeds[t]);
            let rows: Vec<u32> = if cfg.bootstrap {
                (0..n).map(|_| rng.uniform_usize(n) as u32).collect()
            } else {
                (0..n as u32).collect()
            };
            let mut in_bag = vec![false; n];
            for &r in &rows {
                in_bag[r as usize] = true;
            }
            let labels_view = match &targets {
                Targets::Class { labels, num_classes, .. } => {
                    Labels::Classification { labels, num_classes: *num_classes }
                }
                Targets::Reg { targets, .. } => Labels::Regression { targets },
            };
            let mut engine = SplitEngine::sequential(Arc::clone(&index));
            let mut arena = RowArena::new();
            let t_span = crate::obs::trace::begin();
            let t_grow = std::time::Instant::now();
            let tree = grow_tree(
                ds,
                &rows,
                &labels_view,
                &features,
                &tree_cfg,
                &mut engine,
                &mut arena,
                &mut rng,
            );
            let grow_us = t_grow.elapsed().as_secs_f64() * 1e6;
            obs_trees.inc();
            obs_tree_us.add(grow_us as u64);
            crate::obs::trace::end(t_span, "train_tree", || {
                use crate::obs::trace::ArgValue;
                vec![
                    ("learner", ArgValue::Str("rf".to_string())),
                    ("tree", ArgValue::U64(t as u64)),
                    ("nodes", ArgValue::U64(tree.nodes.len() as u64)),
                    ("us", ArgValue::F64(grow_us)),
                ]
            });
            crate::ydf_debug!(
                "rf tree {t}: {} nodes in {:.0} us",
                tree.nodes.len(),
                grow_us
            );
            (tree, in_bag)
        });

        let mut trees = Vec::with_capacity(cfg.num_trees);
        let mut bags = Vec::with_capacity(cfg.num_trees);
        for (tree, bag) in trees_and_bags {
            trees.push(tree);
            bags.push(bag);
        }
        crate::ydf_info!(
            "rf: grew {} trees on {} rows ({} thread(s))",
            trees.len(),
            n,
            cfg.num_threads.max(1)
        );

        // Out-of-bag evaluation (§3.6): each example is scored only by the
        // trees whose bootstrap sample excluded it.
        let oob_evaluation = if cfg.compute_oob && cfg.bootstrap {
            match &targets {
                Targets::Class { labels, num_classes, .. } => {
                    let mut correct = 0u64;
                    let mut counted = 0u64;
                    for r in 0..n {
                        let mut votes = vec![0.0f64; *num_classes];
                        let mut any = false;
                        for (t, tree) in trees.iter().enumerate() {
                            if !bags[t][r] {
                                let leaf = tree.eval_ds(ds, r);
                                if cfg.winner_take_all {
                                    let mut best = 0usize;
                                    for (i, &v) in leaf.value.iter().enumerate().skip(1) {
                                        if v > leaf.value[best] {
                                            best = i;
                                        }
                                    }
                                    votes[best] += 1.0;
                                } else {
                                    for (v, &lv) in votes.iter_mut().zip(&leaf.value) {
                                        *v += lv as f64;
                                    }
                                }
                                any = true;
                            }
                        }
                        if any {
                            let mut best = 0usize;
                            for (i, &v) in votes.iter().enumerate().skip(1) {
                                if v > votes[best] {
                                    best = i;
                                }
                            }
                            counted += 1;
                            if best as u32 == labels[r] {
                                correct += 1;
                            }
                        }
                    }
                    Some(SelfEvaluation {
                        metric: "out-of-bag accuracy".to_string(),
                        value: if counted > 0 { correct as f64 / counted as f64 } else { 0.0 },
                        num_examples: counted,
                    })
                }
                Targets::Reg { targets, .. } => {
                    let mut sse = 0.0f64;
                    let mut counted = 0u64;
                    for r in 0..n {
                        let mut sum = 0.0f64;
                        let mut cnt = 0usize;
                        for (t, tree) in trees.iter().enumerate() {
                            if !bags[t][r] {
                                sum += tree.eval_ds(ds, r).value[0] as f64;
                                cnt += 1;
                            }
                        }
                        if cnt > 0 {
                            let err = sum / cnt as f64 - targets[r] as f64;
                            sse += err * err;
                            counted += 1;
                        }
                    }
                    Some(SelfEvaluation {
                        metric: "out-of-bag rmse".to_string(),
                        value: if counted > 0 { (sse / counted as f64).sqrt() } else { 0.0 },
                        num_examples: counted,
                    })
                }
            }
        } else {
            None
        };

        Ok(Box::new(RandomForestModel {
            spec: ds.spec.clone(),
            label_col,
            task: cfg.task,
            trees,
            winner_take_all: cfg.winner_take_all,
            oob_evaluation,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::evaluation_free_accuracy;

    #[test]
    fn learns_adult_like() {
        let ds = synthetic::adult_like(600, 7);
        let mut cfg = RandomForestConfig::new("income");
        cfg.num_trees = 20;
        let model = RandomForestLearner::new(cfg).train(&ds).unwrap();
        let acc = evaluation_free_accuracy(model.as_ref(), &ds);
        assert!(acc > 0.75, "train accuracy {acc}");
        let oob = model.self_evaluation().unwrap();
        assert!(oob.metric.contains("out-of-bag"));
        assert!(oob.value > 0.6, "oob {}", oob.value);
        assert!(oob.value <= 1.0);
    }

    #[test]
    fn deterministic_model() {
        let ds = synthetic::adult_like(200, 3);
        let mut cfg = RandomForestConfig::new("income");
        cfg.num_trees = 5;
        cfg.compute_oob = false;
        let m1 = RandomForestLearner::new(cfg.clone()).train(&ds).unwrap();
        let m2 = RandomForestLearner::new(cfg).train(&ds).unwrap();
        assert_eq!(m1.to_json().to_string(), m2.to_json().to_string());
    }

    #[test]
    fn parallel_equals_sequential() {
        let ds = synthetic::adult_like(150, 5);
        let mut cfg = RandomForestConfig::new("income");
        cfg.num_trees = 4;
        cfg.compute_oob = false;
        let seq = RandomForestLearner::new(cfg.clone()).train(&ds).unwrap();
        cfg.num_threads = 3;
        let par = RandomForestLearner::new(cfg).train(&ds).unwrap();
        assert_eq!(seq.to_json().to_string(), par.to_json().to_string());
    }

    #[test]
    fn regression_forest() {
        // Regress hours_per_week from the other features — weak signal,
        // just verify plumbing and OOB RMSE sanity.
        let ds = synthetic::adult_like(300, 11);
        let mut cfg = RandomForestConfig::new("hours_per_week");
        cfg.task = Task::Regression;
        cfg.num_trees = 10;
        let model = RandomForestLearner::new(cfg).train(&ds).unwrap();
        assert_eq!(model.task(), Task::Regression);
        let p = model.predict_ds_row(&ds, 0);
        assert_eq!(p.len(), 1);
        assert!(p[0] > 0.0 && p[0] < 100.0);
        assert!(model.self_evaluation().unwrap().metric.contains("rmse"));
    }

    #[test]
    fn benchmark_template_uses_oblique() {
        let cfg = RandomForestConfig::benchmark_rank1("income");
        assert!(matches!(cfg.splitter.axis, SplitAxis::SparseOblique { .. }));
        assert!(matches!(cfg.splitter.categorical, CategoricalSplit::Random { .. }));
    }
}
