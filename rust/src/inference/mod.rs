//! Inference engines (§3.7): a model is *compiled* into an engine chosen
//! by model structure and available backends, trading space, complexity
//! and latency. Engines:
//!
//! * [`naive::NaiveEngine`] — Algorithm 1, pointer-chasing traversal.
//!   Always compatible with the core model types; the correctness
//!   reference the optimized engines are validated against.
//! * [`flat::FlatEngine`] — structure-of-arrays layout, branch-light.
//!   Compiles for any RF/GBT forest, including oblique and
//!   categorical-set conditions.
//! * [`quickscorer::QuickScorerEngine`] — Lucchese et al. 2015 bitvector
//!   traversal for trees with ≤ 64 leaves and Higher/Contains/IsTrue
//!   conditions only (the engine the B.4 report calls
//!   `GradientBoostedTreesQuickScorer`). Fastest when compatible.
//! * [`compiled::CompiledEngine`] — the forest lowered to one flat word
//!   array that doubles as a versioned, checksummed on-disk artifact
//!   (`ydf compile` → `.bin`, mmap-ed back at serve time). Traversal
//!   mirrors the flat engine (same kernels, bit-identical); the win is
//!   near-instant model open and a position-independent layout.
//! * [`pjrt::PjrtEngine`] — the XLA artifact produced by the build-time
//!   JAX/Pallas layers, executed through the PJRT C API (requires the
//!   `xla` cargo feature plus `make artifacts`; lossy: binary GBT over
//!   numerical features only).
//!
//! ## The batch contract
//!
//! The hot path is [`InferenceEngine::predict_batch`]: engines read the
//! columnar [`ColumnData`] storage directly and write predictions into a
//! caller-provided `&mut [f64]` — no `Observation` materialization, no
//! per-row output `Vec`. Examples are processed in fixed-size blocks of
//! [`BLOCK_SIZE`] rows across trees so node tables and bitvectors stay
//! cache-resident (QuickScorer's bitvector traversal operates block-wise,
//! as Lucchese et al. intend). `predict_row` remains for single-example
//! serving; `predict_dataset` is a compatibility wrapper over
//! [`InferenceEngine::predict_into`], which fans blocks out over threads
//! with index-disjoint writes (thread count from `YDF_INFER_THREADS`,
//! default = available parallelism).
//!
//! Engine selection: [`compile_engines`] returns every compatible engine,
//! fastest first — QuickScorer when every tree fits its 64-leaf/condition
//! envelope, then the flat engine, then the naive fallback. Callers that
//! only need predictions from a `Model` should use [`predict_flat`], which
//! performs the selection and batch fan-out in one call and degrades to
//! the model's own row loop for wrapper models (ensembles, calibrators)
//! that no engine compiles. [`auto_engine_name`] reports which path
//! `predict_flat` would take, so tools can surface the selection.
//!
//! The static order is only the fallback: [`router`] measures every
//! compatible engine variant per batch-size bucket at model load and
//! pins a per-(model, bucket) winner table — the serving `Session` and
//! `Batcher` route each flush by its actual row count through that
//! table ([`router::Router`]), caching the measurement next to the
//! model as `<model>.router.json`.
//!
//! ## SIMD lane kernels
//!
//! The flat and QuickScorer engines each carry two block kernels: a
//! scalar one (the correctness reference) and a lane-wise one whose
//! threshold sweeps and bitvector AND-reductions are straight-line loops
//! over the [`BLOCK_SIZE`]-row block that the compiler auto-vectorizes.
//! Both are always compiled; the `simd` cargo feature (on by default)
//! only selects which one `predict_batch` uses, and
//! `set_simd(true | false)` on either engine overrides that per instance.
//! The two kernels are bit-identical (pinned by
//! `rust/tests/properties.rs::prop_simd_lanes_match_scalar`), and
//! [`benchmark_inference`] times both — `BENCH_inference.json` keys the
//! scalar variants with a `[scalar]` suffix. See `docs/serving.md` for
//! the full serving contract.
//!
//! ```
//! use ydf::inference::predict_flat;
//! use ydf::learner::gbt::GbtConfig;
//! use ydf::learner::{GradientBoostedTreesLearner, Learner};
//!
//! let data = ydf::dataset::synthetic::adult_like(100, 7);
//! let mut config = GbtConfig::new("income");
//! config.num_trees = 3;
//! config.max_depth = 3;
//! let model = GradientBoostedTreesLearner::new(config).train(&data).unwrap();
//! // Fastest compatible engine, flat row-major output buffer.
//! let (predictions, dim) = predict_flat(model.as_ref(), &data);
//! assert_eq!(predictions.len(), data.num_rows() * dim);
//! let p0 = &predictions[..dim]; // class probabilities of row 0
//! assert!((p0.iter().sum::<f64>() - 1.0).abs() < 1e-9);
//! ```

pub mod compiled;
pub mod flat;
pub mod naive;
pub mod pjrt;
pub mod quickscorer;
pub mod router;

use crate::dataset::{ColumnData, Dataset, Observation};
use crate::model::forest::GbtLoss;
use crate::model::Model;
use crate::utils::json::Json;
use std::ops::Range;

/// Rows per inference block. 64 keeps a block's bitvectors (64 × 8 bytes ×
/// trees) and leaf scratch within L1/L2 for typical model sizes while
/// amortizing per-block setup; it also matches the PJRT artifact's padded
/// batch. The knob is compile-time on purpose: engines size their scratch
/// buffers from it.
pub const BLOCK_SIZE: usize = 64;

/// Thread count for whole-dataset fan-out: `YDF_INFER_THREADS` when set
/// to a positive integer, otherwise the machine's available parallelism.
/// A set-but-invalid value (unparsable, or `0`) also falls back, with a
/// one-time warning naming the bad value (via `utils::env`) — a
/// misconfigured deployment should be diagnosable, not silently single-
/// or all-core.
pub fn batch_threads() -> usize {
    let fallback = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    crate::utils::env::positive_usize("YDF_INFER_THREADS").unwrap_or(fallback)
}

/// Partitions `n` rows into at most `threads` contiguous,
/// [`BLOCK_SIZE`]-aligned spans (the last span carries the unaligned
/// tail). This is the single source of truth for batch fan-out
/// partitioning: [`InferenceEngine::predict_into`] spawns one scoped
/// thread per span, and the serving batcher scatters the same spans over
/// persistent `utils/pool.rs` workers — identical partitioning, so both
/// paths are trivially bit-identical to a single `predict_batch` call
/// (engines are row-independent and every span start is block-aligned).
pub fn block_spans(n: usize, threads: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let n_blocks = n.div_ceil(BLOCK_SIZE);
    let threads = threads.clamp(1, n_blocks);
    let span = n_blocks.div_ceil(threads) * BLOCK_SIZE;
    let mut out = Vec::with_capacity(threads);
    let mut row0 = 0usize;
    while row0 < n {
        let hi = (row0 + span).min(n);
        out.push(row0..hi);
        row0 = hi;
    }
    out
}

/// Columnar storage resolved once per batch: engines index typed slices
/// instead of matching the `ColumnData` enum per node visit per row.
pub(crate) struct ColumnAccess<'a> {
    pub num: Vec<Option<&'a [f32]>>,
    pub cat: Vec<Option<&'a [u32]>>,
    pub boolean: Vec<Option<&'a [u8]>>,
    /// Raw columns, for the ragged categorical-set accessor.
    pub columns: &'a [ColumnData],
}

impl<'a> ColumnAccess<'a> {
    pub fn new(ds: &'a Dataset) -> ColumnAccess<'a> {
        ColumnAccess {
            num: ds.columns.iter().map(|c| c.as_numerical()).collect(),
            cat: ds.columns.iter().map(|c| c.as_categorical()).collect(),
            boolean: ds.columns.iter().map(|c| c.as_boolean()).collect(),
            columns: &ds.columns,
        }
    }
}

/// Forest-output aggregation mode, fixed at engine-compile time. Shared by
/// the flat and QuickScorer engines: they differ in how per-tree leaves
/// are gathered, not in how outputs are shaped or linked.
pub(crate) enum Aggregate {
    RfAverage { num_classes: usize, winner_take_all: bool },
    RfRegression,
    Gbt { loss: GbtLoss, dim: usize, initial: Vec<f64> },
}

impl Aggregate {
    /// Values per example in batch output.
    pub(crate) fn output_dim(&self) -> usize {
        match self {
            Aggregate::RfAverage { num_classes, .. } => *num_classes,
            Aggregate::RfRegression => 1,
            Aggregate::Gbt { loss, dim, .. } => match loss {
                GbtLoss::BinomialLogLikelihood => 2,
                GbtLoss::MultinomialLogLikelihood | GbtLoss::SquaredError => *dim,
            },
        }
    }

    /// Length of the raw-score scratch the GBT link function needs
    /// (0 for RF aggregates, which accumulate directly into the output).
    pub(crate) fn score_dim(&self) -> usize {
        match self {
            Aggregate::Gbt { dim, .. } => *dim,
            _ => 0,
        }
    }

    /// Maps accumulated raw GBT scores into the prediction space.
    pub(crate) fn apply_gbt_link(loss: GbtLoss, scores: &mut [f64], out: &mut [f64]) {
        match loss {
            GbtLoss::BinomialLogLikelihood => {
                let p = crate::utils::stats::sigmoid(scores[0]);
                out[0] = 1.0 - p;
                out[1] = p;
            }
            GbtLoss::MultinomialLogLikelihood => {
                crate::utils::stats::softmax_in_place(scores);
                out.copy_from_slice(scores);
            }
            GbtLoss::SquaredError => out.copy_from_slice(scores),
        }
    }
}

/// A compiled inference engine.
pub trait InferenceEngine: Send + Sync {
    /// Engine name as shown by `benchmark_inference` (B.4).
    fn name(&self) -> String;

    /// Values per example in batch output: class count for classification,
    /// 1 (or the tree multiplicity) for regression.
    fn output_dim(&self) -> usize;

    /// Predicts one row observation (probabilities / regression value).
    /// Single-example serving path; batch callers use `predict_batch`.
    fn predict_row(&self, obs: &Observation) -> Vec<f64>;

    /// Batch prediction over `rows` of a columnar dataset into a
    /// caller-provided buffer of `rows.len() * output_dim()` values,
    /// row-major. Engines override this with an allocation-free columnar
    /// traversal; the default funnels through the per-row path for
    /// engines without a native batch implementation.
    fn predict_batch(&self, ds: &Dataset, rows: Range<usize>, out: &mut [f64]) {
        let dim = self.output_dim();
        debug_assert_eq!(out.len(), rows.len() * dim);
        for (i, r) in rows.enumerate() {
            out[i * dim..(i + 1) * dim].copy_from_slice(&self.predict_row(&ds.row(r)));
        }
    }

    /// Predicts the whole dataset into a flat row-major buffer of
    /// `num_rows * output_dim()` values, fanning contiguous
    /// [`BLOCK_SIZE`]-aligned row spans out over `threads` threads with
    /// index-disjoint writes (no per-item synchronization). Each thread
    /// makes a single `predict_batch` call over its whole span — engines
    /// block internally, so scratch and column resolution are set up once
    /// per span, not once per block.
    fn predict_into(&self, ds: &Dataset, threads: usize, out: &mut [f64]) {
        let dim = self.output_dim();
        let n = ds.num_rows();
        assert_eq!(
            out.len(),
            n * dim,
            "predict_into: output buffer holds {} values but {} rows x {} outputs are required",
            out.len(),
            n,
            dim
        );
        if n == 0 {
            return;
        }
        let spans = block_spans(n, threads);
        if spans.len() == 1 {
            self.predict_batch(ds, 0..n, out);
            return;
        }
        std::thread::scope(|s| {
            let mut rest: &mut [f64] = out;
            for span in spans {
                let (head, tail) =
                    std::mem::take(&mut rest).split_at_mut((span.end - span.start) * dim);
                rest = tail;
                s.spawn(move || self.predict_batch(ds, span, head));
            }
        });
    }

    /// Predicts a whole dataset (compatibility wrapper: one `Vec` per row).
    /// Batch callers should prefer `predict_into`, which is what this
    /// method rides on.
    fn predict_dataset(&self, ds: &Dataset) -> Vec<Vec<f64>> {
        let dim = self.output_dim();
        let mut flat = vec![0.0f64; ds.num_rows() * dim];
        self.predict_into(ds, batch_threads(), &mut flat);
        flat.chunks(dim).map(|c| c.to_vec()).collect()
    }
}

/// Compiles all engines compatible with `model`, fastest first. This is
/// the automatic engine selection of §3.7: callers normally use
/// `engines.first()`.
pub fn compile_engines(model: &dyn Model) -> Vec<Box<dyn InferenceEngine>> {
    // An artifact-backed model only the compiled engine understands (the
    // naive fallback cannot traverse the word layout).
    if model.as_any().downcast_ref::<compiled::CompiledModel>().is_some() {
        let eng = compiled::CompiledEngine::compile(model)
            .expect("CompiledModel always compiles to CompiledEngine");
        return vec![Box::new(eng)];
    }
    let mut out: Vec<Box<dyn InferenceEngine>> = Vec::new();
    if let Some(qs) = quickscorer::QuickScorerEngine::compile(model) {
        out.push(Box::new(qs));
    }
    if let Some(flat) = flat::FlatEngine::compile(model) {
        out.push(Box::new(flat));
    }
    if let Some(ce) = compiled::CompiledEngine::compile(model) {
        out.push(Box::new(ce));
    }
    out.push(Box::new(naive::NaiveEngine::compile(model)));
    out
}

/// The engine [`predict_flat`] rides on, or `None` for wrapper models
/// (ensembles, calibrators — those fall back to the model's own row
/// loop). A thin wrapper over [`router::Router::uncalibrated`], which
/// owns the static §3.7 preference order: compiled for artifact-backed
/// models, else QuickScorer when compatible, else the flat engine.
/// Callers that want the *measured* per-batch-size choice open a
/// serving `Session` with a [`router::CalibrateMode`] instead — the
/// router calibrates per (model, bucket) and this wrapper is its
/// static fallback.
pub fn fastest_engine(model: &dyn Model) -> Option<Box<dyn InferenceEngine>> {
    router::Router::uncalibrated(model).map(router::Router::into_primary)
}

/// Batch prediction for any model through the fastest compatible engine:
/// compiles QuickScorer or the flat engine when the model structure allows
/// it, and falls back to the model's own columnar row loop otherwise
/// (wrapper models — ensembles, calibrators — have no native engine).
/// Returns the flat row-major prediction buffer and the per-row dimension.
pub fn predict_flat(model: &dyn Model, ds: &Dataset) -> (Vec<f64>, usize) {
    let dim = model.num_classes().max(1);
    let n = ds.num_rows();
    let mut flat = vec![0.0f64; n * dim];
    if let Some(engine) = fastest_engine(model) {
        crate::ydf_debug!("predict_flat: scoring {n} rows via {}", engine.name());
        engine.predict_into(ds, batch_threads(), &mut flat);
        note_offline_rows(&engine.name(), n);
    } else {
        crate::ydf_debug!("predict_flat: scoring {n} rows via model row loop (no engine compiled)");
        for r in 0..n {
            flat[r * dim..(r + 1) * dim].copy_from_slice(&model.predict_ds_row(ds, r));
        }
        note_offline_rows("row loop", n);
    }
    (flat, dim)
}

/// Feeds the offline-inference rows counter. One registry lookup per
/// `predict_flat` call — dataset-level, not per row, so the lock is
/// negligible next to the prediction work it accounts for.
fn note_offline_rows(engine: &str, rows: usize) {
    crate::obs::metrics()
        .counter_with(
            "ydf_inference_rows_total",
            "Rows scored offline through predict_flat, by engine.",
            &[("engine", engine)],
        )
        .add(rows as u64);
}

/// Name of the engine [`predict_flat`] would select for `model` — the
/// fastest compatible one — or `None` when no engine compiles and
/// prediction falls back to the model's own row loop (wrapper models).
/// Compiles the engine to answer (compilation is cheap next to serving,
/// but don't call this per request). Lets tools print the automatic
/// engine selection they ride on.
pub fn auto_engine_name(model: &dyn Model) -> Option<String> {
    fastest_engine(model).map(|e| e.name())
}

/// One engine's timings in the B.4 report: the batch path (columnar
/// `predict_into`, single thread, so µs/example/core matches the paper's
/// unit) and the seed-style per-row path (`Dataset::row` materialization +
/// `predict_row`), measured in the same run.
pub struct EngineTiming {
    pub name: String,
    pub batch_us_per_example: f64,
    pub row_us_per_example: f64,
}

/// Inference benchmark results (Appendix B.4), machine-readable.
pub struct InferenceBenchmark {
    pub num_examples: usize,
    pub runs: usize,
    pub block_size: usize,
    /// Engines compatible with the model (`compile_engines` count); the
    /// `engines` table may hold more rows — kernel variants of the same
    /// engine, tagged `[scalar]`.
    pub num_compatible: usize,
    /// Sorted by batch time, fastest first.
    pub engines: Vec<EngineTiming>,
}

/// Runs every compatible engine over the dataset `runs` times on both the
/// batch and the per-row path. When the default kernels are the SIMD lane
/// sweeps (`simd` cargo feature, on by default), the scalar kernels of the
/// flat and QuickScorer engines are timed as additional `[scalar]`-tagged
/// rows, so `BENCH_inference.json` tracks scalar vs SIMD across PRs. The
/// per-row path is kernel-independent, so the variants inherit the
/// untagged row timing instead of re-measuring it.
pub fn benchmark_inference(
    model: &dyn Model,
    ds: &Dataset,
    runs: usize,
) -> InferenceBenchmark {
    let compatible = compile_engines(model);
    let num_compatible = compatible.len();
    // (label, engine, measure_row): scalar-kernel variants are labeled by
    // the benchmark so engine names stay stable across feature configs.
    let mut entries: Vec<(String, Box<dyn InferenceEngine>, bool)> =
        compatible.into_iter().map(|e| (e.name(), e, true)).collect();
    if cfg!(feature = "simd") {
        if let Some(mut qs) = quickscorer::QuickScorerEngine::compile(model) {
            qs.set_simd(false);
            entries.push((format!("{}[scalar]", qs.name()), Box::new(qs), false));
        }
        if let Some(mut fl) = flat::FlatEngine::compile(model) {
            fl.set_simd(false);
            entries.push((format!("{}[scalar]", fl.name()), Box::new(fl), false));
        }
        if let Some(mut ce) = compiled::CompiledEngine::compile(model) {
            ce.set_simd(false);
            entries.push((format!("{}[scalar]", ce.name()), Box::new(ce), false));
        }
    }
    let runs = runs.max(1);
    let denom = (runs * ds.num_rows().max(1)) as f64;
    let mut timings: Vec<EngineTiming> = Vec::new();
    for (name, e, measure_row) in &entries {
        let dim = e.output_dim();
        let mut flat = vec![0.0f64; ds.num_rows() * dim];
        let t0 = std::time::Instant::now();
        for _ in 0..runs {
            e.predict_into(ds, 1, &mut flat);
            std::hint::black_box(&mut flat);
        }
        let batch_us = t0.elapsed().as_secs_f64() / denom * 1e6;
        let row_us = if *measure_row {
            let t0 = std::time::Instant::now();
            for _ in 0..runs {
                for r in 0..ds.num_rows() {
                    std::hint::black_box(e.predict_row(&ds.row(r)));
                }
            }
            t0.elapsed().as_secs_f64() / denom * 1e6
        } else {
            // Kernel variants share the untagged engine's per-row path;
            // its entry was measured above.
            let base = name.trim_end_matches("[scalar]");
            timings
                .iter()
                .find(|t| t.name == base)
                .map(|t| t.row_us_per_example)
                .unwrap_or(0.0)
        };
        timings.push(EngineTiming {
            name: name.clone(),
            batch_us_per_example: batch_us,
            row_us_per_example: row_us,
        });
    }
    timings.sort_by(|a, b| a.batch_us_per_example.partial_cmp(&b.batch_us_per_example).unwrap());
    InferenceBenchmark {
        num_examples: ds.num_rows(),
        runs,
        block_size: BLOCK_SIZE,
        num_compatible,
        engines: timings,
    }
}

impl InferenceBenchmark {
    /// Renders the B.4 report.
    pub fn report(&self) -> String {
        let mut out = format!(
            "Inference benchmark: {} engines compatible with the model ({} timed variants), \
             {} examples x {} runs (block={})\n  {:<42} {:>16} {:>18} {:>9}\n",
            self.num_compatible,
            self.engines.len(),
            self.num_examples,
            self.runs,
            self.block_size,
            "engine",
            "batch us/example",
            "per-row us/example",
            "speedup",
        );
        for e in &self.engines {
            out.push_str(&format!(
                "  {:<42} {:>16.3} {:>18.3} {:>8.1}x\n",
                e.name,
                e.batch_us_per_example,
                e.row_us_per_example,
                e.row_us_per_example / e.batch_us_per_example.max(1e-12),
            ));
        }
        out
    }

    /// JSON form for perf tracking across PRs (BENCH_inference.json).
    pub fn to_json(&self) -> Json {
        let mut engines = Json::obj();
        for e in &self.engines {
            let mut ej = Json::obj();
            ej.set("batch_us_per_example", Json::Num(e.batch_us_per_example))
                .set("row_us_per_example", Json::Num(e.row_us_per_example));
            engines.set(&e.name, ej);
        }
        let mut j = Json::obj();
        j.set("num_examples", Json::Num(self.num_examples as f64))
            .set("runs", Json::Num(self.runs as f64))
            .set("block_size", Json::Num(self.block_size as f64))
            .set("num_compatible", Json::Num(self.num_compatible as f64))
            .set("engines", engines);
        j
    }
}

/// Inference benchmark report (Appendix B.4) as a string — the CLI's
/// `benchmark_inference` output.
pub fn benchmark_inference_report(
    model: &dyn Model,
    ds: &Dataset,
    runs: usize,
) -> String {
    benchmark_inference(model, ds, runs).report()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};

    #[test]
    fn engine_selection_order() {
        let ds = synthetic::adult_like(200, 111);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 5;
        cfg.max_depth = 4; // <= 64 leaves -> QuickScorer compatible
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let engines = compile_engines(model.as_ref());
        assert!(engines.len() >= 3);
        assert!(engines[0].name().contains("QuickScorer"), "{}", engines[0].name());
    }

    #[test]
    fn b4_report_renders() {
        let ds = synthetic::adult_like(100, 113);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 3;
        cfg.max_depth = 3;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let bench = benchmark_inference(model.as_ref(), &ds, 2);
        let rep = bench.report();
        assert!(rep.contains("us/example"));
        assert!(rep.contains("engines compatible"));
        let json = bench.to_json().to_string();
        assert!(json.contains("batch_us_per_example"), "{json}");
        // The scalar-kernel variants ride along whenever the default is
        // the SIMD lane path, keying the scalar-vs-SIMD perf trajectory.
        if cfg!(feature = "simd") {
            assert!(json.contains("[scalar]"), "{json}");
        }
    }

    #[test]
    fn auto_engine_name_reports_selection() {
        // `fastest_engine` and `compile_engines` encode the selection order
        // independently (first returns one engine, the other all of them);
        // pin them together so they cannot drift.
        let ds = synthetic::adult_like(120, 115);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 3;
        cfg.max_depth = 3; // QuickScorer-compatible
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let name = auto_engine_name(model.as_ref()).expect("forest model compiles");
        assert!(name.contains("QuickScorer"), "{name}");
        assert_eq!(name, compile_engines(model.as_ref())[0].name());

        // Oblique model: QuickScorer incompatible, flat engine selected.
        let mut cfg = GbtConfig::benchmark_rank1("income");
        cfg.num_trees = 3;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let name = auto_engine_name(model.as_ref()).expect("forest model compiles");
        assert!(name.contains("OptPred"), "{name}");
        assert_eq!(name, compile_engines(model.as_ref())[0].name());
    }

    #[test]
    fn predict_flat_matches_model_rows() {
        let ds = synthetic::adult_like(150, 117);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 6;
        cfg.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let (flat, dim) = predict_flat(model.as_ref(), &ds);
        assert_eq!(flat.len(), ds.num_rows() * dim);
        for r in 0..ds.num_rows() {
            let p = model.predict_ds_row(&ds, r);
            for k in 0..dim {
                assert!((flat[r * dim + k] - p[k]).abs() < 1e-9, "row {r}");
            }
        }
    }

    #[test]
    fn block_spans_cover_disjoint_and_aligned() {
        for n in [0usize, 1, 63, 64, 65, 128, 201, 512, 1000] {
            for threads in [1usize, 2, 3, 4, 16, 100] {
                let spans = block_spans(n, threads);
                if n == 0 {
                    assert!(spans.is_empty());
                    continue;
                }
                assert!(spans.len() <= threads.max(1), "n={n} t={threads}");
                let mut at = 0usize;
                for s in &spans {
                    assert_eq!(s.start, at, "contiguous: n={n} t={threads}");
                    assert_eq!(s.start % BLOCK_SIZE, 0, "aligned start: n={n} t={threads}");
                    assert!(s.end > s.start);
                    at = s.end;
                }
                assert_eq!(at, n, "full cover: n={n} t={threads}");
            }
        }
    }

    #[test]
    fn predict_into_multithreaded_matches_single() {
        let ds = synthetic::adult_like(333, 119); // non-aligned tail
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 4;
        cfg.max_depth = 3;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let engine = flat::FlatEngine::compile(model.as_ref()).unwrap();
        let dim = engine.output_dim();
        let mut single = vec![0.0; ds.num_rows() * dim];
        let mut multi = vec![0.0; ds.num_rows() * dim];
        engine.predict_into(&ds, 1, &mut single);
        engine.predict_into(&ds, 3, &mut multi);
        assert_eq!(single, multi);
    }
}
