//! Integration test of the full AOT bridge: train a GBT in Rust, compile
//! it to the PJRT engine (the XLA artifact produced by the JAX/Pallas
//! build layer), and check its predictions against the native engines.
//!
//! Requires `make artifacts`; skipped (with a message) when the artifact
//! is absent.

use ydf::dataset::synthetic;
use ydf::inference::pjrt::PjrtEngine;
use ydf::inference::InferenceEngine;
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};
use ydf::runtime::Runtime;

fn artifact_present() -> bool {
    ydf::runtime::artifacts_dir().join("forest.hlo.txt").exists()
}

#[test]
fn pjrt_engine_matches_native_engines() {
    if !artifact_present() {
        eprintln!("SKIP: artifacts/forest.hlo.txt missing — run `make artifacts`");
        return;
    }
    // Numerical-only dataset (the PJRT engine supports Higher conditions
    // over numerical features only — documented lossy compilation).
    let spec = synthetic::spec_by_name("Wilt").unwrap();
    let opts = synthetic::GenOptions { max_examples: 500, ..Default::default() };
    let ds = synthetic::generate(spec, 161, &opts);
    // Fit within the artifact's padded shapes (T<=64, N<=256, D<=12).
    let mut cfg = GbtConfig::new("label");
    cfg.num_trees = 40;
    cfg.max_depth = 5;
    let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();

    let runtime = Runtime::cpu().expect("PJRT CPU client");
    let engine = PjrtEngine::compile(model.as_ref(), &runtime).expect("compatible model");

    let pjrt_preds = engine.predict_dataset(&ds);
    assert_eq!(pjrt_preds.len(), ds.num_rows());

    // The PJRT engine mean-imputes missing values (documented lossy
    // compilation, §3.7); compare on rows without missing values and
    // check the imputed rows stay within probability bounds.
    let mut compared = 0;
    for r in 0..ds.num_rows() {
        let row = ds.row(r);
        let has_missing = row.iter().any(|v| matches!(v, ydf::dataset::AttrValue::Missing));
        let native = model.predict_ds_row(&ds, r);
        let pjrt = &pjrt_preds[r];
        assert!(pjrt[1] >= 0.0 && pjrt[1] <= 1.0);
        if !has_missing {
            assert!(
                (native[1] - pjrt[1]).abs() < 1e-4,
                "row {r}: native {native:?} vs pjrt {pjrt:?}"
            );
            compared += 1;
        }
    }
    assert!(compared > 100, "only {compared} rows compared");
}

#[test]
fn pjrt_rejects_oversized_models() {
    if !artifact_present() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let spec = synthetic::spec_by_name("Wilt").unwrap();
    let opts = synthetic::GenOptions { max_examples: 400, ..Default::default() };
    let ds = synthetic::generate(spec, 163, &opts);
    let mut cfg = GbtConfig::new("label");
    cfg.num_trees = 80; // > MAX_TREES
    cfg.max_depth = 4;
    let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
    let runtime = Runtime::cpu().unwrap();
    let err = match PjrtEngine::compile(model.as_ref(), &runtime) {
        Err(e) => e,
        Ok(_) => return, // early stopping may have kept < 64 trees
    };
    assert!(err.contains("trees"), "{err}");
}

#[test]
fn linear_artifact_executes() {
    let path = ydf::runtime::artifacts_dir().join("linear.hlo.txt");
    if !path.exists() {
        eprintln!("SKIP: artifacts/linear.hlo.txt missing");
        return;
    }
    let runtime = Runtime::cpu().unwrap();
    let exe = runtime.load_hlo_text(&path).unwrap();
    // x: [64, 32], w: [32, 8], b: [8] -> softmax probs [64, 8].
    let x = vec![0.1f32; 64 * 32];
    let w = vec![0.0f32; 32 * 8];
    let b = vec![0.0f32; 8];
    let out = exe
        .run(&[
            ydf::runtime::literal_f32(&x, &[64, 32]).unwrap(),
            ydf::runtime::literal_f32(&w, &[32, 8]).unwrap(),
            ydf::runtime::literal_f32(&b, &[8]).unwrap(),
        ])
        .unwrap();
    let probs = ydf::runtime::to_vec_f32(&out[0]).unwrap();
    assert_eq!(probs.len(), 64 * 8);
    // Uniform weights -> uniform softmax.
    assert!((probs[0] - 0.125).abs() < 1e-5);
}
