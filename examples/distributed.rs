//! Distributed training (§3.9): feature-parallel exact GBT training on
//! the in-process and thread backends, verifying the exactness guarantee
//! (distributed model == single-machine model) and reporting the network
//! IO the delta-bit encoding would send.
//!
//! Run: `cargo run --release --example distributed`

use std::sync::atomic::Ordering;
use ydf::dataset::synthetic;
use ydf::distributed::{DistributedGbtLearner, InProcessBackend, ThreadBackend};
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};

fn config() -> GbtConfig {
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = 20;
    cfg.max_depth = 5;
    cfg.validation_ratio = 0.0;
    cfg.early_stopping = ydf::learner::gbt::EarlyStopping::None;
    cfg
}

fn main() {
    let ds = synthetic::adult_like(2000, 31);

    let t0 = std::time::Instant::now();
    let single = GradientBoostedTreesLearner::new(config()).train(&ds).unwrap();
    let single_time = t0.elapsed().as_secs_f64();
    let single_json = single.to_json().to_string();

    for workers in [1usize, 2, 4, 8] {
        let learner = DistributedGbtLearner::new(config(), workers, InProcessBackend);
        let t0 = std::time::Instant::now();
        let model = learner.train(&ds).unwrap();
        let elapsed = t0.elapsed().as_secs_f64();
        let exact = model.to_json().to_string() == single_json;
        println!(
            "workers={workers:>2} backend=in-process time={elapsed:>6.2}s exact_match={} \
             net_bytes={} messages={}",
            exact,
            learner.net.bytes_sent.load(Ordering::Relaxed),
            learner.net.messages.load(Ordering::Relaxed),
        );
        assert!(exact, "distributed training must be exact");
    }

    let learner = DistributedGbtLearner::new(config(), 4, ThreadBackend);
    let t0 = std::time::Instant::now();
    let model = learner.train(&ds).unwrap();
    println!(
        "workers= 4 backend=threads    time={:>6.2}s exact_match={}",
        t0.elapsed().as_secs_f64(),
        model.to_json().to_string() == single_json
    );
    println!("single-machine reference time: {single_time:.2}s");
}
