//! Inference engines (§3.7): a model is *compiled* into an engine chosen
//! by model structure and available backends, trading space, complexity
//! and latency. Engines:
//!
//! * [`naive::NaiveEngine`] — Algorithm 1, pointer-chasing traversal.
//! * [`flat::FlatEngine`] — structure-of-arrays layout, branch-light.
//! * [`quickscorer::QuickScorerEngine`] — Lucchese et al. 2015 bitvector
//!   traversal for trees with ≤ 64 leaves (the engine the B.4 report calls
//!   `GradientBoostedTreesQuickScorer`).
//! * [`pjrt::PjrtEngine`] — the XLA artifact produced by the build-time
//!   JAX/Pallas layers, executed through the PJRT C API.

pub mod flat;
pub mod naive;
pub mod pjrt;
pub mod quickscorer;

use crate::dataset::{Dataset, Observation};
use crate::model::Model;

/// A compiled inference engine.
pub trait InferenceEngine: Send + Sync {
    /// Engine name as shown by `benchmark_inference` (B.4).
    fn name(&self) -> String;
    /// Predicts one row observation (probabilities / regression value).
    fn predict_row(&self, obs: &Observation) -> Vec<f64>;
    /// Predicts a whole dataset.
    fn predict_dataset(&self, ds: &Dataset) -> Vec<Vec<f64>> {
        (0..ds.num_rows()).map(|r| self.predict_row(&ds.row(r))).collect()
    }
}

/// Compiles all engines compatible with `model`, fastest first. This is
/// the automatic engine selection of §3.7: callers normally use
/// `engines.first()`.
pub fn compile_engines(model: &dyn Model) -> Vec<Box<dyn InferenceEngine>> {
    let mut out: Vec<Box<dyn InferenceEngine>> = Vec::new();
    if let Some(qs) = quickscorer::QuickScorerEngine::compile(model) {
        out.push(Box::new(qs));
    }
    if let Some(flat) = flat::FlatEngine::compile(model) {
        out.push(Box::new(flat));
    }
    out.push(Box::new(naive::NaiveEngine::compile(model)));
    out
}

/// Inference benchmark report (Appendix B.4): runs every compatible engine
/// over the dataset `runs` times and reports µs/example.
pub fn benchmark_inference_report(
    model: &dyn Model,
    ds: &Dataset,
    runs: usize,
) -> String {
    let engines = compile_engines(model);
    let mut rows: Vec<(String, f64)> = Vec::new();
    for e in &engines {
        let t0 = std::time::Instant::now();
        for _ in 0..runs.max(1) {
            std::hint::black_box(e.predict_dataset(ds));
        }
        let per_example = t0.elapsed().as_secs_f64() / (runs.max(1) * ds.num_rows()) as f64;
        rows.push((e.name(), per_example * 1e6));
    }
    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut out = format!(
        "Inference benchmark: {} engines compatible with the model, {} examples x {} runs\n",
        engines.len(),
        ds.num_rows(),
        runs
    );
    for (name, us) in rows {
        out.push_str(&format!("  {name:<42} {us:>10.3} us/example\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};

    #[test]
    fn engine_selection_order() {
        let ds = synthetic::adult_like(200, 111);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 5;
        cfg.max_depth = 4; // <= 64 leaves -> QuickScorer compatible
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let engines = compile_engines(model.as_ref());
        assert!(engines.len() >= 3);
        assert!(engines[0].name().contains("QuickScorer"), "{}", engines[0].name());
    }

    #[test]
    fn b4_report_renders() {
        let ds = synthetic::adult_like(100, 113);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 3;
        cfg.max_depth = 3;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let rep = benchmark_inference_report(model.as_ref(), &ds, 2);
        assert!(rep.contains("us/example"));
        assert!(rep.contains("engines compatible"));
    }
}
