//! Micro-batching model serving runtime: the shared online-serving layer
//! on top of the batch-first inference contract (`docs/serving.md`).
//!
//! The paper positions YDF as a library for "training, serving and
//! interpretation" with production serving as a first-class concern
//! (§3.7); this module turns the offline batch path into an online one.
//! Guan et al. ("A Comparison of Decision Forest Inference Platforms from
//! A Database Perspective") observe that *batching policy*, not just
//! kernel speed, dominates end-to-end forest-serving throughput — the
//! runtime here makes that policy an explicit, configurable knob.
//!
//! Five modules:
//!
//! * [`session`] — a loaded model pinned to its auto-selected engine, with
//!   dataspec-driven request decoding: feature-name → column mapping and
//!   direct materialization of incoming rows into columnar
//!   [`crate::dataset::ColumnData`] scratch ([`session::RowBlock`]) that
//!   is reused across calls.
//! * [`batcher`] — a bounded submission queue that coalesces concurrent
//!   single/multi-row requests into blocks: flush when the pending rows
//!   reach a [`crate::inference::BLOCK_SIZE`]-multiple threshold or when
//!   the oldest request has waited past a configurable deadline; score
//!   once via the engine batch path — fanning the block spans of a large
//!   coalesced flush out across a persistent scoring pool
//!   (`utils/pool.rs`, the `predict_into` contract) — and scatter results
//!   back to per-request waiters. The bounded queue rejects when full —
//!   natural backpressure, never an unbounded buffer or an indefinite
//!   block.
//! * [`registry`] — several named models behind one server: each
//!   [`Session`] keeps its own [`Batcher`] and [`ServingStats`], requests
//!   route by the wire protocol's top-level `"model"` field (absent ⇒ the
//!   default model, preserving the single-model protocol), and all
//!   batchers share one scoring pool.
//! * [`server`] — a `std::net` TCP front end speaking newline-delimited
//!   JSON (via `utils/json.rs`) over a worker pool (`utils/pool.rs`).
//! * [`stats`] — latency histograms (`utils/histogram.rs`) plus
//!   throughput / queue-depth counters, exportable as JSON per model and
//!   aggregated across the registry.
//!
//! Layered on top is a fault-tolerant **control plane**:
//!
//! * **Hot reload** — `{"cmd": "load"|"swap"|"unload"}` admin requests
//!   mutate the live [`Registry`]: the incoming session is built with no
//!   registry lock held, installed by an atomic entry swap, and the
//!   outgoing generation drains in the background with zero accepted
//!   requests dropped (lifecycle `Loading → Serving → Draining →
//!   Retired`/`Failed`, surfaced by `{"cmd": "health"}`).
//! * **Deadlines** — read/write timeouts on every accepted connection
//!   (slowloris/idle reaping) plus a per-request queue deadline: requests
//!   that wait too long are shed with a retryable in-band error carrying
//!   a `retry_after_ms` hint derived from observed flush latency.
//! * **Admission control** — per-model queue quotas and a shared
//!   cross-model pending-row budget layered on the reject-on-full
//!   backpressure.
//! * **Fleet routing** (the [`route`] module, the `ydf route` CLI mode) —
//!   one logical endpoint over N backend server processes: rendezvous
//!   hashing on the `"model"` field with per-model replica sets,
//!   per-backend health probes (`Healthy → Suspect → Down → Recovering`),
//!   bounded per-hop timeouts, retry-on-next-replica with exponential
//!   backoff + jitter under a retry budget (idempotent predict requests
//!   only), in-band `{"retryable": true}` degradation when every replica
//!   of a model is down, and admin `drain`/`undrain` of a backend for
//!   zero-drop removal.
//! * **Fault injection** (the `faults` module, compiled under
//!   `cfg(any(test, feature = "fault-injection"))`) — armed budgets for
//!   scorer panics mid-flush, artificial flush latency and connection
//!   stalls, driving the chaos tests; scorer panics are caught at the
//!   flush boundary and answered as in-band errors, so one bad batch
//!   never takes the server down.
//!
//! The CLI exposes all of this as `ydf serve --model=name=path …` (the
//! flag repeats to serve several models from one port); the wire
//! protocol is specified in `docs/serving.md` ("Server loop" and
//! "Control plane & failure modes") and `cargo bench --bench b5_serving`
//! tracks µs/request and requests/s across request-size × concurrency ×
//! model-count combinations in `BENCH_serving.json`.
//!
//! ```
//! use ydf::learner::gbt::GbtConfig;
//! use ydf::learner::{GradientBoostedTreesLearner, Learner};
//! use ydf::serving::batcher::{Batcher, BatcherConfig};
//! use ydf::serving::session::Session;
//! use ydf::utils::json::Json;
//! use std::sync::Arc;
//!
//! let data = ydf::dataset::synthetic::adult_like(200, 7);
//! let mut config = GbtConfig::new("income");
//! config.num_trees = 5;
//! config.max_depth = 3;
//! let model = GradientBoostedTreesLearner::new(config).train(&data).unwrap();
//! let session = Arc::new(Session::new(model));
//! let batcher = Batcher::new(Arc::clone(&session), BatcherConfig::default());
//! // Decode one request into reusable columnar scratch and submit it.
//! let mut block = session.new_block();
//! let row = Json::parse(r#"{"age": 44, "education": "Masters"}"#).unwrap();
//! session.decode_row(&mut block, &row).unwrap();
//! let pending = batcher.submit(&block).unwrap();
//! let predictions = pending.wait().unwrap(); // one probability per class
//! assert_eq!(predictions.len(), session.output_dim());
//! ```

pub mod batcher;
#[cfg(any(test, feature = "fault-injection"))]
pub mod faults;
pub mod registry;
pub mod route;
pub mod server;
pub mod session;
pub mod stats;

#[cfg(test)]
mod chaos_tests;

pub use batcher::{AdmissionControl, Batcher, BatcherConfig, Pending, ScoreError, SubmitError};
#[cfg(any(test, feature = "fault-injection"))]
pub use faults::FaultPlan;
pub use registry::{Lifecycle, LoadTicket, ModelEntry, Registry};
pub use route::{route, HealthFsm, HealthState, RouteConfig};
pub use server::{serve, serve_shared, ServerConfig};
pub use session::{RowBlock, Session};
pub use stats::ServingStats;
