//! Distributed training (§3.9): feature-parallel exact decision-forest
//! training after Guillame-Bert & Teytaud (2018).
//!
//! The distribution API is modular: [`backend::Backend`] abstracts how
//! worker computations run. Two implementations ship — the in-process
//! sequential backend ("specialized for development, debugging and
//! unit-testing", the paper's third implementation) and a thread-pool
//! backend. Workers own disjoint feature shards; for each node the leader
//! gathers per-worker best splits, picks the global best, asks the winning
//! feature's owner to materialize the example partition, and broadcasts it
//! as a delta-encoded bitmap (the paper's "delta-bit encoding" that
//! minimizes the maximum network IO among workers).

pub mod backend;
pub mod learner;

pub use backend::{Backend, InProcessBackend, ThreadBackend};
pub use learner::DistributedGbtLearner;

use crate::dataset::Dataset;
use crate::model::tree::{DecisionTree, Node};
use crate::splitter::score::Labels;
use crate::splitter::{
    better_candidate, find_best_split, ColumnIndex, NodeScratch, SplitCandidate,
    SplitterConfig,
};
use crate::utils::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

/// Network accounting: bytes that would cross the network in a real
/// multi-machine deployment (split proposals + partition broadcasts).
#[derive(Default)]
pub struct NetworkStats {
    pub bytes_sent: AtomicU64,
    pub messages: AtomicU64,
}

impl NetworkStats {
    pub fn record(&self, bytes: u64) {
        self.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }
}

/// Round-robin assignment of feature columns to workers. The paper notes
/// assignments adapt to worker availability; here availability is uniform
/// so round-robin is the balanced choice.
pub fn shard_features(features: &[usize], workers: usize) -> Vec<Vec<usize>> {
    let mut shards = vec![Vec::new(); workers.max(1)];
    for (i, &f) in features.iter().enumerate() {
        shards[i % workers.max(1)].push(f);
    }
    shards
}

/// Delta-bit encoding of a partition bitmap: positions of set bits encoded
/// as gaps, each gap varint-encoded. Returns the encoded size in bytes —
/// the quantity the network accounting charges (the real bytes stay local
/// in this single-process simulation).
pub fn delta_bit_encoded_size(partition: &[bool]) -> u64 {
    let mut bytes = 0u64;
    let mut last = 0usize;
    let mut first = true;
    for (i, &b) in partition.iter().enumerate() {
        if b {
            let gap = if first { i } else { i - last };
            first = false;
            last = i;
            // varint size
            let mut g = gap as u64;
            let mut n = 1;
            while g >= 0x80 {
                g >>= 7;
                n += 1;
            }
            bytes += n;
        }
    }
    bytes.max(1)
}

/// One worker's view: its feature shard and its private split-search
/// scratch (the shared read-only [`ColumnIndex`] is passed to
/// [`grow_tree_distributed`] — workers only own mutable state).
pub struct WorkerState {
    pub features: Vec<usize>,
    pub scratch: NodeScratch,
    pub rng: Rng,
}

/// Grows one tree with feature-parallel workers. Produces the *same tree*
/// as the single-machine grower given the same candidate features (exact
/// distributed training): gains are deterministic and ties are broken by
/// the leader in worker order.
#[allow(clippy::too_many_arguments)]
pub fn grow_tree_distributed<B: Backend>(
    ds: &Dataset,
    rows: Vec<u32>,
    labels: &Labels,
    workers: &mut [WorkerState],
    index: &ColumnIndex,
    splitter: &SplitterConfig,
    max_depth: usize,
    min_examples: usize,
    backend: &B,
    net: &NetworkStats,
) -> DecisionTree {
    let leaf_from_rows = |rows: &[u32]| -> Node {
        let mut acc = labels.new_acc();
        for &r in rows {
            acc.add(labels, r as usize);
        }
        Node::leaf(acc.leaf_value(labels), rows.len() as f64)
    };

    let mut tree = DecisionTree { nodes: vec![leaf_from_rows(&rows)] };
    let mut stack = vec![(0usize, rows, 0usize)];
    while let Some((idx, node_rows, depth)) = stack.pop() {
        if depth >= max_depth || node_rows.len() < 2 * min_examples.max(1) {
            continue;
        }
        // Each worker proposes its best split over its feature shard.
        let proposals: Vec<Option<SplitCandidate>> =
            backend.map_workers(workers, &|w: &mut WorkerState| {
                let cand = find_best_split(
                    ds,
                    &node_rows,
                    labels,
                    &w.features,
                    splitter,
                    index,
                    &mut w.scratch,
                    &mut w.rng,
                );
                // A proposal message: condition + gain, ~32 bytes.
                net.record(32);
                cand
            });
        // Leader reduction with the shared `(gain, lowest feature index)`
        // tie-break — the same total order every worker's local reduction
        // used, so the hierarchical reduce equals the single-machine flat
        // reduce and distributed training is bit-exact.
        let best = proposals.into_iter().flatten().fold(
            None::<SplitCandidate>,
            |acc, c| match acc {
                None => Some(c),
                Some(b) => {
                    if better_candidate(&c, &b) {
                        Some(c)
                    } else {
                        Some(b)
                    }
                }
            },
        );
        let split = match best {
            Some(s) if s.gain > 1e-12 => s,
            _ => continue,
        };
        // The winning worker materializes the partition; the leader
        // broadcasts it delta-bit encoded to all other workers.
        let (pos_rows, neg_rows) = crate::splitter::partition_rows(
            ds,
            &node_rows,
            &split.condition,
            split.missing_to_positive,
        );
        let mut partition = vec![false; node_rows.len()];
        {
            use std::collections::HashSet;
            let pos_set: HashSet<u32> = pos_rows.iter().copied().collect();
            for (i, &r) in node_rows.iter().enumerate() {
                partition[i] = pos_set.contains(&r);
            }
        }
        let encoded = delta_bit_encoded_size(&partition);
        // Broadcast to (workers - 1) peers.
        net.record(encoded * (workers.len().saturating_sub(1)) as u64);

        if pos_rows.len() < min_examples || neg_rows.len() < min_examples {
            continue;
        }
        let pos_idx = tree.nodes.len() as u32;
        tree.nodes.push(leaf_from_rows(&pos_rows));
        let neg_idx = tree.nodes.len() as u32;
        tree.nodes.push(leaf_from_rows(&neg_rows));
        {
            let node = &mut tree.nodes[idx];
            node.condition = Some(split.condition);
            node.positive = pos_idx;
            node.negative = neg_idx;
            node.missing_to_positive = split.missing_to_positive;
            node.score = split.gain as f32;
            node.value = vec![];
        }
        stack.push((pos_idx as usize, pos_rows, depth + 1));
        stack.push((neg_idx as usize, neg_rows, depth + 1));
    }
    tree
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharding_is_balanced_and_complete() {
        let features: Vec<usize> = (0..10).collect();
        let shards = shard_features(&features, 3);
        assert_eq!(shards.len(), 3);
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, features);
        assert!(shards.iter().all(|s| s.len() >= 3));
    }

    #[test]
    fn delta_encoding_smaller_for_sparse() {
        let mut sparse = vec![false; 1000];
        sparse[5] = true;
        sparse[900] = true;
        let dense: Vec<bool> = (0..1000).map(|i| i % 2 == 0).collect();
        assert!(delta_bit_encoded_size(&sparse) < delta_bit_encoded_size(&dense));
        // Dense alternating pattern: 500 bits, 1 byte per gap.
        assert_eq!(delta_bit_encoded_size(&dense), 500);
    }

    #[test]
    fn network_stats_accumulate() {
        let net = NetworkStats::default();
        net.record(10);
        net.record(20);
        assert_eq!(net.bytes_sent.load(Ordering::Relaxed), 30);
        assert_eq!(net.messages.load(Ordering::Relaxed), 2);
    }
}
