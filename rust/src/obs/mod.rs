//! Unified observability: named metrics, leveled logging, and execution
//! tracing — dependency-free, near-zero-cost when idle.
//!
//! Three faces (the paper's "simplicity and safety of use" principle
//! applied to operations — behavior should be measurable, not guessed):
//!
//! * **Metrics** (this module): a process-global registry of named,
//!   optionally labeled counters and gauges backed by relaxed atomics.
//!   The serving batcher feeds per-flush engine timings (the data the
//!   adaptive-engine-routing ROADMAP item needs), the learners feed
//!   per-tree training counters, `utils/pool.rs` feeds pool activity,
//!   and the inference layer feeds batch-call counters. Rendered in
//!   Prometheus text exposition format by [`prom`] — the serving wire
//!   protocol exposes it as `{"cmd": "metrics"}`.
//! * **Logging** ([`log`]): a leveled facade (`YDF_LOG=off|warn|info|
//!   debug`, default `warn`) behind the [`crate::ydf_warn!`],
//!   [`crate::ydf_info!`] and [`crate::ydf_debug!`] macros. Training
//!   progress (per-iteration loss, per-tree events) logs at `info`/
//!   `debug`; misconfiguration warnings at `warn`.
//! * **Tracing** ([`trace`]): Chrome trace-event JSON spans (request
//!   lifecycle, per-flush scoring, per-tree training), enabled by
//!   `ydf serve --trace=FILE` / `ydf train --trace=FILE`. One relaxed
//!   atomic load per span site when disabled — no allocation, no lock.
//!
//! Hot paths cache their metric handles in `OnceLock` statics: the
//! registry lock is taken once per (name, label-set) for the process
//! lifetime, after which a metric update is one relaxed `fetch_add`.

pub mod log;
pub mod prom;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotone counter. Cheap to clone (an `Arc` around one atomic);
/// updates are relaxed — counters are statistics, not synchronization.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins gauge (current value, not a running total).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// What a registered metric is, for exposition (`# TYPE` lines).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MetricKind {
    Counter,
    Gauge,
}

impl MetricKind {
    pub fn name(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        }
    }
}

/// One metric family in a [`Metrics::snapshot`]: every label-set series
/// registered under one name, values read at snapshot time.
pub struct MetricFamily {
    pub name: &'static str,
    pub help: &'static str,
    pub kind: MetricKind,
    /// `(sorted label pairs, value)` per series, in deterministic order.
    pub series: Vec<(Vec<(String, String)>, u64)>,
}

struct Slot {
    help: &'static str,
    kind: MetricKind,
    /// Label-set → value cell. `BTreeMap` keeps exposition deterministic.
    series: BTreeMap<Vec<(String, String)>, Arc<AtomicU64>>,
}

/// The process-global named-metric registry. Registration is idempotent:
/// asking for the same `(name, labels)` twice returns handles to the
/// same underlying cell, so call sites don't need to coordinate.
pub struct Metrics {
    slots: Mutex<BTreeMap<&'static str, Slot>>,
}

/// The global registry ([`Metrics`]). Exists for the process lifetime;
/// a long-lived server accumulates counters across model reloads.
pub fn metrics() -> &'static Metrics {
    static METRICS: OnceLock<Metrics> = OnceLock::new();
    METRICS.get_or_init(|| Metrics { slots: Mutex::new(BTreeMap::new()) })
}

impl Metrics {
    pub fn counter(&self, name: &'static str, help: &'static str) -> Counter {
        self.counter_with(name, help, &[])
    }

    /// A counter series under `name` with the given label pairs (label
    /// order does not matter; pairs are sorted by label name).
    pub fn counter_with(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
    ) -> Counter {
        Counter(self.cell(name, help, MetricKind::Counter, labels))
    }

    pub fn gauge(&self, name: &'static str, help: &'static str) -> Gauge {
        self.gauge_with(name, help, &[])
    }

    pub fn gauge_with(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
    ) -> Gauge {
        Gauge(self.cell(name, help, MetricKind::Gauge, labels))
    }

    fn cell(
        &self,
        name: &'static str,
        help: &'static str,
        kind: MetricKind,
        labels: &[(&str, &str)],
    ) -> Arc<AtomicU64> {
        let mut key: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        key.sort();
        let mut slots = match self.slots.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let slot = slots.entry(name).or_insert_with(|| Slot {
            help,
            kind,
            series: BTreeMap::new(),
        });
        debug_assert_eq!(
            slot.kind, kind,
            "metric '{name}' registered with two different kinds"
        );
        Arc::clone(slot.series.entry(key).or_default())
    }

    /// A point-in-time read of every registered series, families and
    /// series both in deterministic (name, label) order.
    pub fn snapshot(&self) -> Vec<MetricFamily> {
        let slots = match self.slots.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        slots
            .iter()
            .map(|(&name, slot)| MetricFamily {
                name,
                help: slot.help,
                kind: slot.kind,
                series: slot
                    .series
                    .iter()
                    .map(|(labels, cell)| (labels.clone(), cell.load(Ordering::Relaxed)))
                    .collect(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent_and_shared() {
        let a = metrics().counter("ydf_test_obs_shared_total", "test counter");
        let b = metrics().counter("ydf_test_obs_shared_total", "test counter");
        let before = a.get();
        b.add(3);
        assert_eq!(a.get(), before + 3, "both handles hit the same cell");
    }

    #[test]
    fn labeled_series_are_distinct_and_snapshot_ordered() {
        let x = metrics().counter_with(
            "ydf_test_obs_labeled_total",
            "test labeled counter",
            &[("engine", "x")],
        );
        let y = metrics().counter_with(
            "ydf_test_obs_labeled_total",
            "test labeled counter",
            &[("engine", "y")],
        );
        x.add(1);
        y.add(2);
        let snap = metrics().snapshot();
        let fam = snap
            .iter()
            .find(|f| f.name == "ydf_test_obs_labeled_total")
            .expect("family registered");
        assert_eq!(fam.kind, MetricKind::Counter);
        assert!(fam.series.len() >= 2);
        // Series come out label-sorted: engine=x before engine=y.
        let labels: Vec<&str> = fam
            .series
            .iter()
            .filter_map(|(ls, _)| ls.iter().find(|(k, _)| k == "engine").map(|(_, v)| v.as_str()))
            .collect();
        let mut sorted = labels.clone();
        sorted.sort();
        assert_eq!(labels, sorted);
    }

    #[test]
    fn gauge_is_last_write_wins() {
        let g = metrics().gauge("ydf_test_obs_gauge", "test gauge");
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
    }
}
