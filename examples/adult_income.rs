//! Full CLI-equivalent workflow of §4.1 / Appendix B on the Adult-like
//! dataset: dataspec inference + report, training, model report,
//! evaluation report, predictions, and the engine inference benchmark
//! (B.1–B.4), exercised through the same library calls the `ydf` binary
//! uses.
//!
//! Run: `cargo run --release --example adult_income`

use ydf::dataset::csv::{read_csv_str, write_csv_string};
use ydf::dataset::dataspec::InferenceOptions;
use ydf::dataset::synthetic;
use ydf::evaluation::evaluate_model;
use ydf::inference::benchmark_inference_report;
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};
use ydf::model::io::{load_model, model_to_string, model_from_string};

fn main() {
    // The dataset is stored as CSV (as in the paper's usage example);
    // round-trip through the CSV reader to exercise dataspec inference.
    let raw_train = synthetic::adult_like(3000, 10);
    let raw_test = synthetic::adult_like(1500, 11);
    let train_csv = write_csv_string(&raw_train);
    let test_csv = write_csv_string(&raw_test);

    // --- infer_dataspec + show_dataspec (B.1) ---
    let train = read_csv_str(&train_csv, &InferenceOptions::default()).unwrap();
    let test = read_csv_str(&test_csv, &InferenceOptions::default()).unwrap();
    println!("=== B.1 Column information (show_dataspec) ===");
    println!("{}", train.spec.describe(train.num_rows()));

    // --- train (GBT, default hyper-parameters) ---
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = 80;
    cfg.max_depth = 5;
    let model = GradientBoostedTreesLearner::new(cfg).train(&train).unwrap();

    // Model files round-trip through the versioned format (§3.11).
    let text = model_to_string(model.as_ref());
    let model = model_from_string(&text).unwrap();
    let _ = load_model; // (same entry point, file-based)

    // --- show_model (B.2) ---
    println!("=== B.2 Model information (show_model) ===");
    println!("{}", model.describe());

    // --- evaluate (B.3) ---
    println!("=== B.3 Model evaluation report ===");
    let ev = evaluate_model(model.as_ref(), &test, "income").unwrap();
    println!("{}", ev.report());

    // --- predict (batch path: fastest engine over columnar storage) ---
    let (preds, dim) = ydf::inference::predict_flat(model.as_ref(), &test);
    println!("first predictions: {:?}\n", &preds[..(3 * dim).min(preds.len())]);

    // --- benchmark_inference (B.4) ---
    println!("=== B.4 Model inference benchmark ===");
    println!("{}", benchmark_inference_report(model.as_ref(), &test, 5));
}
