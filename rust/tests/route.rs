//! Fleet-routing integration tests (`ydf::serving::route`): routed
//! responses are byte-identical to direct backend responses and
//! bit-identical to the offline batch path; when every replica is down
//! the router degrades in band with a retryable shed; and the chaos
//! gate — one of two replicas killed mid-traffic — loses zero accepted
//! requests, emits only in-band retryable errors, and re-admits the
//! killed backend after restart via health probes.

mod common;

use common::{adult_json_rows, adult_session_owned, decode_all};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;
use ydf::serving::{BatcherConfig, Registry, RouteConfig};
use ydf::utils::json::Json;

/// Reserves a free loopback address by binding port 0, then releasing it
/// for the server/router under test (the `listening on` stdout contract
/// is covered by the smoke script).
fn free_addr() -> SocketAddr {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap();
    drop(probe);
    addr
}

/// Starts one backend server at `addr` serving the deterministic
/// adult-like GBT under the name `m`.
fn start_backend(addr: SocketAddr, seed: u64) -> std::thread::JoinHandle<Result<(), String>> {
    let registry = Registry::new(BatcherConfig {
        max_delay: Duration::ZERO,
        ..Default::default()
    });
    registry.register("m", adult_session_owned(400, seed, 6, 4)).unwrap();
    let config = ydf::serving::ServerConfig {
        addr: addr.to_string(),
        // Headroom over the router's pooled forward connections (each
        // occupies a backend worker for its lifetime), the per-pass
        // probe connection, and direct test clients.
        workers: 8,
        ..Default::default()
    };
    std::thread::spawn(move || ydf::serving::serve(registry, &config))
}

/// Starts the router over `backends` at `addr` with a fast probe cadence.
fn start_router(
    addr: SocketAddr,
    backends: Vec<SocketAddr>,
) -> std::thread::JoinHandle<Result<(), String>> {
    let config = RouteConfig {
        addr: addr.to_string(),
        workers: 8,
        backends: backends.iter().map(|a| a.to_string()).collect(),
        probe_interval: Duration::from_millis(100),
        backoff_base_ms: 1,
        backoff_cap_ms: 20,
        ..Default::default()
    };
    std::thread::spawn(move || ydf::serving::route(&config))
}

/// Line-oriented JSON client with a bounded connect-retry loop (the
/// server under test comes up asynchronously).
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    return Client {
                        reader: BufReader::new(s.try_clone().unwrap()),
                        writer: s,
                    }
                }
                Err(e) => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "server never came up at {addr}: {e}"
                    );
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }

    /// One request line, one reply line. Every accepted request must get
    /// an in-band reply — a short read here is a dropped request.
    fn rpc_line(&mut self, line: &str) -> String {
        writeln!(self.writer, "{line}").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp).unwrap();
        assert!(n > 0, "connection closed without an in-band reply");
        resp.trim_end().to_string()
    }

    fn rpc(&mut self, line: &str) -> Json {
        let resp = self.rpc_line(line);
        Json::parse(&resp).unwrap_or_else(|e| panic!("bad reply '{resp}': {e}"))
    }
}

/// Waits (bounded) until `cond` holds, polling `every`.
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while !cond() {
        assert!(std::time::Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// The state the router's health block reports for one backend address.
fn backend_state(health: &Json, addr: &SocketAddr) -> String {
    let addr = addr.to_string();
    health
        .req("router")
        .unwrap()
        .req_arr("backends")
        .unwrap()
        .iter()
        .find(|b| b.req_str("addr").unwrap() == addr)
        .unwrap_or_else(|| panic!("backend {addr} missing from router health"))
        .req_str("state")
        .unwrap()
        .to_string()
}

/// Blocks until a backend at `addr` answers a health check (servers come
/// up asynchronously; the router must not see transport failures from a
/// backend that simply has not bound yet).
fn wait_backend_up(addr: SocketAddr) {
    let mut c = Client::connect(addr);
    assert_eq!(c.rpc(r#"{"cmd": "health"}"#).get("ok"), Some(&Json::Bool(true)));
}

/// Builds the wire request line for a slice of JSON row strings.
/// `adult_json_rows` fixtures embed newlines — flattened here because the
/// wire protocol is strictly one request per line.
fn request_line(rows: &[String]) -> String {
    let flat: Vec<String> = rows.iter().map(|r| r.replace('\n', " ")).collect();
    format!(r#"{{"model": "m", "rows": [{}]}}"#, flat.join(", "))
}

/// Routed responses over two healthy replicas are (a) byte-identical to
/// the same request sent directly to a backend — the router forwards
/// verbatim, it never rewrites a reply — and (b) bit-identical to the
/// offline `predict_block` over the same rows, NaN/missing rows and
/// unaligned tails included.
#[test]
fn routed_predictions_bit_identical_to_direct_and_offline() {
    let backend_addrs = [free_addr(), free_addr()];
    // Same seed on both backends: identical replicas of one model, as a
    // real replica set would be.
    let _backend_a = start_backend(backend_addrs[0], 81);
    let _backend_b = start_backend(backend_addrs[1], 81);
    wait_backend_up(backend_addrs[0]);
    wait_backend_up(backend_addrs[1]);
    let router_addr = free_addr();
    let router = start_router(router_addr, backend_addrs.to_vec());

    // Offline reference: the identical model, scored through one batch
    // call.
    let session = adult_session_owned(400, 81, 6, 4);
    let rows = adult_json_rows(101); // 101: unaligned tail in every block path
    let mut reference_block = decode_all(&session, &rows);
    let reference = session.predict_block(&mut reference_block);
    let dim = session.output_dim();

    let mut via_router = Client::connect(router_addr);
    let mut direct = Client::connect(backend_addrs[0]);

    // Mixed request sizes, covering every row exactly once.
    let sizes = [1usize, 8, 64, 3, 17, 2, 5, 1];
    let (mut at, mut k) = (0usize, 0usize);
    while at < rows.len() {
        let take = sizes[k % sizes.len()].min(rows.len() - at);
        let line = request_line(&rows[at..at + take]);
        let routed = via_router.rpc_line(&line);
        // Verbatim forwarding: the routed reply is byte-identical to the
        // direct one (both replicas serve the identical model).
        assert_eq!(routed, direct.rpc_line(&line), "rows {at}..{}", at + take);
        // And bit-identical to the offline batch path.
        let parsed = Json::parse(&routed).unwrap();
        let preds = parsed.req_arr("predictions").unwrap_or_else(|e| {
            panic!("rows {at}..{}: {e} in {routed}", at + take)
        });
        assert_eq!(preds.len(), take);
        for (i, row) in preds.iter().enumerate() {
            let got: Vec<f64> =
                row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
            let want = &reference[(at + i) * dim..(at + i + 1) * dim];
            assert_eq!(got.as_slice(), want, "row {}", at + i);
        }
        at += take;
        k += 1;
    }

    // The router block is live on the health wire, and both backends are
    // (or become) Healthy under probing.
    let health = via_router.rpc(r#"{"cmd": "health"}"#);
    assert_eq!(health.get("ok"), Some(&Json::Bool(true)));
    let block = health.req("router").unwrap();
    assert_eq!(block.req_arr("backends").unwrap().len(), 2);
    assert_eq!(block.req_f64("replicas").unwrap(), 2.0);
    // Metrics exposition carries the route families.
    let metrics = via_router.rpc(r#"{"cmd": "metrics"}"#);
    let text = metrics.req_str("metrics").unwrap();
    assert!(text.contains("ydf_route_forwarded_total"), "route families missing:\n{text}");

    // Shut everything down in-band.
    assert_eq!(via_router.rpc(r#"{"cmd": "shutdown"}"#).get("ok"), Some(&Json::Bool(true)));
    router.join().unwrap().expect("router exits cleanly");
    for addr in backend_addrs {
        let mut c = Client::connect(addr);
        c.rpc(r#"{"cmd": "shutdown"}"#);
    }
}

/// With every replica unreachable, predict requests degrade in band with
/// the Shed reply shape — `retryable: true` plus a `retry_after_ms`
/// hint — and the health block reports the backends Down.
#[test]
fn all_replicas_down_sheds_in_band() {
    // Two addresses nothing listens on (bound once, then released).
    let dead = [free_addr(), free_addr()];
    let router_addr = free_addr();
    let router = {
        let config = RouteConfig {
            addr: router_addr.to_string(),
            workers: 2,
            backends: dead.iter().map(|a| a.to_string()).collect(),
            probe_interval: Duration::from_millis(50),
            connect_timeout: Duration::from_millis(200),
            retry_budget: 1,
            backoff_base_ms: 1,
            backoff_cap_ms: 5,
            ..Default::default()
        };
        std::thread::spawn(move || ydf::serving::route(&config))
    };
    let mut client = Client::connect(router_addr);

    // Whether the probes have marked the backends Down yet or not, the
    // reply is in-band and retryable — never a dropped connection.
    let reply = client.rpc(r#"{"model": "m", "rows": [{"age": 30}]}"#);
    assert_eq!(reply.get("retryable"), Some(&Json::Bool(true)), "{reply}");
    assert!(reply.req_f64("retry_after_ms").unwrap() >= 1.0);
    assert!(reply.req_str("error").unwrap().contains("m"), "{reply}");

    // The probes converge both backends to Down.
    wait_until("both backends Down", || {
        let health = client.rpc(r#"{"cmd": "health"}"#);
        dead.iter().all(|a| backend_state(&health, a) == "Down")
    });
    // Down replicas shed immediately (no routable candidate to try).
    let reply = client.rpc(r#"{"model": "m", "rows": [{"age": 30}]}"#);
    assert_eq!(reply.get("retryable"), Some(&Json::Bool(true)), "{reply}");
    assert!(reply.req_str("error").unwrap().contains("down"), "{reply}");

    client.rpc(r#"{"cmd": "shutdown"}"#);
    router.join().unwrap().expect("router exits cleanly");
}

/// The chaos gate: two replicas of one model, one killed mid-traffic.
/// Every request gets an in-band reply (zero drops); successful replies
/// stay bit-identical to the offline reference throughout; only
/// retryable errors appear while the fleet degrades; and the killed
/// backend is re-admitted by health probes after it restarts.
#[test]
fn killed_replica_fails_over_and_readmits_after_restart() {
    let backend_addrs = [free_addr(), free_addr()];
    let backend_a = start_backend(backend_addrs[0], 91);
    let _backend_b = start_backend(backend_addrs[1], 91);
    wait_backend_up(backend_addrs[0]);
    wait_backend_up(backend_addrs[1]);
    let router_addr = free_addr();
    let router = start_router(router_addr, backend_addrs.to_vec());

    let session = adult_session_owned(400, 91, 6, 4);
    let rows = adult_json_rows(24);
    let mut reference_block = decode_all(&session, &rows);
    let reference = session.predict_block(&mut reference_block);
    let dim = session.output_dim();

    // One request per fixture row; asserts bit-identity on success and
    // returns whether the reply was a (legal) retryable shed instead.
    let check = |client: &mut Client, i: usize| -> bool {
        let reply = client.rpc(&request_line(&rows[i..i + 1]));
        if let Some(err) = reply.get("error") {
            assert_eq!(
                reply.get("retryable"),
                Some(&Json::Bool(true)),
                "only *retryable* in-band errors are acceptable mid-chaos: {err}"
            );
            assert!(reply.req_f64("retry_after_ms").unwrap() >= 1.0);
            return true;
        }
        let preds = reply.req_arr("predictions").unwrap();
        let got: Vec<f64> =
            preds[0].as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got.as_slice(), &reference[i * dim..(i + 1) * dim], "row {i}");
        false
    };

    let mut client = Client::connect(router_addr);
    // Phase 1: both replicas healthy — no request may shed.
    for i in 0..rows.len() {
        assert!(!check(&mut client, i), "no shed with a healthy fleet (row {i})");
    }

    // Kill replica A mid-traffic: concurrent clients hammer the router
    // while the backend goes away; every request still gets an in-band
    // reply, with sheds allowed only if they are retryable.
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(30));
        let mut direct = Client::connect(backend_addrs[0]);
        direct.rpc(r#"{"cmd": "shutdown"}"#);
    });
    let shed_count: usize = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|t: usize| {
                let check = &check;
                scope.spawn(move || {
                    let mut client = Client::connect(router_addr);
                    let mut sheds = 0usize;
                    for round in 0..12usize {
                        let i = (t * 12 + round) % rows.len();
                        if check(&mut client, i) {
                            sheds += 1;
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    sheds
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("no client panics")).sum()
    });
    killer.join().unwrap();
    backend_a.join().unwrap().expect("backend A exits cleanly");

    // With replica B alive and a retry budget, failover should absorb
    // the kill: requests retried onto B, not shed. Tolerate stray sheds
    // (they were in-band and retryable) but not systematic failure.
    assert!(shed_count <= 8, "failover mostly absorbed the kill, shed {shed_count}/48");

    // The router marks the killed replica Down...
    wait_until("killed backend marked Down", || {
        let health = client.rpc(r#"{"cmd": "health"}"#);
        backend_state(&health, &backend_addrs[0]) == "Down"
    });
    // ...while traffic keeps flowing bit-identically through B.
    for i in 0..rows.len() {
        assert!(!check(&mut client, i), "one healthy replica suffices (row {i})");
    }

    // Restart replica A on its old address: the probes walk it through
    // Recovering back to Healthy — re-admission needs no admin action.
    let backend_a = start_backend(backend_addrs[0], 91);
    wait_backend_up(backend_addrs[0]);
    wait_until("restarted backend re-admitted", || {
        let health = client.rpc(r#"{"cmd": "health"}"#);
        backend_state(&health, &backend_addrs[0]) == "Healthy"
    });
    // Full-fleet service again, still bit-identical.
    for i in 0..rows.len() {
        assert!(!check(&mut client, i), "restored fleet must not shed (row {i})");
    }

    // Drain the restarted backend: reported Draining, and traffic flows
    // unshed through the remaining replica — zero-drop removal.
    let drain = client.rpc(&format!(
        r#"{{"cmd": "drain", "backend": "{}"}}"#,
        backend_addrs[0]
    ));
    assert_eq!(drain.req_str("state").unwrap(), "Draining");
    for i in 0..8 {
        assert!(!check(&mut client, i), "drain must not shed (row {i})");
    }
    let undrain = client.rpc(&format!(
        r#"{{"cmd": "undrain", "backend": "{}"}}"#,
        backend_addrs[0]
    ));
    assert_eq!(undrain.req_str("state").unwrap(), "Serving");

    client.rpc(r#"{"cmd": "shutdown"}"#);
    router.join().unwrap().expect("router exits cleanly");
    for addr in backend_addrs {
        let mut c = Client::connect(addr);
        c.rpc(r#"{"cmd": "shutdown"}"#);
    }
    backend_a.join().unwrap().expect("restarted backend exits cleanly");
}
