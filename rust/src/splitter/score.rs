//! Label access and split scoring.
//!
//! Splitters are modular along two axes (§2.3): *feature type* (numerical,
//! categorical, boolean, categorical-set — one module each) and *label type*
//! (classification, regression, gradient pairs — this module). A label type
//! is a [`Labels`] view plus a [`ScoreAcc`] accumulator; every feature-type
//! splitter works with every label type through this interface, which is
//! exactly the code-reuse structure the paper describes for YDF's splitters.

/// Borrowed view of the training targets.
#[derive(Clone, Copy)]
pub enum Labels<'a> {
    /// Class index per example.
    Classification { labels: &'a [u32], num_classes: usize },
    /// Numerical target per example.
    Regression { targets: &'a [f32] },
    /// Gradient/hessian pair per example (GBT training). `use_hessian_gain`
    /// selects between variance gain over -g (default, §C.1) and the
    /// XGBoost-style G²/H gain.
    Gradients { grad: &'a [f32], hess: &'a [f32], use_hessian_gain: bool, l1: f64, l2: f64 },
}

impl<'a> Labels<'a> {
    pub fn new_acc(&self) -> ScoreAcc {
        match self {
            Labels::Classification { num_classes, .. } => {
                ScoreAcc::Class { counts: vec![0.0; *num_classes], n: 0.0 }
            }
            Labels::Regression { .. } => ScoreAcc::Reg { sum: 0.0, sum_sq: 0.0, n: 0.0 },
            Labels::Gradients { .. } => {
                ScoreAcc::Grad { g: 0.0, h: 0.0, neg_g_sq: 0.0, n: 0.0 }
            }
        }
    }

    pub fn num_examples(&self) -> usize {
        match self {
            Labels::Classification { labels, .. } => labels.len(),
            Labels::Regression { targets } => targets.len(),
            Labels::Gradients { grad, .. } => grad.len(),
        }
    }
}

/// Incremental accumulator of label statistics for one side of a split.
#[derive(Clone, Debug)]
pub enum ScoreAcc {
    Class { counts: Vec<f64>, n: f64 },
    Reg { sum: f64, sum_sq: f64, n: f64 },
    Grad { g: f64, h: f64, neg_g_sq: f64, n: f64 },
}

impl ScoreAcc {
    #[inline]
    pub fn add(&mut self, labels: &Labels, row: usize) {
        match (self, labels) {
            (ScoreAcc::Class { counts, n }, Labels::Classification { labels, .. }) => {
                counts[labels[row] as usize] += 1.0;
                *n += 1.0;
            }
            (ScoreAcc::Reg { sum, sum_sq, n }, Labels::Regression { targets }) => {
                let y = targets[row] as f64;
                *sum += y;
                *sum_sq += y * y;
                *n += 1.0;
            }
            (ScoreAcc::Grad { g, h, neg_g_sq, n }, Labels::Gradients { grad, hess, .. }) => {
                let gi = grad[row] as f64;
                *g += gi;
                *h += hess[row] as f64;
                *neg_g_sq += gi * gi;
                *n += 1.0;
            }
            _ => unreachable!("accumulator/label type mismatch"),
        }
    }

    #[inline]
    pub fn remove(&mut self, labels: &Labels, row: usize) {
        match (self, labels) {
            (ScoreAcc::Class { counts, n }, Labels::Classification { labels, .. }) => {
                counts[labels[row] as usize] -= 1.0;
                *n -= 1.0;
            }
            (ScoreAcc::Reg { sum, sum_sq, n }, Labels::Regression { targets }) => {
                let y = targets[row] as f64;
                *sum -= y;
                *sum_sq -= y * y;
                *n -= 1.0;
            }
            (ScoreAcc::Grad { g, h, neg_g_sq, n }, Labels::Gradients { grad, hess, .. }) => {
                let gi = grad[row] as f64;
                *g -= gi;
                *h -= hess[row] as f64;
                *neg_g_sq -= gi * gi;
                *n -= 1.0;
            }
            _ => unreachable!("accumulator/label type mismatch"),
        }
    }

    /// Merges another accumulator of the same kind.
    pub fn merge(&mut self, other: &ScoreAcc) {
        match (self, other) {
            (ScoreAcc::Class { counts, n }, ScoreAcc::Class { counts: c2, n: n2 }) => {
                for (a, b) in counts.iter_mut().zip(c2) {
                    *a += b;
                }
                *n += n2;
            }
            (
                ScoreAcc::Reg { sum, sum_sq, n },
                ScoreAcc::Reg { sum: s2, sum_sq: q2, n: n2 },
            ) => {
                *sum += s2;
                *sum_sq += q2;
                *n += n2;
            }
            (
                ScoreAcc::Grad { g, h, neg_g_sq, n },
                ScoreAcc::Grad { g: g2, h: h2, neg_g_sq: q2, n: n2 },
            ) => {
                *g += g2;
                *h += h2;
                *neg_g_sq += q2;
                *n += n2;
            }
            _ => unreachable!("accumulator kind mismatch"),
        }
    }

    pub fn count(&self) -> f64 {
        match self {
            ScoreAcc::Class { n, .. } | ScoreAcc::Reg { n, .. } | ScoreAcc::Grad { n, .. } => *n,
        }
    }

    /// Zeroes the accumulator in place, keeping its allocation — the pool
    /// operation of `NodeScratch` (reuse across nodes without reallocating
    /// the per-class count vector).
    pub fn reset(&mut self) {
        match self {
            ScoreAcc::Class { counts, n } => {
                counts.iter_mut().for_each(|c| *c = 0.0);
                *n = 0.0;
            }
            ScoreAcc::Reg { sum, sum_sq, n } => {
                *sum = 0.0;
                *sum_sq = 0.0;
                *n = 0.0;
            }
            ScoreAcc::Grad { g, h, neg_g_sq, n } => {
                *g = 0.0;
                *h = 0.0;
                *neg_g_sq = 0.0;
                *n = 0.0;
            }
        }
    }

    /// Whether a pooled accumulator can be reused (after [`ScoreAcc::reset`]) for
    /// this label view: same kind, and for classification the same class
    /// count.
    pub fn compatible(&self, labels: &Labels) -> bool {
        match (self, labels) {
            (ScoreAcc::Class { counts, .. }, Labels::Classification { num_classes, .. }) => {
                counts.len() == *num_classes
            }
            (ScoreAcc::Reg { .. }, Labels::Regression { .. }) => true,
            (ScoreAcc::Grad { .. }, Labels::Gradients { .. }) => true,
            _ => false,
        }
    }

    /// Node impurity × n (so gains are additive in examples).
    fn weighted_impurity(&self, labels: &Labels) -> f64 {
        match self {
            ScoreAcc::Class { counts, n } => {
                if *n <= 0.0 {
                    return 0.0;
                }
                // Shannon entropy (information gain splits, YDF default).
                let mut ent = 0.0;
                for &c in counts {
                    if c > 0.0 {
                        let p = c / n;
                        ent -= p * p.ln();
                    }
                }
                ent * n
            }
            ScoreAcc::Reg { sum, sum_sq, n } => {
                if *n <= 0.0 {
                    return 0.0;
                }
                // Variance × n = SSE.
                sum_sq - sum * sum / n
            }
            ScoreAcc::Grad { g, h, neg_g_sq, n } => {
                if *n <= 0.0 {
                    return 0.0;
                }
                if let Labels::Gradients { use_hessian_gain: true, l1, l2, .. } = labels {
                    // Negated XGBoost leaf objective: -G'^2 / (H + λ2);
                    // impurity form so gain = parent - children is positive.
                    let gg = soft_threshold(*g, *l1);
                    -(gg * gg) / (h + l2)
                } else {
                    // Variance of -g (Friedman residual-fitting).
                    neg_g_sq - g * g / n
                }
            }
        }
    }

    /// Split gain: impurity(parent) − impurity(left) − impurity(right).
    pub fn gain(parent: &ScoreAcc, left: &ScoreAcc, right: &ScoreAcc, labels: &Labels) -> f64 {
        parent.weighted_impurity(labels)
            - left.weighted_impurity(labels)
            - right.weighted_impurity(labels)
    }

    /// Leaf payload for this label type.
    pub fn leaf_value(&self, labels: &Labels) -> Vec<f32> {
        match self {
            ScoreAcc::Class { counts, n } => {
                if *n <= 0.0 {
                    vec![0.0; counts.len()]
                } else {
                    counts.iter().map(|&c| (c / n) as f32).collect()
                }
            }
            ScoreAcc::Reg { sum, n, .. } => {
                vec![if *n > 0.0 { (sum / n) as f32 } else { 0.0 }]
            }
            ScoreAcc::Grad { g, h, .. } => {
                if let Labels::Gradients { l1, l2, .. } = labels {
                    let gg = soft_threshold(*g, *l1);
                    vec![(-gg / (h + l2)).clamp(-1e4, 1e4) as f32]
                } else {
                    vec![0.0]
                }
            }
        }
    }

    /// Mean target used to order categories in the CART categorical
    /// splitter (Fisher 1958 / Breiman's exact trick for binary targets).
    pub fn ordering_statistic(&self, labels: &Labels) -> f64 {
        match self {
            ScoreAcc::Class { counts, n } => {
                // Probability of the globally most useful class: for binary
                // this is exactly p(class 1), optimal ordering; for
                // multiclass it is the standard one-vs-rest heuristic.
                if *n <= 0.0 {
                    0.0
                } else {
                    let _ = labels;
                    counts.last().map(|&c| c / n).unwrap_or(0.0)
                        + counts.get(1).map(|&c| c / n).unwrap_or(0.0)
                }
            }
            ScoreAcc::Reg { sum, n, .. } => {
                if *n > 0.0 {
                    sum / n
                } else {
                    0.0
                }
            }
            ScoreAcc::Grad { g, h, .. } => {
                if *h > 0.0 {
                    -g / h
                } else {
                    0.0
                }
            }
        }
    }
}

#[inline]
fn soft_threshold(g: f64, l1: f64) -> f64 {
    if l1 <= 0.0 {
        g
    } else if g > l1 {
        g - l1
    } else if g < -l1 {
        g + l1
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_gain_perfect_split() {
        let labels_data = vec![0u32, 0, 0, 0, 1, 1, 1, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let mut parent = labels.new_acc();
        let mut left = labels.new_acc();
        let mut right = labels.new_acc();
        for i in 0..8 {
            parent.add(&labels, i);
            if i < 4 {
                left.add(&labels, i);
            } else {
                right.add(&labels, i);
            }
        }
        let g = ScoreAcc::gain(&parent, &left, &right, &labels);
        // Perfect split: gain = n * ln 2.
        assert!((g - 8.0 * std::f64::consts::LN_2).abs() < 1e-9, "{g}");
    }

    #[test]
    fn classification_gain_useless_split_zero() {
        let labels_data = vec![0u32, 1, 0, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let mut parent = labels.new_acc();
        let mut left = labels.new_acc();
        let mut right = labels.new_acc();
        for i in 0..4 {
            parent.add(&labels, i);
            if i < 2 {
                left.add(&labels, i);
            } else {
                right.add(&labels, i);
            }
        }
        let g = ScoreAcc::gain(&parent, &left, &right, &labels);
        assert!(g.abs() < 1e-9);
    }

    #[test]
    fn regression_gain_is_sse_reduction() {
        let targets = vec![1.0f32, 1.0, 5.0, 5.0];
        let labels = Labels::Regression { targets: &targets };
        let mut parent = labels.new_acc();
        let mut left = labels.new_acc();
        let mut right = labels.new_acc();
        for i in 0..4 {
            parent.add(&labels, i);
            if i < 2 {
                left.add(&labels, i);
            } else {
                right.add(&labels, i);
            }
        }
        // Parent SSE = 4 * var = 16; children = 0.
        let g = ScoreAcc::gain(&parent, &left, &right, &labels);
        assert!((g - 16.0).abs() < 1e-9, "{g}");
        assert_eq!(left.leaf_value(&labels), vec![1.0]);
        assert_eq!(right.leaf_value(&labels), vec![5.0]);
    }

    #[test]
    fn add_remove_is_inverse() {
        let targets = vec![2.0f32, -1.0, 3.5];
        let labels = Labels::Regression { targets: &targets };
        let mut acc = labels.new_acc();
        acc.add(&labels, 0);
        acc.add(&labels, 1);
        acc.add(&labels, 2);
        acc.remove(&labels, 1);
        acc.remove(&labels, 2);
        assert_eq!(acc.leaf_value(&labels), vec![2.0]);
        assert!((acc.count() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gradient_leaf_is_newton_step() {
        let grad = vec![-1.0f32, -1.0, -1.0, -1.0];
        let hess = vec![0.5f32, 0.5, 0.5, 0.5];
        let labels =
            Labels::Gradients { grad: &grad, hess: &hess, use_hessian_gain: false, l1: 0.0, l2: 0.0 };
        let mut acc = labels.new_acc();
        for i in 0..4 {
            acc.add(&labels, i);
        }
        // -(Σg)/(Σh) = 4/2 = 2.
        assert_eq!(acc.leaf_value(&labels), vec![2.0]);
    }

    #[test]
    fn hessian_gain_prefers_separating_gradients() {
        let grad = vec![-1.0f32, -1.0, 1.0, 1.0];
        let hess = vec![1.0f32; 4];
        let labels =
            Labels::Gradients { grad: &grad, hess: &hess, use_hessian_gain: true, l1: 0.0, l2: 1.0 };
        let mut parent = labels.new_acc();
        let mut good_l = labels.new_acc();
        let mut good_r = labels.new_acc();
        let mut bad_l = labels.new_acc();
        let mut bad_r = labels.new_acc();
        for i in 0..4 {
            parent.add(&labels, i);
        }
        good_l.add(&labels, 0);
        good_l.add(&labels, 1);
        good_r.add(&labels, 2);
        good_r.add(&labels, 3);
        bad_l.add(&labels, 0);
        bad_l.add(&labels, 2);
        bad_r.add(&labels, 1);
        bad_r.add(&labels, 3);
        let g_good = ScoreAcc::gain(&parent, &good_l, &good_r, &labels);
        let g_bad = ScoreAcc::gain(&parent, &bad_l, &bad_r, &labels);
        assert!(g_good > g_bad, "{g_good} vs {g_bad}");
        assert!(g_good > 0.0);
    }

    #[test]
    fn merge_matches_bulk_add() {
        let labels_data = vec![0u32, 1, 1, 0, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let mut a = labels.new_acc();
        let mut b = labels.new_acc();
        let mut all = labels.new_acc();
        for i in 0..5 {
            all.add(&labels, i);
            if i % 2 == 0 {
                a.add(&labels, i);
            } else {
                b.add(&labels, i);
            }
        }
        a.merge(&b);
        assert_eq!(a.leaf_value(&labels), all.leaf_value(&labels));
    }

    #[test]
    fn l1_soft_threshold() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(2.0, 0.0), 2.0);
    }
}
