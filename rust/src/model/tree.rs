//! Decision tree structure: conditions, nodes and traversal.
//!
//! Condition types mirror YDF's (Appendix B.2 lists `HigherCondition`,
//! `ContainsBitmapCondition`, `ContainsCondition`; §3.8 adds oblique and
//! categorical-set splits). Each node records which branch receives missing
//! values (local imputation decided at training time, §3.4).

use crate::dataset::{AttrValue, ColumnData, Dataset, Observation};
use crate::utils::json::Json;

/// A split condition evaluated on one observation.
#[derive(Clone, Debug, PartialEq)]
pub enum Condition {
    /// `x[attr] >= threshold` — numerical features.
    Higher { attr: usize, threshold: f32 },
    /// `x[attr] ∈ set` with the set encoded as a bitmap over the dictionary
    /// — categorical features (the efficient form of ContainsCondition).
    ContainsBitmap { attr: usize, bitmap: Vec<u64> },
    /// `x[attr] ∩ set ≠ ∅` — categorical-set features (text tokens, §3.8).
    ContainsSetBitmap { attr: usize, bitmap: Vec<u64> },
    /// `Σ weights[i]·x[attrs[i]] >= threshold` — sparse oblique splits
    /// (Tomita et al.), the `split_axis: SPARSE_OBLIQUE` of benchmark hp.
    Oblique { attrs: Vec<usize>, weights: Vec<f32>, threshold: f32 },
    /// `x[attr] == true` — boolean features.
    IsTrue { attr: usize },
}

#[inline]
pub fn bitmap_contains(bitmap: &[u64], value: u32) -> bool {
    let w = (value / 64) as usize;
    w < bitmap.len() && (bitmap[w] >> (value % 64)) & 1 == 1
}

pub fn bitmap_from_items(items: &[u32], domain: usize) -> Vec<u64> {
    let mut bm = vec![0u64; domain.div_ceil(64)];
    for &it in items {
        bm[(it / 64) as usize] |= 1 << (it % 64);
    }
    bm
}

impl Condition {
    /// The primary attribute(s) tested by this condition.
    pub fn attributes(&self) -> Vec<usize> {
        match self {
            Condition::Higher { attr, .. }
            | Condition::ContainsBitmap { attr, .. }
            | Condition::ContainsSetBitmap { attr, .. }
            | Condition::IsTrue { attr } => vec![*attr],
            Condition::Oblique { attrs, .. } => attrs.clone(),
        }
    }

    /// The lowest attribute index the condition tests (oblique attrs are
    /// stored sorted). Allocation-free — this is the split tie-break key,
    /// compared on every candidate of every node during training.
    pub fn first_attribute(&self) -> Option<usize> {
        match self {
            Condition::Higher { attr, .. }
            | Condition::ContainsBitmap { attr, .. }
            | Condition::ContainsSetBitmap { attr, .. }
            | Condition::IsTrue { attr } => Some(*attr),
            Condition::Oblique { attrs, .. } => attrs.first().copied(),
        }
    }

    /// Human-readable name matching the paper's report vocabulary.
    pub fn type_name(&self) -> &'static str {
        match self {
            Condition::Higher { .. } => "HigherCondition",
            Condition::ContainsBitmap { .. } => "ContainsBitmapCondition",
            Condition::ContainsSetBitmap { .. } => "ContainsSetCondition",
            Condition::Oblique { .. } => "ObliqueCondition",
            Condition::IsTrue { .. } => "IsTrueCondition",
        }
    }

    /// Evaluates on a row-form observation. `None` = value missing.
    pub fn evaluate(&self, obs: &Observation) -> Option<bool> {
        match self {
            Condition::Higher { attr, threshold } => match &obs[*attr] {
                AttrValue::Num(x) if !x.is_nan() => Some(*x >= *threshold),
                _ => None,
            },
            Condition::ContainsBitmap { attr, bitmap } => match &obs[*attr] {
                AttrValue::Cat(c) => Some(bitmap_contains(bitmap, *c)),
                _ => None,
            },
            Condition::ContainsSetBitmap { attr, bitmap } => match &obs[*attr] {
                AttrValue::CatSet(items) => {
                    Some(items.iter().any(|&i| bitmap_contains(bitmap, i)))
                }
                _ => None,
            },
            Condition::Oblique { attrs, weights, threshold } => {
                let mut acc = 0.0f32;
                for (&a, &w) in attrs.iter().zip(weights) {
                    match &obs[a] {
                        AttrValue::Num(x) if !x.is_nan() => acc += w * x,
                        // Oblique projections impute missing as 0 (post
                        // normalization this is the mid-range), matching
                        // the sparse-oblique training-side treatment.
                        _ => {}
                    }
                }
                Some(acc >= *threshold)
            }
            Condition::IsTrue { attr } => match &obs[*attr] {
                AttrValue::Bool(b) => Some(*b),
                _ => None,
            },
        }
    }

    /// Evaluates against column storage (training/batch path — avoids
    /// materializing row observations).
    pub fn evaluate_ds(&self, ds: &Dataset, row: usize) -> Option<bool> {
        match self {
            Condition::Higher { attr, threshold } => {
                let x = match &ds.columns[*attr] {
                    ColumnData::Numerical(v) => v[row],
                    _ => return None,
                };
                if x.is_nan() {
                    None
                } else {
                    Some(x >= *threshold)
                }
            }
            Condition::ContainsBitmap { attr, bitmap } => {
                let c = match &ds.columns[*attr] {
                    ColumnData::Categorical(v) => v[row],
                    _ => return None,
                };
                if c == crate::dataset::MISSING_CAT {
                    None
                } else {
                    Some(bitmap_contains(bitmap, c))
                }
            }
            Condition::ContainsSetBitmap { attr, bitmap } => {
                let col = &ds.columns[*attr];
                if col.is_missing(row) {
                    return None;
                }
                col.set_values(row)
                    .map(|items| items.iter().any(|&i| bitmap_contains(bitmap, i)))
            }
            Condition::Oblique { attrs, weights, threshold } => {
                let mut acc = 0.0f32;
                for (&a, &w) in attrs.iter().zip(weights) {
                    if let ColumnData::Numerical(v) = &ds.columns[a] {
                        let x = v[row];
                        if !x.is_nan() {
                            acc += w * x;
                        }
                    }
                }
                Some(acc >= *threshold)
            }
            Condition::IsTrue { attr } => {
                let b = match &ds.columns[*attr] {
                    ColumnData::Boolean(v) => v[row],
                    _ => return None,
                };
                if b == crate::dataset::MISSING_BOOL {
                    None
                } else {
                    Some(b == 1)
                }
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self {
            Condition::Higher { attr, threshold } => {
                j.set("type", Json::Str("higher".into()))
                    .set("attr", Json::Num(*attr as f64))
                    .set("threshold", Json::Num(*threshold as f64));
            }
            Condition::ContainsBitmap { attr, bitmap } => {
                j.set("type", Json::Str("contains".into()))
                    .set("attr", Json::Num(*attr as f64))
                    .set(
                        "bitmap",
                        Json::Arr(bitmap.iter().map(|&w| Json::Str(format!("{w:x}"))).collect()),
                    );
            }
            Condition::ContainsSetBitmap { attr, bitmap } => {
                j.set("type", Json::Str("contains_set".into()))
                    .set("attr", Json::Num(*attr as f64))
                    .set(
                        "bitmap",
                        Json::Arr(bitmap.iter().map(|&w| Json::Str(format!("{w:x}"))).collect()),
                    );
            }
            Condition::Oblique { attrs, weights, threshold } => {
                j.set("type", Json::Str("oblique".into()))
                    .set("attrs", Json::from_usizes(attrs))
                    .set(
                        "weights",
                        Json::Arr(weights.iter().map(|&w| Json::Num(w as f64)).collect()),
                    )
                    .set("threshold", Json::Num(*threshold as f64));
            }
            Condition::IsTrue { attr } => {
                j.set("type", Json::Str("is_true".into()))
                    .set("attr", Json::Num(*attr as f64));
            }
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<Condition, String> {
        let parse_bitmap = |j: &Json| -> Result<Vec<u64>, String> {
            j.req_arr("bitmap")?
                .iter()
                .map(|v| {
                    u64::from_str_radix(v.as_str().unwrap_or(""), 16)
                        .map_err(|e| format!("bad bitmap word: {e}"))
                })
                .collect()
        };
        match j.req_str("type")? {
            "higher" => Ok(Condition::Higher {
                attr: j.req_usize("attr")?,
                threshold: j.req_f64("threshold")? as f32,
            }),
            "contains" => Ok(Condition::ContainsBitmap {
                attr: j.req_usize("attr")?,
                bitmap: parse_bitmap(j)?,
            }),
            "contains_set" => Ok(Condition::ContainsSetBitmap {
                attr: j.req_usize("attr")?,
                bitmap: parse_bitmap(j)?,
            }),
            "oblique" => Ok(Condition::Oblique {
                attrs: j
                    .req_arr("attrs")?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect(),
                weights: j
                    .req_arr("weights")?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                    .collect(),
                threshold: j.req_f64("threshold")? as f32,
            }),
            "is_true" => Ok(Condition::IsTrue { attr: j.req_usize("attr")? }),
            t => Err(format!("unknown condition type '{t}'")),
        }
    }
}

/// A tree node in arena storage.
#[derive(Clone, Debug)]
pub struct Node {
    /// `None` for leaves.
    pub condition: Option<Condition>,
    /// Index of the positive (condition true) child.
    pub positive: u32,
    /// Index of the negative child.
    pub negative: u32,
    /// Branch receiving missing values (local imputation result).
    pub missing_to_positive: bool,
    /// Leaf payload: class distribution (RF), single logit (GBT) or
    /// regression value. Empty on internal nodes.
    pub value: Vec<f32>,
    /// Number of training examples that reached this node.
    pub num_examples: f64,
    /// Split score (gain) — used by variable importances.
    pub score: f32,
}

impl Node {
    pub fn leaf(value: Vec<f32>, num_examples: f64) -> Node {
        Node {
            condition: None,
            positive: 0,
            negative: 0,
            missing_to_positive: false,
            value,
            num_examples,
            score: 0.0,
        }
    }

    pub fn is_leaf(&self) -> bool {
        self.condition.is_none()
    }
}

/// A decision tree in arena form; node 0 is the root.
#[derive(Clone, Debug, Default)]
pub struct DecisionTree {
    pub nodes: Vec<Node>,
}

impl DecisionTree {
    /// Algorithm 1 of the paper: iterate from the root, follow the branch
    /// given by the node condition, return the leaf.
    pub fn eval_row(&self, obs: &Observation) -> &Node {
        let mut idx = 0usize;
        loop {
            let node = &self.nodes[idx];
            let cond = match &node.condition {
                None => return node,
                Some(c) => c,
            };
            let go_positive = cond.evaluate(obs).unwrap_or(node.missing_to_positive);
            idx = if go_positive { node.positive as usize } else { node.negative as usize };
        }
    }

    /// Same traversal against column storage.
    pub fn eval_ds(&self, ds: &Dataset, row: usize) -> &Node {
        let mut idx = 0usize;
        loop {
            let node = &self.nodes[idx];
            let cond = match &node.condition {
                None => return node,
                Some(c) => c,
            };
            let go_positive = cond.evaluate_ds(ds, row).unwrap_or(node.missing_to_positive);
            idx = if go_positive { node.positive as usize } else { node.negative as usize };
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Maximum depth (root = 0). Iterative to avoid recursion limits on
    /// deep RF trees.
    pub fn max_depth(&self) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut max_d = 0;
        let mut stack = vec![(0u32, 0usize)];
        while let Some((idx, d)) = stack.pop() {
            let n = &self.nodes[idx as usize];
            if n.is_leaf() {
                max_d = max_d.max(d);
            } else {
                stack.push((n.positive, d + 1));
                stack.push((n.negative, d + 1));
            }
        }
        max_d
    }

    /// Per-leaf depths (for the `show_model` "Depth by leafs" histogram).
    pub fn leaf_depths(&self) -> Vec<usize> {
        let mut out = Vec::new();
        if self.nodes.is_empty() {
            return out;
        }
        let mut stack = vec![(0u32, 0usize)];
        while let Some((idx, d)) = stack.pop() {
            let n = &self.nodes[idx as usize];
            if n.is_leaf() {
                out.push(d);
            } else {
                stack.push((n.positive, d + 1));
                stack.push((n.negative, d + 1));
            }
        }
        out
    }

    /// Visits internal nodes with their depth.
    pub fn visit_internal<F: FnMut(&Node, usize)>(&self, mut f: F) {
        if self.nodes.is_empty() {
            return;
        }
        let mut stack = vec![(0u32, 0usize)];
        while let Some((idx, d)) = stack.pop() {
            let n = &self.nodes[idx as usize];
            if !n.is_leaf() {
                f(n, d);
                stack.push((n.positive, d + 1));
                stack.push((n.negative, d + 1));
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let mut nodes = Vec::with_capacity(self.nodes.len());
        for n in &self.nodes {
            let mut j = Json::obj();
            if let Some(c) = &n.condition {
                j.set("cond", c.to_json())
                    .set("pos", Json::Num(n.positive as f64))
                    .set("neg", Json::Num(n.negative as f64))
                    .set("miss_pos", Json::Bool(n.missing_to_positive))
                    .set("score", Json::Num(n.score as f64));
            } else {
                j.set(
                    "value",
                    Json::Arr(n.value.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
            }
            j.set("n", Json::Num(n.num_examples));
            nodes.push(j);
        }
        let mut j = Json::obj();
        j.set("nodes", Json::Arr(nodes));
        j
    }

    pub fn from_json(j: &Json) -> Result<DecisionTree, String> {
        let mut nodes = Vec::new();
        for nj in j.req_arr("nodes")? {
            let num_examples = nj.req_f64("n")?;
            let node = if let Some(cj) = nj.get("cond") {
                Node {
                    condition: Some(Condition::from_json(cj)?),
                    positive: nj.req_usize("pos")? as u32,
                    negative: nj.req_usize("neg")? as u32,
                    missing_to_positive: nj
                        .get("miss_pos")
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false),
                    value: vec![],
                    num_examples,
                    score: nj.get("score").and_then(|v| v.as_f64()).unwrap_or(0.0) as f32,
                }
            } else {
                Node::leaf(
                    nj.req_arr("value")?
                        .iter()
                        .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                        .collect(),
                    num_examples,
                )
            };
            nodes.push(node);
        }
        Ok(DecisionTree { nodes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::AttrValue;

    /// x0 >= 2.0 ? leaf[0.9] : (x1 in {1,3} ? leaf[0.5] : leaf[0.1])
    fn sample_tree() -> DecisionTree {
        DecisionTree {
            nodes: vec![
                Node {
                    condition: Some(Condition::Higher { attr: 0, threshold: 2.0 }),
                    positive: 1,
                    negative: 2,
                    missing_to_positive: false,
                    value: vec![],
                    num_examples: 100.0,
                    score: 0.5,
                },
                Node::leaf(vec![0.9], 40.0),
                Node {
                    condition: Some(Condition::ContainsBitmap {
                        attr: 1,
                        bitmap: bitmap_from_items(&[1, 3], 8),
                    }),
                    positive: 3,
                    negative: 4,
                    missing_to_positive: true,
                    value: vec![],
                    num_examples: 60.0,
                    score: 0.2,
                },
                Node::leaf(vec![0.5], 30.0),
                Node::leaf(vec![0.1], 30.0),
            ],
        }
    }

    #[test]
    fn traversal_follows_conditions() {
        let t = sample_tree();
        let leaf = t.eval_row(&vec![AttrValue::Num(3.0), AttrValue::Cat(0)]);
        assert_eq!(leaf.value, vec![0.9]);
        let leaf = t.eval_row(&vec![AttrValue::Num(1.0), AttrValue::Cat(3)]);
        assert_eq!(leaf.value, vec![0.5]);
        let leaf = t.eval_row(&vec![AttrValue::Num(1.0), AttrValue::Cat(0)]);
        assert_eq!(leaf.value, vec![0.1]);
    }

    #[test]
    fn missing_value_follows_configured_branch() {
        let t = sample_tree();
        // Root: missing_to_positive = false -> negative -> node 2; node 2
        // missing_to_positive = true -> leaf 3.
        let leaf = t.eval_row(&vec![AttrValue::Missing, AttrValue::Missing]);
        assert_eq!(leaf.value, vec![0.5]);
    }

    #[test]
    fn bitmap_roundtrip() {
        let bm = bitmap_from_items(&[0, 63, 64, 100], 128);
        assert!(bitmap_contains(&bm, 0));
        assert!(bitmap_contains(&bm, 63));
        assert!(bitmap_contains(&bm, 64));
        assert!(bitmap_contains(&bm, 100));
        assert!(!bitmap_contains(&bm, 1));
        assert!(!bitmap_contains(&bm, 127));
        assert!(!bitmap_contains(&bm, 4000)); // out of range is false
    }

    #[test]
    fn depth_and_leaves() {
        let t = sample_tree();
        assert_eq!(t.num_nodes(), 5);
        assert_eq!(t.num_leaves(), 3);
        assert_eq!(t.max_depth(), 2);
        let mut depths = t.leaf_depths();
        depths.sort_unstable();
        assert_eq!(depths, vec![1, 2, 2]);
    }

    #[test]
    fn json_roundtrip() {
        let t = sample_tree();
        let j = t.to_json();
        let back = DecisionTree::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.num_nodes(), t.num_nodes());
        let obs = vec![AttrValue::Num(1.0), AttrValue::Cat(3)];
        assert_eq!(back.eval_row(&obs).value, t.eval_row(&obs).value);
        match &back.nodes[2].condition {
            Some(Condition::ContainsBitmap { bitmap, .. }) => {
                assert!(bitmap_contains(bitmap, 1) && bitmap_contains(bitmap, 3));
            }
            other => panic!("bad condition {other:?}"),
        }
    }

    #[test]
    fn oblique_condition() {
        let c = Condition::Oblique {
            attrs: vec![0, 1],
            weights: vec![1.0, -1.0],
            threshold: 0.5,
        };
        let obs = vec![AttrValue::Num(2.0), AttrValue::Num(1.0)];
        assert_eq!(c.evaluate(&obs), Some(true));
        let obs = vec![AttrValue::Num(1.0), AttrValue::Num(1.0)];
        assert_eq!(c.evaluate(&obs), Some(false));
        // Missing coordinate contributes 0.
        let obs = vec![AttrValue::Missing, AttrValue::Num(-1.0)];
        assert_eq!(c.evaluate(&obs), Some(true));
    }

    #[test]
    fn condition_json_all_variants() {
        let conds = vec![
            Condition::Higher { attr: 3, threshold: -1.5 },
            Condition::ContainsBitmap { attr: 1, bitmap: vec![0b1010] },
            Condition::ContainsSetBitmap { attr: 2, bitmap: vec![0b1, 0b10] },
            Condition::Oblique {
                attrs: vec![0, 2],
                weights: vec![0.5, -0.25],
                threshold: 1.0,
            },
            Condition::IsTrue { attr: 7 },
        ];
        for c in conds {
            let j = Json::parse(&c.to_json().to_string()).unwrap();
            assert_eq!(Condition::from_json(&j).unwrap(), c);
        }
    }
}
