//! Quickstart: the paper's motto in action — "with only five lines of
//! configuration, you can produce a functional, competitive, trained and
//! tuned, fully evaluated and analysed machine learning model" (§2.1).
//!
//! Run: `cargo run --release --example quickstart`

use ydf::dataset::synthetic;
use ydf::evaluation::evaluate_model;
use ydf::learner::{GradientBoostedTreesLearner, Learner};

fn main() {
    // 1. Data (a synthetic Adult-like census dataset).
    let train = synthetic::adult_like(2000, 1);
    let test = synthetic::adult_like(1000, 2);

    // 2. Learner with sensible defaults (Appendix C.1).
    let learner = GradientBoostedTreesLearner::default_config("income");

    // 3. Train.
    let model = learner.train(&train).expect("training failed");

    // 4. Analyse: the `show_model` report (Appendix B.2).
    println!("{}", model.describe());

    // 5. Evaluate with confidence intervals (Appendix B.3). Evaluation
    // rides on the automatic engine selection (§3.7) — say which engine
    // won instead of picking one silently.
    match ydf::inference::auto_engine_name(model.as_ref()) {
        Some(name) => println!("inference engine (auto-selected): {name}"),
        None => println!("inference engine: none compatible, using the model's row loop"),
    }
    let evaluation = evaluate_model(model.as_ref(), &test, "income").expect("evaluation");
    println!("{}", evaluation.report());
}
