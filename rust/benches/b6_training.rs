//! b6: training benchmark — µs per training example for the learners the
//! training stack optimizes (PR 5: shared column index, per-thread
//! scratch, arena partitioning, feature-parallel split search), recorded
//! to `BENCH_training.json` so training performance is tracked across PRs
//! exactly like `BENCH_inference.json` / `BENCH_serving.json` track the
//! serving path.
//!
//! The grid (mixed numerical+categorical synthetic data, the Table 6
//! workload shape):
//!
//! * `rf_{exact,hist}_t{1,4}` — Random Forest, exact in-sort vs
//!   64-bin histogram numerical splitter, 1 vs 4 training threads
//!   (tree-level parallelism).
//! * `gbt_{exact,hist}_t{1,4}` — Gradient Boosted Trees, same splitter
//!   pair, 1 vs 4 training threads (per-node feature-parallel split
//!   search — boosting is sequential across trees).
//!
//! Threaded and single-threaded training are bit-identical (pinned by
//! `rust/tests/properties.rs::prop_threaded_training_bit_identical_to_sequential`),
//! so every `t4` row measures pure speedup; the JSON carries
//! `speedup_vs_t1` for the cross-PR record.
//!
//! Run: cargo bench --bench b6_training
//!      cargo bench --bench b6_training -- --rows=8000 --runs=5 --out=path.json

use ydf::dataset::synthetic;
use ydf::learner::gbt::GbtConfig;
use ydf::learner::random_forest::RandomForestConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};
use ydf::splitter::NumericalSplit;
use ydf::utils::json::Json;

struct ComboResult {
    key: String,
    learner: &'static str,
    splitter: &'static str,
    threads: usize,
    num_trees: usize,
    us_per_example: f64,
    train_s: f64,
}

fn time_train(learner: &dyn Learner, ds: &ydf::dataset::Dataset, runs: usize) -> f64 {
    // Best-of-runs: training is deterministic, so the minimum is the
    // least-noisy estimate of the true cost.
    let mut best = f64::INFINITY;
    for _ in 0..runs.max(1) {
        let t0 = std::time::Instant::now();
        let model = learner.train(ds).expect("bench training must succeed");
        std::hint::black_box(&model);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut rows = 4000usize;
    let mut rf_trees = 20usize;
    let mut gbt_trees = 30usize;
    let mut runs = 3usize;
    let mut threads = 4usize;
    let mut out_path = "BENCH_training.json".to_string();
    for a in &args {
        if let Some(v) = a.strip_prefix("--rows=") {
            rows = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--rf-trees=") {
            rf_trees = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--gbt-trees=") {
            gbt_trees = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--runs=") {
            runs = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--threads=") {
            threads = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--out=") {
            out_path = v.to_string();
        }
    }

    // Mixed numerical/categorical table — the adult-like workload the
    // inference benchmarks use, so the BENCH_* records stay comparable.
    let ds = synthetic::adult_like(rows, 20230806);
    eprintln!(
        "training benchmark: {rows} rows, RF {rf_trees} trees, GBT {gbt_trees} trees, \
         best of {runs} runs"
    );

    let splitters: [(&'static str, NumericalSplit); 2] = [
        ("exact", NumericalSplit::ExactInSort),
        ("hist", NumericalSplit::Histogram { bins: 64 }),
    ];

    // --threads=1 collapses the grid to the single-threaded rows instead
    // of timing (and overwriting) every t1 combo twice.
    let thread_grid: Vec<usize> =
        if threads > 1 { vec![1, threads] } else { vec![1] };
    let mut results: Vec<ComboResult> = Vec::new();
    for (split_name, numerical) in splitters {
        for &t in &thread_grid {
            let mut cfg = RandomForestConfig::new("income");
            cfg.num_trees = rf_trees;
            cfg.compute_oob = false;
            cfg.splitter.numerical = numerical;
            cfg.num_threads = t;
            let secs = time_train(&RandomForestLearner::new(cfg), &ds, runs);
            results.push(ComboResult {
                key: format!("rf_{split_name}_t{t}"),
                learner: "RANDOM_FOREST",
                splitter: split_name,
                threads: t,
                num_trees: rf_trees,
                us_per_example: secs / rows as f64 * 1e6,
                train_s: secs,
            });

            let mut cfg = GbtConfig::new("income");
            cfg.num_trees = gbt_trees;
            cfg.max_depth = 6;
            cfg.splitter.numerical = numerical;
            cfg.num_threads = t;
            let secs = time_train(&GradientBoostedTreesLearner::new(cfg), &ds, runs);
            results.push(ComboResult {
                key: format!("gbt_{split_name}_t{t}"),
                learner: "GRADIENT_BOOSTED_TREES",
                splitter: split_name,
                threads: t,
                num_trees: gbt_trees,
                us_per_example: secs / rows as f64 * 1e6,
                train_s: secs,
            });
        }
    }

    let t1_us = |key_t1: &str| -> Option<f64> {
        results.iter().find(|r| r.key == key_t1).map(|r| r.us_per_example)
    };
    println!("{:<16} {:>12} {:>10} {:>12}", "combo", "us/example", "train s", "speedup");
    let mut combos = Json::obj();
    for r in &results {
        let speedup = if r.threads > 1 {
            t1_us(&format!(
                "{}_{}_t1",
                if r.learner == "RANDOM_FOREST" { "rf" } else { "gbt" },
                r.splitter
            ))
            .map(|base| base / r.us_per_example)
        } else {
            None
        };
        println!(
            "{:<16} {:>12.3} {:>10.3} {:>12}",
            r.key,
            r.us_per_example,
            r.train_s,
            speedup.map(|s| format!("{s:.2}x")).unwrap_or_else(|| "-".to_string())
        );
        let mut cj = Json::obj();
        cj.set("learner", Json::Str(r.learner.to_string()))
            .set("splitter", Json::Str(r.splitter.to_string()))
            .set("threads", Json::Num(r.threads as f64))
            .set("num_trees", Json::Num(r.num_trees as f64))
            .set("us_per_example", Json::Num(r.us_per_example))
            .set("train_s", Json::Num(r.train_s));
        if let Some(s) = speedup {
            cj.set("speedup_vs_t1", Json::Num(s));
        }
        combos.set(&r.key, cj);
    }

    let mut j = Json::obj();
    j.set("rows", Json::Num(rows as f64))
        .set("rf_trees", Json::Num(rf_trees as f64))
        .set("gbt_trees", Json::Num(gbt_trees as f64))
        .set("runs", Json::Num(runs as f64))
        .set("threads", Json::Num(threads as f64))
        .set("combos", combos);
    match std::fs::write(&out_path, j.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("cannot write {out_path}: {e}"),
    }
}
