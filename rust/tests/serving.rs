//! Serving-runtime integration tests: the batcher's coalescing is
//! bit-identical to one `predict_batch` over the same rows, the bounded
//! queue rejects instead of blocking, and the TCP server answers the
//! wire protocol end to end on a loopback socket.

use std::sync::Arc;
use std::time::Duration;
use ydf::dataset::synthetic;
use ydf::inference::BLOCK_SIZE;
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};
use ydf::serving::{Batcher, BatcherConfig, RowBlock, Session, SubmitError};
use ydf::utils::json::Json;

/// A trained adult-like session plus JSON rows for `n` requests covering
/// NaN/missing features: every 7th row drops `age` (numerical missing)
/// and every 5th row carries an out-of-dictionary `workclass`.
fn session_and_rows(n: usize, seed: u64) -> (Arc<Session>, Vec<String>) {
    let ds = synthetic::adult_like(400, seed);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = 6;
    cfg.max_depth = 4;
    let session =
        Arc::new(Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()));
    let workclasses = ["Private", "Self-emp-inc", "Federal-gov", "Moon-base"];
    let educations = ["HS-grad", "Bachelors", "Masters", "Doctorate"];
    let rows: Vec<String> = (0..n)
        .map(|i| {
            let age = if i % 7 == 0 {
                "null".to_string() // missing numerical -> NaN
            } else {
                format!("{}", 18 + (i * 13) % 60)
            };
            format!(
                r#"{{"age": {age}, "hours_per_week": {}, "workclass": "{}",
                    "education": "{}", "capital_gain": {}}}"#,
                20 + (i * 7) % 50,
                workclasses[i % workclasses.len()], // i%4==3 -> OOD
                educations[(i / 3) % educations.len()],
                (i % 11) * 500,
            )
        })
        .collect();
    (session, rows)
}

fn decode_all(session: &Session, rows: &[String]) -> RowBlock {
    let mut block = session.new_block();
    for r in rows {
        session.decode_row(&mut block, &Json::parse(r).unwrap()).unwrap();
    }
    block
}

/// N concurrent requests (mixed sizes, unaligned tails, NaN/missing and
/// OOD features) coalesced through the batcher must be bit-identical to
/// one `predict_batch` call over the same rows.
#[test]
fn concurrent_coalesced_requests_match_single_predict_batch() {
    // 201 rows: not a BLOCK_SIZE multiple, so tail blocks are exercised
    // both in the single reference call and inside coalesced batches.
    let (session, rows) = session_and_rows(201, 31);
    let mut reference_block = decode_all(&session, &rows);
    let reference = session.predict_block(&mut reference_block);
    let dim = session.output_dim();

    // Uneven request sizes (1, 8, 64, 3, ...) covering every row once.
    let sizes = [1usize, 8, 64, 3, 17, 2, 64, 5, 1, 9, 27];
    let mut requests: Vec<(usize, Vec<String>)> = Vec::new(); // (first row, rows)
    let mut at = 0usize;
    let mut k = 0usize;
    while at < rows.len() {
        let take = sizes[k % sizes.len()].min(rows.len() - at);
        requests.push((at, rows[at..at + take].to_vec()));
        at += take;
        k += 1;
    }

    for trial in 0..3 {
        let batcher = Batcher::new(
            Arc::clone(&session),
            BatcherConfig {
                // Vary the flush policy across trials: deadline-driven,
                // adaptive (drain-when-free), and threshold-driven.
                max_delay: Duration::from_micros([500, 0, 2000][trial]),
                flush_rows: [BLOCK_SIZE, BLOCK_SIZE, 2 * BLOCK_SIZE][trial],
                ..Default::default()
            },
        );
        let results: Vec<(usize, usize, Vec<f64>)> = std::thread::scope(|s| {
            let handles: Vec<_> = requests
                .iter()
                .map(|(start, request_rows)| {
                    let session = &session;
                    let batcher = &batcher;
                    s.spawn(move || {
                        let block = decode_all(session, request_rows);
                        let out = batcher
                            .submit(&block)
                            .expect("queue sized for the test load")
                            .wait()
                            .expect("batcher scores every accepted request");
                        (*start, request_rows.len(), out)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (start, len, out) in results {
            assert_eq!(out.len(), len * dim);
            let expected = &reference[start * dim..(start + len) * dim];
            // Bit-identical, not approximately equal: coalescing must not
            // change a single bit of any prediction.
            assert_eq!(out.as_slice(), expected, "trial {trial}, rows {start}..{}", start + len);
        }
    }
}

/// A full bounded queue rejects new submissions immediately — it never
/// blocks the submitter — and the already-accepted requests still score.
#[test]
fn full_queue_rejects_instead_of_blocking() {
    let (session, rows) = session_and_rows(12, 47);
    let batcher = Batcher::new(
        Arc::clone(&session),
        BatcherConfig {
            // Flush can only happen via shutdown: threshold above capacity,
            // deadline far beyond the test's lifetime.
            flush_rows: BLOCK_SIZE,
            max_delay: Duration::from_secs(60),
            max_queue_rows: 10,
        },
    );
    assert_eq!(batcher.capacity_rows(), 10);

    // Fill the queue to exactly its capacity with 5 two-row requests.
    let mut accepted = Vec::new();
    for chunk in rows.chunks(2).take(5) {
        let block = decode_all(&session, chunk);
        accepted.push(batcher.submit(&block).expect("queue has room"));
    }

    // The queue is full: the next submission is rejected, and quickly —
    // rejection is a return value, not a blocked thread.
    let extra = decode_all(&session, &rows[10..11]);
    let t0 = std::time::Instant::now();
    let err = batcher.submit(&extra).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "rejection must be immediate, took {:?}",
        t0.elapsed()
    );
    assert_eq!(err, SubmitError::QueueFull { pending_rows: 10, capacity: 10 });
    assert_eq!(batcher.stats().snapshot().rejected, 1);

    // Shutdown drains the accepted requests; none is left hanging.
    drop(batcher);
    let dim = session.output_dim();
    for pending in accepted {
        assert_eq!(pending.wait().expect("drained on shutdown").len(), 2 * dim);
    }
}

/// End-to-end over loopback TCP: requests, commands, malformed input,
/// and shutdown through the real server loop.
#[test]
fn tcp_server_round_trip() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let ds = synthetic::adult_like(200, 53);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = 3;
    cfg.max_depth = 3;
    let session = Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap());

    // The stdout "listening on <addr>" contract is covered by the smoke
    // test; here we pre-bind to learn a free loopback port, release it,
    // and hand it to the server.
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap();
    drop(probe);

    let config = ydf::serving::ServerConfig {
        addr: addr.to_string(),
        workers: 2,
        batcher: BatcherConfig { max_delay: Duration::ZERO, ..Default::default() },
    };
    let server = std::thread::spawn(move || ydf::serving::serve(session, &config));

    // Wait for the listener to come up.
    let mut stream = None;
    for _ in 0..100 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server came up within 2s");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut rpc = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    };

    let health = rpc(r#"{"cmd": "health"}"#);
    assert_eq!(health.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(health.req_str("model_type").unwrap(), "GRADIENT_BOOSTED_TREES");

    let spec = rpc(r#"{"cmd": "spec"}"#);
    assert_eq!(spec.req_str("label").unwrap(), "income");
    assert_eq!(spec.req_arr("features").unwrap().len(), 8);

    let single = rpc(r#"{"age": 44, "education": "Masters"}"#);
    let preds = single.req_arr("predictions").unwrap();
    assert_eq!(preds.len(), 1);
    let p0 = preds[0].as_arr().unwrap();
    assert_eq!(p0.len(), 2);
    let total: f64 = p0.iter().map(|v| v.as_f64().unwrap()).sum();
    assert!((total - 1.0).abs() < 1e-9);

    let multi = rpc(r#"{"rows": [{"age": 23}, {"age": 67, "workclass": "Private"}, {}]}"#);
    assert_eq!(multi.req_arr("predictions").unwrap().len(), 3);

    let bad = rpc("this is not json");
    assert!(bad.req_str("error").unwrap().contains("invalid JSON"), "{bad}");
    let unknown = rpc(r#"{"rows": [{"flux_capacitance": 1.21}]}"#);
    assert!(unknown.req_str("error").unwrap().contains("flux_capacitance"), "{unknown}");

    let stats = rpc(r#"{"cmd": "stats"}"#);
    assert!(stats.req_f64("requests").unwrap() >= 2.0);
    assert!(stats.req_f64("errors").unwrap() >= 2.0);

    // An idle connection that never sends anything must not stall
    // shutdown: the server closes registered connections on exit.
    let idle = TcpStream::connect(addr).expect("idle connection accepted");

    let bye = rpc(r#"{"cmd": "shutdown"}"#);
    assert_eq!(bye.get("ok"), Some(&Json::Bool(true)));
    server.join().unwrap().expect("server exits cleanly");
    drop(idle);
}
