//! CSV reader/writer (§3.5 READERS/WRITERS).
//!
//! Handles RFC-4180 quoting, embedded separators/newlines and missing cells
//! (empty string or `?`, the UCI convention used by the Adult dataset of the
//! paper's usage example).

use super::dataspec::{infer_dataspec, InferenceOptions, RawColumn};
use super::{AttrValue, ColumnData, Dataset};
use std::io::Write;
use std::path::Path;

/// Parses CSV text into header + string cells.
pub fn parse_csv(text: &str) -> Result<(Vec<String>, Vec<Vec<Option<String>>>), String> {
    let mut rows: Vec<Vec<Option<String>>> = Vec::new();
    let mut record: Vec<Option<String>> = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    let mut field_was_quoted = false;
    let mut chars = text.chars().peekable();

    let push_field = |record: &mut Vec<Option<String>>, field: &mut String, quoted: bool| {
        let raw = std::mem::take(field);
        let trimmed = raw.trim();
        if !quoted && (trimmed.is_empty() || trimmed == "?") {
            record.push(None);
        } else if quoted {
            record.push(Some(raw));
        } else {
            record.push(Some(trimmed.to_string()));
        }
    };

    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    in_quotes = true;
                    field_was_quoted = true;
                }
                ',' => {
                    push_field(&mut record, &mut field, field_was_quoted);
                    field_was_quoted = false;
                }
                '\r' => {} // tolerate CRLF
                '\n' => {
                    push_field(&mut record, &mut field, field_was_quoted);
                    field_was_quoted = false;
                    rows.push(std::mem::take(&mut record));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err("CSV parse error: unterminated quoted field at end of input".to_string());
    }
    if !field.is_empty() || !record.is_empty() {
        push_field(&mut record, &mut field, field_was_quoted);
        rows.push(record);
    }
    if rows.is_empty() {
        return Err("CSV parse error: the file is empty (no header row found)".to_string());
    }
    let header: Vec<String> = rows
        .remove(0)
        .into_iter()
        .map(|c| c.unwrap_or_default())
        .collect();
    for (i, r) in rows.iter().enumerate() {
        if r.len() != header.len() {
            return Err(format!(
                "CSV parse error: row {} has {} fields but the header declares {}. Check for \
                 unquoted separators.",
                i + 2,
                r.len(),
                header.len()
            ));
        }
    }
    Ok((header, rows))
}

/// Reads a CSV string into a `Dataset`, inferring the dataspec.
pub fn read_csv_str(text: &str, options: &InferenceOptions) -> Result<Dataset, String> {
    let (header, rows) = parse_csv(text)?;
    let mut raw_cols: Vec<RawColumn> = header
        .iter()
        .map(|name| RawColumn { name: name.clone(), values: Vec::with_capacity(rows.len()) })
        .collect();
    for row in rows {
        for (c, cell) in row.into_iter().enumerate() {
            raw_cols[c].values.push(cell);
        }
    }
    let inferred = infer_dataspec(&raw_cols, options)?;
    Dataset::new(inferred.spec, inferred.columns)
}

/// Reads a CSV file into a `Dataset`.
pub fn read_csv_file(path: &Path, options: &InferenceOptions) -> Result<Dataset, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read CSV file {}: {e}", path.display()))?;
    read_csv_str(&text, options)
}

fn escape_cell(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Writes a dataset back to CSV text (WRITERS module).
pub fn write_csv_string(ds: &Dataset) -> String {
    let mut out = String::new();
    let names: Vec<String> =
        ds.spec.columns.iter().map(|c| escape_cell(&c.name)).collect();
    out.push_str(&names.join(","));
    out.push('\n');
    for r in 0..ds.num_rows() {
        let mut cells = Vec::with_capacity(ds.num_columns());
        for (ci, col) in ds.columns.iter().enumerate() {
            let spec = &ds.spec.columns[ci];
            let cell = if col.is_missing(r) {
                String::new()
            } else {
                match col {
                    ColumnData::Numerical(v) => format!("{}", v[r]),
                    ColumnData::Categorical(v) => {
                        escape_cell(&spec.dictionary[v[r] as usize])
                    }
                    ColumnData::Boolean(v) => {
                        if v[r] == 1 { "true".into() } else { "false".into() }
                    }
                    ColumnData::CategoricalSet { .. } => {
                        let toks: Vec<&str> = col
                            .set_values(r)
                            .unwrap()
                            .iter()
                            .map(|&t| spec.dictionary[t as usize].as_str())
                            .collect();
                        escape_cell(&toks.join(" "))
                    }
                }
            };
            cells.push(cell);
        }
        out.push_str(&cells.join(","));
        out.push('\n');
    }
    out
}

/// Writes predictions to CSV (`predict --output=csv:...` in the CLI flow).
pub fn write_predictions_csv<W: Write>(
    w: &mut W,
    class_names: &[String],
    probabilities: &[Vec<f64>],
) -> std::io::Result<()> {
    writeln!(w, "{}", class_names.join(","))?;
    for p in probabilities {
        let cells: Vec<String> = p.iter().map(|x| format!("{x}")).collect();
        writeln!(w, "{}", cells.join(","))?;
    }
    Ok(())
}

/// Converts one CSV-style string row into an observation for the given
/// dataset spec (single-example serving path).
pub fn observation_from_strs(
    ds_spec: &super::DataSpec,
    cells: &[Option<&str>],
) -> Result<super::Observation, String> {
    if cells.len() != ds_spec.columns.len() {
        return Err(format!(
            "expected {} cells, got {}",
            ds_spec.columns.len(),
            cells.len()
        ));
    }
    let mut obs = Vec::with_capacity(cells.len());
    for (spec, cell) in ds_spec.columns.iter().zip(cells) {
        let v = match cell {
            None => AttrValue::Missing,
            Some(s) => match spec.semantic {
                super::FeatureSemantic::Numerical => AttrValue::Num(
                    s.trim()
                        .parse::<f32>()
                        .map_err(|_| format!("bad numerical value '{s}' for '{}'", spec.name))?,
                ),
                super::FeatureSemantic::Categorical => spec
                    .category_index(s)
                    .map(AttrValue::Cat)
                    .unwrap_or(AttrValue::Missing),
                super::FeatureSemantic::Boolean => {
                    AttrValue::Bool(matches!(s.trim(), "true" | "1"))
                }
                super::FeatureSemantic::CategoricalSet => AttrValue::CatSet(
                    s.split_whitespace()
                        .filter_map(|t| spec.category_index(t))
                        .collect(),
                ),
            },
        };
        obs.push(v);
    }
    Ok(obs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::FeatureSemantic;

    const SAMPLE: &str = "age,workclass,income\n44,Private,<=50K\n20,Private,<=50K\n67,\"Self-emp, inc\",>50K\n51,?,<=50K\n33,Private,>50K\n18,Private,<=50K\n29,Private,<=50K\n";

    #[test]
    fn parses_quotes_and_missing() {
        let (header, rows) = parse_csv(SAMPLE).unwrap();
        assert_eq!(header, vec!["age", "workclass", "income"]);
        assert_eq!(rows.len(), 7);
        assert_eq!(rows[2][1].as_deref(), Some("Self-emp, inc"));
        assert_eq!(rows[3][1], None); // "?" is missing
    }

    #[test]
    fn reads_dataset_with_inference() {
        let ds = read_csv_str(SAMPLE, &InferenceOptions::default()).unwrap();
        assert_eq!(ds.num_rows(), 7);
        assert_eq!(ds.spec.columns[0].semantic, FeatureSemantic::Numerical);
        assert_eq!(ds.spec.columns[1].semantic, FeatureSemantic::Categorical);
        assert_eq!(ds.spec.columns[2].semantic, FeatureSemantic::Categorical);
        assert!(ds.column(1).is_missing(3));
    }

    #[test]
    fn roundtrip_write_read() {
        let ds = read_csv_str(SAMPLE, &InferenceOptions::default()).unwrap();
        let text = write_csv_string(&ds);
        let ds2 = read_csv_str(&text, &InferenceOptions::default()).unwrap();
        assert_eq!(ds2.num_rows(), ds.num_rows());
        assert_eq!(
            ds2.column(0).as_numerical().unwrap(),
            ds.column(0).as_numerical().unwrap()
        );
    }

    #[test]
    fn row_count_mismatch_is_descriptive() {
        let err = parse_csv("a,b\n1\n").unwrap_err();
        assert!(err.contains("row 2 has 1 fields"), "{err}");
    }

    #[test]
    fn quoted_newline() {
        let (_, rows) = parse_csv("a,b\n\"x\ny\",2\n").unwrap();
        assert_eq!(rows[0][0].as_deref(), Some("x\ny"));
    }

    #[test]
    fn crlf_tolerated() {
        let (h, rows) = parse_csv("a,b\r\n1,2\r\n").unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(rows.len(), 1);
    }

    #[test]
    fn observation_parsing() {
        let ds = read_csv_str(SAMPLE, &InferenceOptions::default()).unwrap();
        let obs =
            observation_from_strs(&ds.spec, &[Some("40"), Some("Private"), Some("<=50K")])
                .unwrap();
        assert_eq!(obs[0], AttrValue::Num(40.0));
        assert!(matches!(obs[1], AttrValue::Cat(_)));
        // Unknown category degrades to Missing, not an error.
        let obs2 =
            observation_from_strs(&ds.spec, &[Some("40"), Some("Unseen"), None]).unwrap();
        assert_eq!(obs2[1], AttrValue::Missing);
        assert_eq!(obs2[2], AttrValue::Missing);
    }

    #[test]
    fn empty_file_error() {
        assert!(parse_csv("").is_err());
    }
}
