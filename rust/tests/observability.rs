//! Observability integration: the `ydf` binary's training telemetry.
//!
//! Two acceptance criteria from the observability PR are pinned here
//! against the real binary (not the library): `YDF_LOG=info` prints
//! per-iteration loss lines to stderr and `YDF_LOG=off` prints nothing,
//! and `--trace=FILE` writes Chrome trace-event JSON that round-trips
//! through `utils/json.rs`.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};
use ydf::dataset::csv::write_csv_string;
use ydf::dataset::synthetic;
use ydf::utils::json::Json;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_ydf")
}

/// Per-process temp path so parallel test binaries never collide.
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ydf_obs_{}_{name}", std::process::id()))
}

fn write_dataset(name: &str) -> PathBuf {
    let ds = synthetic::adult_like(300, 42);
    let path = tmp(name);
    std::fs::write(&path, write_csv_string(&ds)).unwrap();
    path
}

fn train(csv: &Path, model_out: &Path, extra: &[String], log_level: &str) -> Output {
    Command::new(bin())
        .arg("train")
        .arg(format!("--dataset={}", csv.display()))
        .arg("--label=income")
        .arg("--learner=GRADIENT_BOOSTED_TREES")
        .arg("--param:num_trees=5")
        .arg(format!("--output={}", model_out.display()))
        .args(extra)
        .env("YDF_LOG", log_level)
        .output()
        .expect("spawn ydf binary")
}

#[test]
fn train_log_levels_gate_stderr() {
    let csv = write_dataset("levels.csv");
    let model = tmp("levels_model.json");

    let info = train(&csv, &model, &[], "info");
    assert!(info.status.success(), "train failed: {}", String::from_utf8_lossy(&info.stderr));
    let stderr = String::from_utf8_lossy(&info.stderr);
    assert!(
        stderr.contains("[ydf info]") && stderr.contains("train loss"),
        "YDF_LOG=info must print per-iteration loss lines, got: {stderr:?}"
    );
    // One line per boosting iteration (5 trees → 5 `gbt iter` lines).
    assert_eq!(
        stderr.lines().filter(|l| l.contains("gbt iter")).count(),
        5,
        "expected one telemetry line per iteration: {stderr:?}"
    );

    let off = train(&csv, &model, &[], "off");
    assert!(off.status.success(), "train failed: {}", String::from_utf8_lossy(&off.stderr));
    assert!(
        off.stderr.is_empty(),
        "YDF_LOG=off must silence all telemetry, got: {:?}",
        String::from_utf8_lossy(&off.stderr)
    );

    let _ = std::fs::remove_file(&csv);
    let _ = std::fs::remove_file(&model);
}

#[test]
fn train_trace_round_trips_through_json() {
    let csv = write_dataset("trace.csv");
    let model = tmp("trace_model.json");
    let trace = tmp("train_trace.json");

    let out = train(&csv, &model, &[format!("--trace={}", trace.display())], "off");
    assert!(out.status.success(), "train failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("trace event(s)"), "expected trace confirmation: {stdout:?}");

    let text = std::fs::read_to_string(&trace).expect("trace file written");
    let parsed = Json::parse(&text).expect("trace file is valid JSON");
    // Lossless round trip through our own JSON layer.
    assert_eq!(Json::parse(&parsed.to_string()).unwrap(), parsed);

    let events = parsed.req_arr("traceEvents").expect("traceEvents array");
    assert!(!events.is_empty(), "a traced training run must record events");
    let mut saw_train_tree = false;
    let mut saw_iteration = false;
    for e in events {
        let ph = e.req_str("ph").unwrap();
        assert!(ph == "X" || ph == "i", "unexpected phase {ph:?}");
        assert!(e.req_f64("ts").unwrap() >= 0.0);
        assert!(e.req_f64("tid").unwrap() >= 1.0);
        if ph == "X" {
            assert!(e.req_f64("dur").unwrap() >= 0.0);
        }
        match e.req_str("name").unwrap() {
            "train_tree" => {
                saw_train_tree = true;
                let args = e.req("args").unwrap();
                assert_eq!(args.req_str("learner").unwrap(), "gbt");
                assert!(args.req_f64("nodes").unwrap() >= 1.0);
            }
            "train_iteration" => saw_iteration = true,
            _ => {}
        }
    }
    assert!(saw_train_tree, "per-tree spans missing from trace");
    assert!(saw_iteration, "per-iteration instants missing from trace");

    let _ = std::fs::remove_file(&csv);
    let _ = std::fs::remove_file(&model);
    let _ = std::fs::remove_file(&trace);
}
