//! Environment-variable parsing with warn-once diagnostics.
//!
//! `YDF_INFER_THREADS`, `YDF_TRAIN_THREADS` and `YDF_LOG` each used to
//! carry (or would have duplicated) their own `static Once` +
//! `eprintln!` for the "set but malformed" case. This module centralizes
//! the pattern: parse helpers return `None` when the variable is unset
//! or invalid — the caller applies its default — and an invalid value
//! warns exactly once per variable through the leveled log facade.

use std::collections::HashSet;
use std::sync::{Mutex, OnceLock};

fn warned() -> &'static Mutex<HashSet<String>> {
    static WARNED: OnceLock<Mutex<HashSet<String>>> = OnceLock::new();
    WARNED.get_or_init(|| Mutex::new(HashSet::new()))
}

/// Emits `message` at warn level, at most once per `key` for the process
/// lifetime. Keyed per variable so one misconfigured knob cannot
/// suppress diagnostics for another.
pub fn warn_once(key: &str, message: &str) {
    let mut set = match warned().lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    if !set.insert(key.to_string()) {
        return;
    }
    drop(set);
    crate::ydf_warn!("{message}");
}

/// The variable's value, trimmed. `None` when unset or blank.
pub fn string(name: &str) -> Option<String> {
    let raw = std::env::var(name).ok()?;
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        None
    } else {
        Some(trimmed.to_string())
    }
}

/// Parses `name` as a positive integer (≥ 1). `None` when unset; a set
/// but malformed value warns once and also returns `None`, so the
/// caller's default applies either way.
pub fn positive_usize(name: &str) -> Option<usize> {
    let raw = string(name)?;
    match raw.parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => {
            warn_once(
                name,
                &format!("ignoring {name}='{raw}': expected a positive integer"),
            );
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn positive_usize_parses_and_rejects() {
        // Distinct variable names per case: the process environment and
        // the warn-once set are both global.
        std::env::set_var("YDF_TEST_ENV_OK", "4");
        assert_eq!(positive_usize("YDF_TEST_ENV_OK"), Some(4));
        std::env::set_var("YDF_TEST_ENV_PADDED", "  8  ");
        assert_eq!(positive_usize("YDF_TEST_ENV_PADDED"), Some(8));
        std::env::set_var("YDF_TEST_ENV_ZERO", "0");
        assert_eq!(positive_usize("YDF_TEST_ENV_ZERO"), None);
        std::env::set_var("YDF_TEST_ENV_JUNK", "many");
        assert_eq!(positive_usize("YDF_TEST_ENV_JUNK"), None);
        assert_eq!(positive_usize("YDF_TEST_ENV_UNSET_NEVER_SET"), None);
    }

    #[test]
    fn string_trims_and_drops_blank() {
        std::env::set_var("YDF_TEST_ENV_STR", "  debug ");
        assert_eq!(string("YDF_TEST_ENV_STR").as_deref(), Some("debug"));
        std::env::set_var("YDF_TEST_ENV_BLANK", "   ");
        assert_eq!(string("YDF_TEST_ENV_BLANK"), None);
    }

    #[test]
    fn warn_once_is_per_key() {
        // No panic on repeats; keyed entries are independent.
        warn_once("YDF_TEST_WARN_A", "warn A");
        warn_once("YDF_TEST_WARN_A", "warn A again (suppressed)");
        warn_once("YDF_TEST_WARN_B", "warn B");
        let set = warned().lock().unwrap();
        assert!(set.contains("YDF_TEST_WARN_A"));
        assert!(set.contains("YDF_TEST_WARN_B"));
    }
}
