//! Classification metrics: accuracy, log-loss, ROC AUC (with Hanley–McNeil
//! and bootstrap CIs), PR-AUC and average precision (§2.2: easily
//! accessible *correct* methods, with documented confidence bounds).

use crate::utils::rng::Rng;
use crate::utils::stats;

/// Area under the ROC curve via the rank statistic (Mann–Whitney U), with
/// tie handling.
pub fn roc_auc(scores: &[f64], positives: &[bool]) -> f64 {
    assert_eq!(scores.len(), positives.len());
    let n_pos = positives.iter().filter(|&&p| p).count();
    let n_neg = positives.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let ranks = stats::fractional_ranks(scores);
    let rank_sum: f64 = ranks
        .iter()
        .zip(positives)
        .filter(|(_, &p)| p)
        .map(|(&r, _)| r)
        .sum();
    let u = rank_sum - (n_pos as f64) * (n_pos as f64 + 1.0) / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Hanley–McNeil (1982) closed-form standard error of the AUC; the `[H]`
/// interval of the evaluation report.
pub fn auc_hanley_ci(auc: f64, n_pos: usize, n_neg: usize, z: f64) -> (f64, f64) {
    if n_pos == 0 || n_neg == 0 {
        return (0.0, 1.0);
    }
    let q1 = auc / (2.0 - auc);
    let q2 = 2.0 * auc * auc / (1.0 + auc);
    let var = (auc * (1.0 - auc)
        + (n_pos as f64 - 1.0) * (q1 - auc * auc)
        + (n_neg as f64 - 1.0) * (q2 - auc * auc))
        / (n_pos as f64 * n_neg as f64);
    let se = var.max(0.0).sqrt();
    ((auc - z * se).max(0.0), (auc + z * se).min(1.0))
}

/// Bootstrap CI of the AUC; the `[B]` interval.
pub fn auc_bootstrap_ci(
    scores: &[f64],
    positives: &[bool],
    rounds: usize,
    alpha: f64,
    rng: &mut Rng,
) -> (f64, f64) {
    let n = scores.len();
    let mut vals = Vec::with_capacity(rounds);
    let mut s = vec![0.0; n];
    let mut p = vec![false; n];
    for _ in 0..rounds {
        for i in 0..n {
            let j = rng.uniform_usize(n);
            s[i] = scores[j];
            p[i] = positives[j];
        }
        vals.push(roc_auc(&s, &p));
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        stats::quantile_sorted(&vals, alpha / 2.0),
        stats::quantile_sorted(&vals, 1.0 - alpha / 2.0),
    )
}

/// Average precision (area under the precision-recall curve, step-wise).
pub fn average_precision(scores: &[f64], positives: &[bool]) -> f64 {
    let n_pos = positives.iter().filter(|&&p| p).count();
    if n_pos == 0 {
        return 0.0;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut tp = 0usize;
    let mut ap = 0.0;
    for (k, &i) in order.iter().enumerate() {
        if positives[i] {
            tp += 1;
            ap += tp as f64 / (k + 1) as f64;
        }
    }
    ap / n_pos as f64
}

/// Multiclass log-loss.
pub fn log_loss(probabilities: &[Vec<f64>], labels: &[u32]) -> f64 {
    let mut sum = 0.0;
    for (p, &y) in probabilities.iter().zip(labels) {
        sum -= p[y as usize].max(1e-12).ln();
    }
    sum / probabilities.len().max(1) as f64
}

/// Accuracy of argmax predictions.
pub fn accuracy(probabilities: &[Vec<f64>], labels: &[u32]) -> f64 {
    let correct = probabilities
        .iter()
        .zip(labels)
        .filter(|(p, &y)| crate::model::argmax(p) as u32 == y)
        .count();
    correct as f64 / probabilities.len().max(1) as f64
}

/// Multiclass log-loss over a flat row-major probability buffer
/// (`probabilities.len() == labels.len() * dim`) — the layout produced by
/// the batch inference path (`inference::predict_flat`), avoiding the
/// Vec-per-row intermediate.
pub fn log_loss_flat(probabilities: &[f64], dim: usize, labels: &[u32]) -> f64 {
    let mut sum = 0.0;
    for (r, &y) in labels.iter().enumerate() {
        sum -= probabilities[r * dim + y as usize].max(1e-12).ln();
    }
    sum / labels.len().max(1) as f64
}

/// Accuracy of argmax predictions over a flat row-major probability buffer.
pub fn accuracy_flat(probabilities: &[f64], dim: usize, labels: &[u32]) -> f64 {
    let correct = labels
        .iter()
        .enumerate()
        .filter(|&(r, &y)| {
            crate::model::argmax(&probabilities[r * dim..(r + 1) * dim]) as u32 == y
        })
        .count();
    correct as f64 / labels.len().max(1) as f64
}

/// Root-mean-square error (regression).
pub fn rmse(predictions: &[f64], targets: &[f32]) -> f64 {
    let sse: f64 = predictions
        .iter()
        .zip(targets)
        .map(|(&p, &t)| (p - t as f64) * (p - t as f64))
        .sum();
    (sse / predictions.len().max(1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_perfect_and_random() {
        let scores = vec![0.9, 0.8, 0.2, 0.1];
        let pos = vec![true, true, false, false];
        assert!((roc_auc(&scores, &pos) - 1.0).abs() < 1e-12);
        let anti = vec![false, false, true, true];
        assert!((roc_auc(&scores, &anti) - 0.0).abs() < 1e-12);
        // Ties everywhere -> 0.5.
        let flat = vec![0.5; 4];
        assert!((roc_auc(&flat, &pos) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_known_value() {
        // scores: pos {0.8, 0.4}, neg {0.6, 0.2}: pairs (0.8>0.6),(0.8>0.2),
        // (0.4<0.6),(0.4>0.2) => 3/4.
        let scores = vec![0.8, 0.4, 0.6, 0.2];
        let pos = vec![true, true, false, false];
        assert!((roc_auc(&scores, &pos) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn hanley_ci_brackets_auc() {
        let (lo, hi) = auc_hanley_ci(0.9, 100, 200, 1.96);
        assert!(lo < 0.9 && 0.9 < hi);
        assert!(hi - lo < 0.15);
    }

    #[test]
    fn bootstrap_ci_reasonable() {
        let mut rng = Rng::seed_from_u64(4);
        let n = 300;
        let scores: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let pos: Vec<bool> = scores.iter().map(|&s| rng.uniform() < s).collect();
        let auc = roc_auc(&scores, &pos);
        let (lo, hi) = auc_bootstrap_ci(&scores, &pos, 200, 0.05, &mut rng);
        assert!(lo <= auc && auc <= hi, "{lo} {auc} {hi}");
    }

    #[test]
    fn average_precision_perfect() {
        let scores = vec![0.9, 0.8, 0.2, 0.1];
        let pos = vec![true, true, false, false];
        assert!((average_precision(&scores, &pos) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn log_loss_and_accuracy() {
        let probs = vec![vec![0.9, 0.1], vec![0.2, 0.8], vec![0.6, 0.4]];
        let labels = vec![0u32, 1, 1];
        assert!((accuracy(&probs, &labels) - 2.0 / 3.0).abs() < 1e-12);
        let ll = log_loss(&probs, &labels);
        let expected = -(0.9f64.ln() + 0.8f64.ln() + 0.4f64.ln()) / 3.0;
        assert!((ll - expected).abs() < 1e-12);
    }

    #[test]
    fn flat_metrics_match_nested() {
        let probs = vec![vec![0.9, 0.1], vec![0.2, 0.8], vec![0.6, 0.4]];
        let flat: Vec<f64> = probs.iter().flatten().copied().collect();
        let labels = vec![0u32, 1, 1];
        assert!((accuracy(&probs, &labels) - accuracy_flat(&flat, 2, &labels)).abs() < 1e-12);
        assert!((log_loss(&probs, &labels) - log_loss_flat(&flat, 2, &labels)).abs() < 1e-12);
    }

    #[test]
    fn rmse_basic() {
        assert!((rmse(&[1.0, 2.0], &[0.0, 4.0]) - (2.5f64).sqrt()).abs() < 1e-12);
    }
}
