//! # Yggdrasil Decision Forests (reproduction)
//!
//! A library for the training, serving and interpretation of decision
//! forest models, reproducing *Yggdrasil Decision Forests: A Fast and
//! Extensible Decision Forests Library* (KDD 2023) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate is organized around the paper's LEARNER–MODEL abstraction
//! (§3.1): a [`model::Model`] is a function from observation to prediction;
//! a [`learner::Learner`] is a function from dataset to model. Everything
//! else — splitters, inference engines, the micro-batching serving
//! runtime, meta-learners, self-evaluation, distributed training — is an
//! interchangeable module (§3.5).
//!
//! ## Quickstart
//!
//! ```no_run
//! use ydf::dataset::synthetic;
//! use ydf::learner::{Learner, gbt::GradientBoostedTreesLearner};
//!
//! let data = synthetic::adult_like(1000, 42);
//! let learner = GradientBoostedTreesLearner::default_config("income");
//! let model = learner.train(&data).unwrap();
//! let eval = ydf::evaluation::evaluate_model(model.as_ref(), &data, "income").unwrap();
//! println!("{}", eval.report());
//! ```

pub mod benchmark;
pub mod dataset;
pub mod distributed;
pub mod evaluation;
pub mod inference;
pub mod learner;
pub mod metalearner;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod serving;
pub mod splitter;
pub mod utils;

/// Plain accuracy of a classification model against a dataset's label
/// column (convenience used widely in tests; the full evaluation lives in
/// [`evaluation`]).
pub fn evaluation_free_accuracy(model: &dyn model::Model, ds: &dataset::Dataset) -> f64 {
    let label_col = model.label_col();
    let labels = ds.columns[label_col].as_categorical().expect("categorical label");
    // Batch path: fastest compatible engine, flat output buffer.
    let (probs, dim) = inference::predict_flat(model, ds);
    let mut correct = 0usize;
    for (r, &y) in labels.iter().enumerate() {
        if model::argmax(&probs[r * dim..(r + 1) * dim]) as u32 == y {
            correct += 1;
        }
    }
    correct as f64 / ds.num_rows().max(1) as f64
}
