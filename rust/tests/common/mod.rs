//! Shared integration-test fixtures: deterministic small RF/GBT model
//! builders and synthetic mixed-semantic datasets, used by `serving.rs`,
//! `properties.rs` and `end_to_end.rs` (each test binary compiles its own
//! copy via `mod common;`). Everything here is seed-deterministic — the
//! same arguments always produce the same model, so tests pinning
//! bit-identity can rebuild references freely.
#![allow(dead_code)]

use std::sync::Arc;
use ydf::dataset::dataspec::{ColumnSpec, DataSpec};
use ydf::dataset::{synthetic, ColumnData, Dataset, MISSING_BOOL, MISSING_CAT};
use ydf::learner::gbt::GbtConfig;
use ydf::learner::random_forest::RandomForestConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};
use ydf::model::Model;
use ydf::serving::{RowBlock, Session};
use ydf::utils::json::Json;
use ydf::utils::rng::Rng;

/// Deterministic small GBT classifier trained on the adult-like synthetic
/// table (label `income`, mixed numerical/categorical features).
pub fn adult_gbt(rows: usize, seed: u64, trees: usize, depth: usize) -> Box<dyn Model> {
    let ds = synthetic::adult_like(rows, seed);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = trees;
    cfg.max_depth = depth;
    GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()
}

/// Deterministic small Random Forest classifier on the same table.
pub fn adult_rf(rows: usize, seed: u64, trees: usize) -> Box<dyn Model> {
    let ds = synthetic::adult_like(rows, seed);
    let mut cfg = RandomForestConfig::new("income");
    cfg.num_trees = trees;
    cfg.compute_oob = false;
    RandomForestLearner::new(cfg).train(&ds).unwrap()
}

/// Serving session over [`adult_gbt`], shareable across threads.
pub fn adult_session(rows: usize, seed: u64, trees: usize, depth: usize) -> Arc<Session> {
    Arc::new(adult_session_owned(rows, seed, trees, depth))
}

/// As [`adult_session`], but by value (what `Registry::register` takes).
pub fn adult_session_owned(rows: usize, seed: u64, trees: usize, depth: usize) -> Session {
    Session::new(adult_gbt(rows, seed, trees, depth))
}

/// JSON request rows for an adult-like session covering the decode edge
/// cases: every 7th row drops `age` (numerical missing → NaN) and every
/// 4th carries an out-of-dictionary `workclass` (→ missing category).
pub fn adult_json_rows(n: usize) -> Vec<String> {
    let workclasses = ["Private", "Self-emp-inc", "Federal-gov", "Moon-base"];
    let educations = ["HS-grad", "Bachelors", "Masters", "Doctorate"];
    (0..n)
        .map(|i| {
            let age = if i % 7 == 0 {
                "null".to_string() // missing numerical -> NaN
            } else {
                format!("{}", 18 + (i * 13) % 60)
            };
            format!(
                r#"{{"age": {age}, "hours_per_week": {}, "workclass": "{}",
                    "education": "{}", "capital_gain": {}}}"#,
                20 + (i * 7) % 50,
                workclasses[i % workclasses.len()], // i%4==3 -> OOD
                educations[(i / 3) % educations.len()],
                (i % 11) * 500,
            )
        })
        .collect()
}

/// Decodes every JSON row into one fresh block of `session`.
pub fn decode_all(session: &Session, rows: &[String]) -> RowBlock {
    let mut block = session.new_block();
    for r in rows {
        session.decode_row(&mut block, &Json::parse(r).unwrap()).unwrap();
    }
    block
}

/// Builds a mixed-semantic dataset (numerical + categorical + boolean +
/// categorical-set, all with missing values) and a label column:
/// categorical with `classes` classes when `classes >= 2`, numerical
/// (regression) when `classes == 0`. Column order: `x0`, `x1`, `cat`,
/// `flag`, [`tokens`,] `label`.
pub fn mixed_ds(n: usize, classes: usize, rng: &mut Rng) -> Dataset {
    mixed_ds_opt(n, classes, true, rng)
}

/// `mixed_ds` with the categorical-set column optional: without it, the
/// trained trees stay inside QuickScorer's condition envelope while the
/// numerical/categorical/boolean columns still carry missing values.
pub fn mixed_ds_opt(n: usize, classes: usize, with_catset: bool, rng: &mut Rng) -> Dataset {
    let mut x0 = Vec::with_capacity(n);
    let mut x1 = Vec::with_capacity(n);
    let mut cat = Vec::with_capacity(n);
    let mut boo = Vec::with_capacity(n);
    let mut cs_offsets = vec![0u32];
    let mut cs_values: Vec<u32> = Vec::new();
    let mut label_cat = Vec::with_capacity(n);
    let mut label_num = Vec::with_capacity(n);
    for i in 0..n {
        let a = rng.uniform_range(-2.0, 2.0);
        let b = rng.uniform_range(-2.0, 2.0);
        let c = rng.uniform_usize(4);
        let bo = rng.bernoulli(0.5);
        x0.push(if rng.bernoulli(0.06) { f32::NAN } else { a as f32 });
        x1.push(if rng.bernoulli(0.06) { f32::NAN } else { b as f32 });
        cat.push(if rng.bernoulli(0.06) { MISSING_CAT } else { c as u32 });
        boo.push(if rng.bernoulli(0.06) { MISSING_BOOL } else { bo as u8 });
        let mut has_token0 = false;
        if with_catset {
            if rng.bernoulli(0.06) {
                cs_values.push(MISSING_CAT); // sentinel: missing set
            } else {
                for _ in 0..rng.uniform_usize(3) {
                    let tok = rng.uniform_usize(5) as u32;
                    has_token0 |= tok == 0;
                    cs_values.push(tok);
                }
            }
            cs_offsets.push(cs_values.len() as u32);
        }
        let z = a + 0.5 * b
            + if bo { 0.8 } else { -0.4 }
            + c as f64 * 0.3
            + if has_token0 { 1.2 } else { 0.0 }
            + rng.normal_ms(0.0, 0.3);
        if classes >= 2 {
            let mut y = if z > 0.8 {
                2
            } else if z > -0.2 {
                1
            } else {
                0
            };
            y = y.min(classes as u32 - 1);
            // Guarantee every class appears.
            if i < classes {
                y = i as u32;
            }
            label_cat.push(y);
        } else {
            label_num.push(z as f32);
        }
    }
    let mut columns = vec![
        ColumnSpec::numerical("x0"),
        ColumnSpec::numerical("x1"),
        ColumnSpec::categorical("cat", (0..4).map(|i| format!("c{i}")).collect()),
        ColumnSpec::boolean("flag"),
    ];
    let mut data = vec![
        ColumnData::Numerical(x0),
        ColumnData::Numerical(x1),
        ColumnData::Categorical(cat),
        ColumnData::Boolean(boo),
    ];
    if with_catset {
        columns.push(ColumnSpec::catset("tokens", (0..5).map(|i| format!("t{i}")).collect()));
        data.push(ColumnData::CategoricalSet { offsets: cs_offsets, values: cs_values });
    }
    if classes >= 2 {
        columns.push(ColumnSpec::categorical(
            "label",
            (0..classes).map(|i| format!("y{i}")).collect(),
        ));
        data.push(ColumnData::Categorical(label_cat));
    } else {
        columns.push(ColumnSpec::numerical("label"));
        data.push(ColumnData::Numerical(label_num));
    }
    Dataset::new(DataSpec { columns }, data).unwrap()
}

/// A deterministic small GBT classifier over [`mixed_ds`] (all four
/// feature semantics, missing values everywhere) plus the dataset it was
/// trained on.
pub fn mixed_gbt(n: usize, classes: usize, seed: u64) -> (Box<dyn Model>, Dataset) {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = mixed_ds(n, classes, &mut rng);
    let mut cfg = GbtConfig::new("label");
    cfg.num_trees = 4;
    cfg.max_depth = 4;
    (GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap(), ds)
}
