//! Appendix B.4: the model inference benchmark — every compatible engine
//! timed over the dataset on both the batch path (columnar, block-wise)
//! and the seed-style per-row path, µs/example (the report the CLI's
//! `benchmark_inference` prints). The scalar block kernels of the flat
//! and QuickScorer engines are timed alongside the default SIMD lane
//! kernels (`[scalar]`-tagged rows), so the scalar-vs-SIMD gap is part of
//! the record. Includes the PJRT/XLA engine when the artifact is
//! available, and writes a machine-readable `BENCH_inference.json` so
//! subsequent PRs can track the perf trajectory.
//!
//! Run: cargo bench --bench b4_engines
//!      cargo bench --bench b4_engines -- --rows=20000 --trees=100 --out=path.json

use ydf::dataset::synthetic;
use ydf::inference::{benchmark_inference, InferenceEngine};
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut rows = 10_000usize;
    let mut trees = 50usize;
    let mut runs = 5usize;
    let mut out_path = "BENCH_inference.json".to_string();
    for a in &args {
        if let Some(v) = a.strip_prefix("--rows=") {
            rows = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--trees=") {
            trees = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--runs=") {
            runs = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--out=") {
            out_path = v.to_string();
        }
    }

    // Adult-like mixed numerical/categorical dataset — the workload of the
    // acceptance gate (>=10k rows, GBT >=50 trees, QuickScorer-compatible
    // depth).
    let ds = synthetic::adult_like(rows, 20230806);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = trees;
    cfg.max_depth = 5;
    let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();

    let bench = benchmark_inference(model.as_ref(), &ds, runs);
    println!("{}", bench.report());

    // Model-open time: parsing the JSON model vs mmap-ing the compiled
    // artifact (`ydf compile`) — the serving cold-start the artifact
    // format exists to cut. Recorded as "model_open" in the JSON report.
    let dir = std::env::temp_dir().join("ydf_b4_model_open");
    std::fs::create_dir_all(&dir).ok();
    let json_path = dir.join("model.json");
    let bin_path = dir.join("model.bin");
    ydf::model::io::save_model(model.as_ref(), &json_path).unwrap();
    let forest = ydf::inference::compiled::CompiledForest::lower(model.as_ref()).unwrap();
    forest.write_artifact(&bin_path).unwrap();
    let time_open_ms = |path: &std::path::Path| {
        let t0 = std::time::Instant::now();
        for _ in 0..runs.max(1) {
            std::hint::black_box(ydf::model::io::load_model(path).unwrap());
        }
        t0.elapsed().as_secs_f64() / runs.max(1) as f64 * 1e3
    };
    let json_ms = time_open_ms(&json_path);
    let artifact_ms = time_open_ms(&bin_path);
    let artifact_bytes = std::fs::metadata(&bin_path).map(|m| m.len()).unwrap_or(0);
    println!(
        "  model open: JSON parse {json_ms:.3} ms, artifact mmap {artifact_ms:.3} ms \
         ({artifact_bytes} bytes on disk)"
    );
    std::fs::remove_file(&json_path).ok();
    std::fs::remove_file(&bin_path).ok();

    let mut report = bench.to_json();
    let mut open = ydf::utils::json::Json::obj();
    open.set("json_ms", ydf::utils::json::Json::Num(json_ms))
        .set("artifact_ms", ydf::utils::json::Json::Num(artifact_ms))
        .set("artifact_bytes", ydf::utils::json::Json::Num(artifact_bytes as f64));
    report.set("model_open", open);

    // Measured routing (inference::router): the per-batch-size winner
    // table the serving router pins at model load, next to the static
    // engine order's time in the same bucket — the routed-vs-static
    // record the router exists to improve on.
    use ydf::inference::router;
    use ydf::utils::json::Json;
    let static_tag = router::static_variant(model.as_ref())
        .map(|v| v.tag())
        .unwrap_or_else(|| "none".to_string());
    let mut router_json = Json::obj();
    router_json.set("static", Json::Str(static_tag.clone()));
    let mut buckets_json = Json::obj();
    match router::measure_model(model.as_ref(), router::DEFAULT_SEED) {
        Some(table) => {
            println!("  router calibration (static order pins: {static_tag}):");
            for b in &table.buckets {
                let (winner, best_ns) = &b.ranking[0];
                let static_ns = b
                    .ranking
                    .iter()
                    .find(|(v, _)| v.tag() == static_tag)
                    .map(|(_, ns)| *ns);
                match static_ns {
                    Some(s_ns) => println!(
                        "    rows={:<4} routed {:<20} {best_ns:>10.1} ns/row   static {s_ns:>10.1} ns/row ({:+.1}%)",
                        b.rows,
                        winner.tag(),
                        (best_ns / s_ns - 1.0) * 100.0
                    ),
                    None => println!(
                        "    rows={:<4} routed {:<20} {best_ns:>10.1} ns/row",
                        b.rows,
                        winner.tag()
                    ),
                }
                let mut bj = Json::obj();
                bj.set("winner", Json::Str(winner.tag()))
                    .set("ns_per_row", Json::Num(*best_ns));
                if let Some(s_ns) = static_ns {
                    bj.set("static_ns_per_row", Json::Num(s_ns));
                }
                buckets_json.set(&b.rows.to_string(), bj);
            }
        }
        None => println!("  (router calibration skipped: no optimized engine compiles)"),
    }
    router_json.set("buckets", buckets_json);
    report.set("router", router_json);

    match std::fs::write(&out_path, report.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("cannot write {out_path}: {e}"),
    }

    // PJRT/XLA engine (lossy compilation, §3.7), when artifacts exist.
    // It requires an all-numerical model, so it gets its own dataset.
    let wilt = synthetic::spec_by_name("Wilt").unwrap();
    let opts = synthetic::GenOptions { max_examples: 2000, ..Default::default() };
    let pjrt_ds = synthetic::generate(wilt, 20230806, &opts);
    let mut pjrt_cfg = GbtConfig::new("label");
    pjrt_cfg.num_trees = 50;
    pjrt_cfg.max_depth = 5;
    let pjrt_model = GradientBoostedTreesLearner::new(pjrt_cfg).train(&pjrt_ds).unwrap();
    match ydf::runtime::Runtime::cpu()
        .and_then(|rt| ydf::inference::pjrt::PjrtEngine::compile(pjrt_model.as_ref(), &rt))
    {
        Ok(engine) => {
            let mut out = vec![0.0f64; pjrt_ds.num_rows() * engine.output_dim()];
            let t0 = std::time::Instant::now();
            let pjrt_runs = 5;
            for _ in 0..pjrt_runs {
                engine.predict_into(&pjrt_ds, 1, &mut out);
                std::hint::black_box(&mut out);
            }
            let us = t0.elapsed().as_secs_f64() / (pjrt_runs * pjrt_ds.num_rows()) as f64 * 1e6;
            println!("  {:<42} {us:>10.3} us/example (Wilt, numerical-only)", engine.name());
        }
        Err(e) => println!("  (PJRT engine skipped: {e})"),
    }
}
