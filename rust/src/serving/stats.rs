//! Serving telemetry: request/row/batch throughput counters, queue-depth
//! gauges, rejection counts and request-latency percentiles, exportable
//! as JSON (the server's `{"cmd": "stats"}` response) or as the
//! `utils/histogram.rs` text rendering for humans.
//!
//! Latency is kept in a fixed-size reservoir (Vitter's Algorithm R over
//! at most [`LATENCY_RESERVOIR_CAP`] samples) with exact full-stream
//! count/mean/min/max via `utils/stats::Moments` — a long-lived server
//! holds bounded memory no matter how many requests it answers, and the
//! `stats` command sorts at most the reservoir, outside the lock.

use crate::utils::histogram::TextHistogram;
use crate::utils::json::Json;
use crate::utils::stats::Moments;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Upper bound on retained latency samples (8 bytes each). Percentiles
/// are exact below the cap and uniformly sampled above it.
pub const LATENCY_RESERVOIR_CAP: usize = 16384;

/// Fixed-size uniform sample of the latency stream plus exact moments.
struct LatencyReservoir {
    moments: Moments,
    samples: Vec<f64>,
    /// xorshift64* state for Algorithm R replacement.
    rng: u64,
}

impl LatencyReservoir {
    fn new() -> LatencyReservoir {
        LatencyReservoir {
            moments: Moments::new(),
            samples: Vec::new(),
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }

    fn add(&mut self, x: f64) {
        self.moments.add(x);
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(x);
        } else {
            self.rng ^= self.rng >> 12;
            self.rng ^= self.rng << 25;
            self.rng ^= self.rng >> 27;
            let r = self.rng.wrapping_mul(0x2545_F491_4F6C_DD1D);
            // Algorithm R: replace a uniformly random index in
            // 0..seen-so-far; indices >= CAP mean "keep the reservoir".
            let j = (r % self.moments.count()) as usize;
            if j < LATENCY_RESERVOIR_CAP {
                self.samples[j] = x;
            }
        }
    }
}

/// Shared, thread-safe serving counters. One instance is shared by the
/// TCP front end (request latency), the batcher (batch sizes, queue
/// depth, rejections) and the `stats` command (export).
pub struct ServingStats {
    requests: AtomicU64,
    rows: AtomicU64,
    errors: AtomicU64,
    rejected: AtomicU64,
    shed_deadline: AtomicU64,
    timed_out_conns: AtomicU64,
    overlong_lines: AtomicU64,
    reloads: AtomicU64,
    batches: AtomicU64,
    batched_rows: AtomicU64,
    batched_requests: AtomicU64,
    queue_rows: AtomicUsize,
    queue_rows_peak: AtomicUsize,
    /// Per-request wall latency in microseconds (decode → respond).
    latency_us: Mutex<LatencyReservoir>,
}

/// A point-in-time copy of the counters (tests and reports).
#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub rows: u64,
    pub errors: u64,
    pub rejected: u64,
    pub shed_deadline: u64,
    pub timed_out_conns: u64,
    pub overlong_lines: u64,
    pub reloads: u64,
    pub batches: u64,
    pub batched_rows: u64,
    pub batched_requests: u64,
    pub queue_rows: usize,
    pub queue_rows_peak: usize,
}

impl Default for ServingStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingStats {
    pub fn new() -> ServingStats {
        ServingStats {
            requests: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed_deadline: AtomicU64::new(0),
            timed_out_conns: AtomicU64::new(0),
            overlong_lines: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_rows: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            queue_rows: AtomicUsize::new(0),
            queue_rows_peak: AtomicUsize::new(0),
            latency_us: Mutex::new(LatencyReservoir::new()),
        }
    }

    /// One successfully answered request of `rows` rows taking
    /// `latency_us` microseconds end to end.
    pub fn note_request(&self, rows: usize, latency_us: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.latency_us.lock().expect("stats poisoned").add(latency_us);
    }

    /// One request answered with an error (parse, decode, or submit).
    pub fn note_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One submission rejected by the bounded queue, a per-model quota or
    /// the shared admission budget (backpressure).
    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One accepted request shed at flush time by the queue deadline.
    pub fn note_shed(&self) {
        self.shed_deadline.fetch_add(1, Ordering::Relaxed);
    }

    /// One connection closed by the read/write idle timeout.
    pub fn note_conn_timeout(&self) {
        self.timed_out_conns.fetch_add(1, Ordering::Relaxed);
    }

    /// One connection closed for streaming a request line past the
    /// server's byte cap without a newline (OOM guard).
    pub fn note_overlong_line(&self) {
        self.overlong_lines.fetch_add(1, Ordering::Relaxed);
    }

    /// One hot reload (swap) of the model behind this stats handle.
    pub fn note_reload(&self) {
        self.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// One scored batch coalescing `requests` requests into `rows` rows.
    pub fn note_batch(&self, rows: usize, requests: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.batched_requests.fetch_add(requests as u64, Ordering::Relaxed);
    }

    /// Current queue depth in rows; also tracks the high-water mark.
    pub fn set_queue_rows(&self, rows: usize) {
        self.queue_rows.store(rows, Ordering::Relaxed);
        self.queue_rows_peak.fetch_max(rows, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            shed_deadline: self.shed_deadline.load(Ordering::Relaxed),
            timed_out_conns: self.timed_out_conns.load(Ordering::Relaxed),
            overlong_lines: self.overlong_lines.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_rows: self.batched_rows.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            queue_rows: self.queue_rows.load(Ordering::Relaxed),
            queue_rows_peak: self.queue_rows_peak.load(Ordering::Relaxed),
        }
    }

    /// Drops accumulated latency samples (counters are kept).
    pub fn reset_latency(&self) {
        *self.latency_us.lock().expect("stats poisoned") = LatencyReservoir::new();
    }

    /// Point-in-time latency summary: exact full-stream
    /// `(count, mean, min, max)` plus a clone of the reservoir sample.
    /// Copies under the lock; callers sort/merge outside it.
    pub fn latency_summary(&self) -> (u64, f64, f64, f64, Vec<f64>) {
        let r = self.latency_us.lock().expect("stats poisoned");
        (r.moments.count(), r.moments.mean(), r.moments.min(), r.moments.max(), r.samples.clone())
    }

    /// JSON export: counters plus latency mean and p50/p95/p99 (µs).
    /// Count/mean/min/max are exact over the full stream; percentiles are
    /// exact below [`LATENCY_RESERVOIR_CAP`] samples, sampled above.
    pub fn to_json(&self) -> Json {
        // Copy what is needed under the lock; sort outside it so a stats
        // call never stalls in-flight request accounting.
        let (count, mean, min, max, xs) = self.latency_summary();
        let mut j = counters_json(&self.snapshot());
        j.set("latency", latency_json(count, mean, min, max, xs));
        j
    }

    /// Human-readable report: counters plus the latency text histogram
    /// (`utils/histogram.rs`), rendered over the reservoir sample.
    pub fn report(&self) -> String {
        let s = self.snapshot();
        let mut out = format!(
            "requests: {} ({} rows, {} errors, {} rejected, {} deadline-shed)\n\
             lifecycle: {} reloads, {} timed-out connections, {} overlong lines\n\
             batches: {} (mean {:.1} rows/batch, {:.1} requests/batch)\n\
             queue: {} rows now, {} rows peak\n\nrequest latency (us):\n",
            s.requests,
            s.rows,
            s.errors,
            s.rejected,
            s.shed_deadline,
            s.reloads,
            s.timed_out_conns,
            s.overlong_lines,
            s.batches,
            if s.batches > 0 { s.batched_rows as f64 / s.batches as f64 } else { 0.0 },
            if s.batches > 0 { s.batched_requests as f64 / s.batches as f64 } else { 0.0 },
            s.queue_rows,
            s.queue_rows_peak,
        );
        let (samples, total) = {
            let r = self.latency_us.lock().expect("stats poisoned");
            (r.samples.clone(), r.moments.count())
        };
        if total as usize > samples.len() {
            out.push_str(&format!(
                "(uniform sample of {} of {} requests)\n",
                samples.len(),
                total
            ));
        }
        let mut h = TextHistogram::new();
        h.extend(samples);
        out.push_str(&h.render(10, 20));
        out
    }
}

/// The counter section shared by [`ServingStats::to_json`] and the
/// per-model entries of [`aggregate_json`] (everything except the
/// latency block).
fn counters_json(s: &StatsSnapshot) -> Json {
    let mut j = Json::obj();
    j.set("requests", Json::Num(s.requests as f64))
        .set("rows", Json::Num(s.rows as f64))
        .set("errors", Json::Num(s.errors as f64))
        .set("rejected", Json::Num(s.rejected as f64))
        .set("shed_deadline", Json::Num(s.shed_deadline as f64))
        .set("timed_out_conns", Json::Num(s.timed_out_conns as f64))
        .set("overlong_lines", Json::Num(s.overlong_lines as f64))
        .set("reloads", Json::Num(s.reloads as f64))
        .set("batches", Json::Num(s.batches as f64))
        .set("batched_rows", Json::Num(s.batched_rows as f64))
        .set("batched_requests", Json::Num(s.batched_requests as f64))
        .set(
            "mean_batch_rows",
            Json::Num(if s.batches > 0 {
                s.batched_rows as f64 / s.batches as f64
            } else {
                0.0
            }),
        )
        .set("queue_rows", Json::Num(s.queue_rows as f64))
        .set("queue_rows_peak", Json::Num(s.queue_rows_peak as f64));
    j
}

/// Renders one latency block (`count` exact; percentiles from `xs`,
/// which is sorted here, outside any lock).
fn latency_json(count: u64, mean: f64, min: f64, max: f64, mut xs: Vec<f64>) -> Json {
    let mut lat = Json::obj();
    lat.set("count", Json::Num(count as f64));
    if count > 0 {
        xs.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        lat.set("mean_us", Json::Num(mean))
            .set("min_us", Json::Num(min))
            .set("max_us", Json::Num(max));
        for (name, p) in [("p50_us", 0.50), ("p95_us", 0.95), ("p99_us", 0.99)] {
            lat.set(name, Json::Num(percentile(&xs, p)));
        }
    }
    lat
}

/// The multi-model `{"cmd": "stats"}` export: the top level carries the
/// same keys as [`ServingStats::to_json`], aggregated across every model
/// (counters summed; latency count/mean/min/max combined exactly from
/// the per-model moments; percentiles by *weighted* nearest rank over
/// the merged reservoirs — each model's retained sample carries weight
/// `count / samples.len()`, its exact stream multiplicity, so a model
/// with 100 requests no longer pulls on the aggregate like one with a
/// million), plus a `"models"` object with each model's full individual
/// export. Each model is read **once** — the aggregate and its
/// `"models"` entry come from the same snapshot, so the two levels of
/// one reply always agree. With a single model every sample has equal
/// weight and weighted nearest rank reduces to the unweighted one, so
/// the top level matches that model's own `to_json` — the PR-3
/// single-model wire shape is preserved.
pub fn aggregate_json(named: &[(&str, &ServingStats)]) -> Json {
    let mut total = StatsSnapshot::default();
    let mut count = 0u64;
    let mut mean_weighted = 0.0f64;
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    let mut samples: Vec<(f64, f64)> = Vec::new();
    let mut models = Json::obj();
    for (name, stats) in named {
        let s = stats.snapshot();
        let (c, mean, mn, mx, xs) = stats.latency_summary();
        total.requests += s.requests;
        total.rows += s.rows;
        total.errors += s.errors;
        total.rejected += s.rejected;
        total.shed_deadline += s.shed_deadline;
        total.timed_out_conns += s.timed_out_conns;
        total.overlong_lines += s.overlong_lines;
        total.reloads += s.reloads;
        total.batches += s.batches;
        total.batched_rows += s.batched_rows;
        total.batched_requests += s.batched_requests;
        total.queue_rows += s.queue_rows;
        total.queue_rows_peak = total.queue_rows_peak.max(s.queue_rows_peak);
        if c > 0 {
            count += c;
            mean_weighted += mean * c as f64;
            min = min.min(mn);
            max = max.max(mx);
            // Each reservoir uniformly samples its own stream, so a
            // retained sample stands for count/len requests. Weighting
            // restores each model's true share of the merged stream —
            // plain concatenation would give a capped 1M-request model
            // the same pull as an uncapped 16k one.
            let w = c as f64 / xs.len() as f64;
            samples.extend(xs.iter().map(|&x| (x, w)));
        }
        let mut mj = counters_json(&s);
        mj.set("latency", latency_json(c, mean, mn, mx, xs));
        models.set(name, mj);
    }
    let mut j = counters_json(&total);
    j.set(
        "latency",
        weighted_latency_json(
            count,
            if count > 0 { mean_weighted / count as f64 } else { 0.0 },
            min,
            max,
            samples,
        ),
    );
    j.set("models", models);
    j
}

/// As [`latency_json`], over `(value, weight)` samples merged from
/// several reservoirs.
fn weighted_latency_json(count: u64, mean: f64, min: f64, max: f64, mut xs: Vec<(f64, f64)>) -> Json {
    let mut lat = Json::obj();
    lat.set("count", Json::Num(count as f64));
    if count > 0 {
        xs.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("latencies are finite"));
        let total: f64 = xs.iter().map(|(_, w)| w).sum();
        lat.set("mean_us", Json::Num(mean))
            .set("min_us", Json::Num(min))
            .set("max_us", Json::Num(max));
        for (name, p) in [("p50_us", 0.50), ("p95_us", 0.95), ("p99_us", 0.99)] {
            lat.set(name, Json::Num(weighted_percentile(&xs, total, p)));
        }
    }
    lat
}

/// Nearest-rank percentile over an ascending-sorted sample.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Weighted nearest-rank percentile over ascending-sorted
/// `(value, weight)` pairs: the smallest value whose cumulative weight
/// reaches `p` of `total`. With equal weights this reduces exactly to
/// [`percentile`]; the relative slack absorbs floating-point
/// accumulation so the boundary rank does not flip.
fn weighted_percentile(sorted: &[(f64, f64)], total: f64, p: f64) -> f64 {
    if sorted.is_empty() || total <= 0.0 {
        return 0.0;
    }
    let threshold = p * total;
    let slack = total * 1e-9;
    let mut cum = 0.0;
    for &(x, w) in sorted {
        cum += w;
        if cum + slack >= threshold {
            return x;
        }
    }
    sorted[sorted.len() - 1].0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_export() {
        let s = ServingStats::new();
        s.note_request(1, 120.0);
        s.note_request(8, 480.0);
        s.note_error();
        s.note_rejected();
        s.note_shed();
        s.note_shed();
        s.note_conn_timeout();
        s.note_reload();
        s.note_batch(9, 2);
        s.set_queue_rows(5);
        s.set_queue_rows(2);
        let snap = s.snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.rows, 9);
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.shed_deadline, 2);
        assert_eq!(snap.timed_out_conns, 1);
        assert_eq!(snap.reloads, 1);
        assert_eq!(snap.batches, 1);
        assert_eq!(snap.queue_rows, 2);
        assert_eq!(snap.queue_rows_peak, 5);
        let j = s.to_json();
        assert_eq!(j.req_f64("requests").unwrap(), 2.0);
        assert_eq!(j.req_f64("shed_deadline").unwrap(), 2.0);
        assert_eq!(j.req_f64("timed_out_conns").unwrap(), 1.0);
        assert_eq!(j.req_f64("reloads").unwrap(), 1.0);
        assert_eq!(j.req_f64("mean_batch_rows").unwrap(), 9.0);
        let lat = j.req("latency").unwrap();
        assert_eq!(lat.req_f64("count").unwrap(), 2.0);
        assert_eq!(lat.req_f64("p99_us").unwrap(), 480.0);
        assert!(s.report().contains("rows peak"));
    }

    #[test]
    fn empty_stats_export_cleanly() {
        let s = ServingStats::new();
        let j = s.to_json();
        assert_eq!(j.req_f64("requests").unwrap(), 0.0);
        assert_eq!(j.req("latency").unwrap().req_f64("count").unwrap(), 0.0);
        assert!(s.report().contains("(empty)"));
    }

    #[test]
    fn aggregate_json_sums_counters_and_merges_latency() {
        let a = ServingStats::new();
        let b = ServingStats::new();
        a.note_request(2, 100.0);
        a.note_request(2, 300.0);
        b.note_request(1, 500.0);
        b.note_error();
        a.note_batch(4, 2);
        b.note_batch(1, 1);
        a.set_queue_rows(7);
        a.set_queue_rows(0);
        b.set_queue_rows(3);
        a.note_shed();
        b.note_reload();
        b.note_conn_timeout();
        let j = aggregate_json(&[("a", &a), ("b", &b)]);
        assert_eq!(j.req_f64("requests").unwrap(), 3.0);
        assert_eq!(j.req_f64("shed_deadline").unwrap(), 1.0);
        assert_eq!(j.req_f64("reloads").unwrap(), 1.0);
        assert_eq!(j.req_f64("timed_out_conns").unwrap(), 1.0);
        assert_eq!(j.req_f64("rows").unwrap(), 5.0);
        assert_eq!(j.req_f64("errors").unwrap(), 1.0);
        assert_eq!(j.req_f64("batches").unwrap(), 2.0);
        assert_eq!(j.req_f64("mean_batch_rows").unwrap(), 2.5);
        assert_eq!(j.req_f64("queue_rows_peak").unwrap(), 7.0);
        let lat = j.req("latency").unwrap();
        assert_eq!(lat.req_f64("count").unwrap(), 3.0);
        assert_eq!(lat.req_f64("mean_us").unwrap(), 300.0);
        assert_eq!(lat.req_f64("min_us").unwrap(), 100.0);
        assert_eq!(lat.req_f64("max_us").unwrap(), 500.0);
        // Per-model breakdown carries each model's own full export.
        let models = j.req("models").unwrap();
        assert_eq!(models.req("a").unwrap().req_f64("requests").unwrap(), 2.0);
        assert_eq!(models.req("b").unwrap().req_f64("errors").unwrap(), 1.0);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let s = ServingStats::new();
        for i in 1..=100 {
            s.note_request(1, i as f64);
        }
        let j = s.to_json();
        let lat = j.req("latency").unwrap();
        assert_eq!(lat.req_f64("p50_us").unwrap(), 50.0);
        assert_eq!(lat.req_f64("p95_us").unwrap(), 95.0);
        assert_eq!(lat.req_f64("p99_us").unwrap(), 99.0);
    }

    #[test]
    fn percentile_edge_cases_zero_and_one_sample() {
        // 0 samples: the latency block is just {"count": 0} — no
        // percentile keys to mislead a dashboard, and percentile() on an
        // empty sample answers 0.0 rather than panicking.
        assert_eq!(percentile(&[], 0.5), 0.0);
        let empty = ServingStats::new();
        let lat0 = empty.to_json();
        let lat0 = lat0.req("latency").unwrap();
        assert_eq!(lat0.req_f64("count").unwrap(), 0.0);
        assert!(lat0.get("p50_us").is_none());
        assert!(lat0.get("mean_us").is_none());
        // 1 sample: every percentile is that sample (nearest rank clamps
        // to the only element), as are min/mean/max.
        let one = ServingStats::new();
        one.note_request(1, 250.0);
        let j = one.to_json();
        let lat = j.req("latency").unwrap();
        for key in ["p50_us", "p95_us", "p99_us", "mean_us", "min_us", "max_us"] {
            assert_eq!(lat.req_f64(key).unwrap(), 250.0, "{key}");
        }
    }

    #[test]
    fn reservoir_sample_bounded_and_moments_exact_past_cap() {
        // Drive well past the cap and check both halves of the contract:
        // the retained sample never exceeds LATENCY_RESERVOIR_CAP and
        // every retained value came from the stream, while count/mean/
        // min/max stay exact over the *full* stream.
        let s = ServingStats::new();
        let n = 2 * LATENCY_RESERVOIR_CAP + 123;
        for i in 0..n {
            s.note_request(1, i as f64);
        }
        let (count, mean, min, max, samples) = s.latency_summary();
        assert_eq!(count, n as u64);
        assert_eq!(min, 0.0);
        assert_eq!(max, (n - 1) as f64);
        let exact_mean = (n - 1) as f64 / 2.0;
        assert!((mean - exact_mean).abs() < 1e-6, "mean {mean} vs {exact_mean}");
        assert_eq!(samples.len(), LATENCY_RESERVOIR_CAP);
        assert!(samples
            .iter()
            .all(|&x| x >= 0.0 && x < n as f64 && x.fract() == 0.0));
        // Algorithm R actually replaced entries: a reservoir frozen at the
        // first CAP values would top out at CAP-1.
        assert!(
            samples.iter().cloned().fold(0.0f64, f64::max)
                >= LATENCY_RESERVOIR_CAP as f64,
            "no sample past the cap made it into the reservoir"
        );
    }

    #[test]
    fn aggregate_json_multi_model_shape() {
        let a = ServingStats::new();
        let b = ServingStats::new();
        let c = ServingStats::new();
        a.note_request(1, 10.0);
        b.note_request(2, 20.0);
        // c stays empty: an idle model must still appear in the breakdown.
        let j = aggregate_json(&[("alpha", &a), ("beta", &b), ("gamma", &c)]);
        let models = j.req("models").unwrap();
        let Json::Obj(map) = models else { panic!("models is an object") };
        let names: Vec<&str> = map.keys().map(|k| k.as_str()).collect();
        assert_eq!(names, ["alpha", "beta", "gamma"], "one entry per model");
        for (name, entry) in map {
            // Each entry is a full per-model export: counters + latency.
            for key in ["requests", "rows", "errors", "batches", "queue_rows"] {
                assert!(entry.get(key).is_some(), "{name} missing {key}");
            }
            assert!(entry.req("latency").unwrap().get("count").is_some());
        }
        assert_eq!(j.req_f64("requests").unwrap(), 2.0);
        assert_eq!(j.req_f64("rows").unwrap(), 3.0);
        assert_eq!(j.req("latency").unwrap().req_f64("count").unwrap(), 2.0);
        // Single-model aggregation preserves that model's own export at
        // the top level (the PR-3 wire shape).
        let solo = aggregate_json(&[("alpha", &a)]);
        assert_eq!(solo.req_f64("requests").unwrap(), a.to_json().req_f64("requests").unwrap());
        assert_eq!(
            solo.req("latency").unwrap().req_f64("p99_us").unwrap(),
            a.to_json().req("latency").unwrap().req_f64("p99_us").unwrap()
        );
    }

    #[test]
    fn aggregate_percentiles_weight_models_by_stream_count() {
        // Model A saw 4x the reservoir cap of fast requests, so its
        // reservoir is capped at 16384 samples standing for 65536
        // requests. Model B saw 2048 slow requests, all retained. The
        // merged stream is 65536 fast + 2048 slow = ~3% slow, so p50
        // and p95 must be fast and only p99 slow. Unweighted
        // concatenation would see 16384 fast vs 2048 slow samples
        // (~11% slow) — still p95=fast, but weight B up and the bias
        // flips medians; pin the exact weighted ranks instead.
        let a = ServingStats::new();
        let b = ServingStats::new();
        for _ in 0..4 * LATENCY_RESERVOIR_CAP {
            a.note_request(1, 10.0);
        }
        for _ in 0..2048 {
            b.note_request(1, 1000.0);
        }
        let j = aggregate_json(&[("fast", &a), ("slow", &b)]);
        let lat = j.req("latency").unwrap();
        let total = (4 * LATENCY_RESERVOIR_CAP + 2048) as f64;
        assert_eq!(lat.req_f64("count").unwrap(), total);
        // Slow share = 2048/67584 ≈ 3.03%: below the p95 tail, inside
        // the p99 tail.
        assert_eq!(lat.req_f64("p50_us").unwrap(), 10.0);
        assert_eq!(lat.req_f64("p95_us").unwrap(), 10.0);
        assert_eq!(lat.req_f64("p99_us").unwrap(), 1000.0);
        // Moments stay exact: mean = (65536*10 + 2048*1000) / 67584.
        let want_mean = (4.0 * LATENCY_RESERVOIR_CAP as f64 * 10.0 + 2048.0 * 1000.0) / total;
        assert!((lat.req_f64("mean_us").unwrap() - want_mean).abs() < 1e-9);
    }

    #[test]
    fn weighted_percentile_with_equal_weights_matches_plain_nearest_rank() {
        // The single-model aggregate path must reduce exactly to the
        // per-model export: same values, equal weights, same ranks.
        let xs: Vec<f64> = (1..=97).map(|i| i as f64).collect();
        let weighted: Vec<(f64, f64)> = xs.iter().map(|&x| (x, 3.5)).collect();
        let total = 3.5 * xs.len() as f64;
        for p in [0.01, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0] {
            assert_eq!(
                weighted_percentile(&weighted, total, p),
                percentile(&xs, p),
                "p={p}"
            );
        }
    }

    #[test]
    fn reservoir_stays_bounded_with_exact_moments() {
        let s = ServingStats::new();
        let n = LATENCY_RESERVOIR_CAP + 500;
        for i in 0..n {
            s.note_request(1, i as f64);
        }
        let j = s.to_json();
        let lat = j.req("latency").unwrap();
        // Full-stream statistics stay exact past the cap...
        assert_eq!(lat.req_f64("count").unwrap(), n as f64);
        assert_eq!(lat.req_f64("min_us").unwrap(), 0.0);
        assert_eq!(lat.req_f64("max_us").unwrap(), (n - 1) as f64);
        // ...while percentiles come from the bounded uniform sample.
        let p50 = lat.req_f64("p50_us").unwrap();
        assert!(p50 > 0.0 && p50 < (n - 1) as f64, "{p50}");
        assert!(s.report().contains("uniform sample"));
    }
}
