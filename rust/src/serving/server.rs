//! TCP front end: newline-delimited JSON over `std::net`, fanned out to a
//! `utils/pool.rs` worker pool, routed through the model [`Registry`] and
//! scored through each model's [`crate::serving::Batcher`].
//!
//! ## Wire protocol (one JSON value per line, both directions)
//!
//! Prediction requests:
//!
//! ```text
//! {"rows": [{"age": 44, "education": "Masters"}, {"age": 23}]}
//! {"model": "fraud_v2", "rows": [{"age": 44}]}   // route to a named model
//! {"age": 44, "education": "Masters"}            // single-row shorthand
//! ```
//!
//! → `{"predictions": [[0.21, 0.79], …], "model": "…"}` — one array of
//! `output_dim()` values per request row, in request order. Absent or
//! `null` features are missing; unknown feature names are an error. The
//! top-level `"model"` field selects the serving session; requests
//! without one go to the default model (the first registered), which is
//! why single-model deployments see the PR-3 protocol unchanged. The
//! bare single-row shorthand always addresses the default model — its
//! object is entirely feature keys.
//!
//! Commands (`"model"` selects the model `health`/`spec` describe):
//!
//! ```text
//! {"cmd": "health"}    -> {"ok": true, "model": …, "models": […], "states": {…}, …}
//! {"cmd": "spec"}      -> {"model": …, "features": […], "label": …, "classes": […]}
//! {"cmd": "stats"}     -> aggregate counters + per-model breakdown under "models"
//! {"cmd": "metrics"}   -> {"content_type": "text/plain; version=0.0.4", "metrics": "…"} —
//!                         the full Prometheus text exposition (per-model serving
//!                         counters + the global obs registry) as one JSON string
//! {"cmd": "shutdown"}  -> {"ok": true}, then the server stops accepting
//! ```
//!
//! Admin commands — the hot-reload control plane (`"path"` is a model
//! file on the *server's* filesystem):
//!
//! ```text
//! {"cmd": "load",   "model": "fraud_v3", "path": "/models/fraud_v3.ydf"}
//! {"cmd": "swap",   "model": "fraud",    "path": "/models/fraud_v3.ydf"}
//! {"cmd": "unload", "model": "fraud_v1"}
//! ```
//!
//! → `{"ok": true, "cmd": …, "model": …, "generation": N}`. The session
//! build runs on the requesting connection's worker with no registry
//! lock held — scoring traffic is never paused; a swap drains the old
//! generation in the background with zero accepted requests dropped.
//!
//! Every error — malformed JSON, unknown feature, unknown model, full
//! queue, a deadline-shed request (with `"retryable": true` and a
//! `"retry_after_ms"` hint), a failed load — is a `{"error": "…"}`
//! response on the same line; the connection survives. Connections that
//! stay silent (or write nothing readable) longer than
//! [`ServerConfig::conn_timeout`] are reaped with one final in-band
//! error, and a request line longer than
//! [`ServerConfig::max_line_bytes`] is answered with one in-band error
//! and the connection closed — the peer is mid-line, so there is no
//! next line boundary to resynchronize on. See `docs/serving.md`
//! ("Server loop", "Control plane & failure modes") for the full
//! contract.

use super::batcher::ScoreError;
use super::registry::{ModelEntry, Registry};
use super::session::{RowBlock, Session};
use crate::inference::router::CalibrateMode;
use crate::utils::json::Json;
use crate::utils::pool::WorkerPool;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Front-end configuration. `workers` bounds concurrent connections (a
/// connection occupies its worker until the peer disconnects). Batching
/// policy lives with the [`Registry`], which applies it to every model.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (printed on stdout).
    pub addr: String,
    pub workers: usize,
    /// Read/write timeout applied to every accepted connection (`None`
    /// = never time out). A worker parked on a silent peer — an idle
    /// client, or a slowloris dribbling bytes — is reclaimed after this
    /// long: the peer gets one in-band timeout error, the connection
    /// closes, and `timed_out_conns` increments.
    pub conn_timeout: Option<Duration>,
    /// Hard cap on one request line's length in bytes. A peer that
    /// streams more than this without a newline gets one in-band error
    /// and its connection closed (`overlong_lines` increments) instead
    /// of growing the line buffer — and the worker's memory — without
    /// bound. The 16 MiB default clears any sane batch by orders of
    /// magnitude.
    pub max_line_bytes: usize,
    /// Engine-calibration policy applied when the control plane opens a
    /// model file (`load`/`swap`): the [`CalibrateMode`] forwarded to
    /// [`Session::open_with`]. Mirrors the server CLI's
    /// `--calibrate=off|load|force` flag.
    pub calibrate: CalibrateMode,
    /// Fault plan consulted once per received request line (the
    /// connection-stall fault point). Test-only plumbing.
    #[cfg(any(test, feature = "fault-injection"))]
    pub faults: Option<Arc<super::faults::FaultPlan>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8123".to_string(),
            workers: 4,
            conn_timeout: Some(Duration::from_secs(60)),
            max_line_bytes: 16 << 20,
            calibrate: CalibrateMode::Load,
            #[cfg(any(test, feature = "fault-injection"))]
            faults: None,
        }
    }
}

/// Live-connection registry: a clone of every open stream, so shutdown
/// can close them and unblock workers parked in `read_line` — without
/// it, one idle client connection would stall `serve()`'s worker join
/// forever (or until its `conn_timeout` fires).
#[derive(Default)]
struct ConnRegistry {
    streams: Mutex<HashMap<u64, TcpStream>>,
    next_id: AtomicU64,
}

impl ConnRegistry {
    /// The map's operations are valid on any state, so a poisoned lock
    /// (a worker panicked mid-insert/remove) is recovered rather than
    /// skipped — skipping `close_all` in particular would let one idle
    /// connection hang server shutdown forever.
    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<u64, TcpStream>> {
        match self.streams.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn insert(&self, stream: TcpStream) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.lock().insert(id, stream);
        id
    }

    fn remove(&self, id: u64) {
        self.lock().remove(&id);
    }

    fn close_all(&self) {
        for (_, s) in self.lock().drain() {
            // Read half only: unblocks workers parked reading (they see
            // EOF) while letting responses to already-accepted in-flight
            // requests still be written before the worker exits.
            let _ = s.shutdown(Shutdown::Read);
        }
    }
}

/// Binds, prints `listening on <addr>` on stdout (machine-parsable — the
/// smoke test reads the ephemeral port from it), and serves every model
/// in `registry` until a `{"cmd": "shutdown"}` request arrives. On
/// shutdown every open connection is closed (idle clients cannot stall
/// the exit), every model's batcher drains, and the call returns once
/// every worker has exited.
pub fn serve(registry: Registry, config: &ServerConfig) -> Result<(), String> {
    serve_shared(Arc::new(registry), config)
}

/// [`serve`] over an already-shared registry: callers that keep their
/// own `Arc<Registry>` (tests driving admin operations from outside the
/// wire protocol, embedders running their own control loop) hand a
/// clone here and hot-reload concurrently with the serving loop.
pub fn serve_shared(registry: Arc<Registry>, config: &ServerConfig) -> Result<(), String> {
    if registry.is_empty() {
        return Err("cannot serve an empty registry: register at least one model".to_string());
    }
    let listener = TcpListener::bind(&config.addr)
        .map_err(|e| format!("cannot bind {}: {e}", config.addr))?;
    let local = listener
        .local_addr()
        .map_err(|e| format!("cannot resolve bound address: {e}"))?;
    for e in registry.entries() {
        println!(
            "serving model '{}' ({}) through engine: {}",
            e.name(),
            e.session().model().model_type(),
            e.session().engine_name()
        );
    }
    println!("listening on {local}");
    let shutdown = Arc::new(AtomicBool::new(false));
    let conns = Arc::new(ConnRegistry::default());
    let pool = WorkerPool::new(config.workers.max(1));
    // Connections go to the least-loaded worker (a connection occupies
    // its worker until the peer disconnects, so blind round-robin could
    // queue a new connection behind a long-lived one while other workers
    // sit idle).
    let loads: Arc<Vec<AtomicUsize>> =
        Arc::new((0..pool.num_workers()).map(|_| AtomicUsize::new(0)).collect());
    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connection from the shutdown handler
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        // Slowloris / idle-client protection: a worker blocked on this
        // peer gets its thread back after conn_timeout.
        let _ = stream.set_read_timeout(config.conn_timeout);
        let _ = stream.set_write_timeout(config.conn_timeout);
        let id = stream.try_clone().ok().map(|c| conns.insert(c));
        let conn = Connection {
            registry: Arc::clone(&registry),
            shutdown: Arc::clone(&shutdown),
            wake_addr: local,
            max_line_bytes: config.max_line_bytes.max(1),
            calibrate: config.calibrate,
            #[cfg(any(test, feature = "fault-injection"))]
            faults: config.faults.clone(),
        };
        let w = loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap_or(0);
        loads[w].fetch_add(1, Ordering::Relaxed);
        let conns = Arc::clone(&conns);
        let loads = Arc::clone(&loads);
        pool.submit_to(w, move || {
            conn.handle(stream);
            if let Some(id) = id {
                conns.remove(id);
            }
            loads[w].fetch_sub(1, Ordering::Relaxed);
        });
    }
    conns.close_all(); // unblock workers parked on idle connections
    drop(pool); // join workers (in-flight requests finish)
    drop(registry); // possibly the last Arc: batchers flush + join
    println!("server stopped");
    Ok(())
}

/// Decode scratch kept per connection, keyed by model-entry generation
/// (a swap changes the generation, so a stale block for the old dataspec
/// can never be fed to the new session). Beyond this many cached blocks
/// the map is reset — a connection churning through hot-swapped
/// generations must not grow scratch without bound.
const MAX_SCRATCH_BLOCKS: usize = 16;

struct Connection {
    registry: Arc<Registry>,
    shutdown: Arc<AtomicBool>,
    wake_addr: std::net::SocketAddr,
    max_line_bytes: usize,
    calibrate: CalibrateMode,
    #[cfg(any(test, feature = "fault-injection"))]
    faults: Option<Arc<super::faults::FaultPlan>>,
}

impl Connection {
    fn handle(&self, stream: TcpStream) {
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let mut reader = BufReader::new(stream);
        // Per-model decode scratch, lazily created: connections that only
        // ever talk to one model allocate one block.
        let mut blocks: HashMap<u64, RowBlock> = HashMap::new();
        let mut buf: Vec<u8> = Vec::new();
        let cap = self.max_line_bytes as u64;
        loop {
            buf.clear();
            // Bounded line read: at most `cap + 1` bytes of one line are
            // ever buffered. An unbounded `read_line` grows the buffer
            // as fast as a hostile peer can stream newline-free bytes —
            // a per-connection OOM. The +1 distinguishes "exactly cap
            // bytes, then the newline" (fine) from "cap exceeded"
            // (overlong). The `Take` is per-iteration, so the budget
            // resets for every line.
            match reader.by_ref().take(cap + 1).read_until(b'\n', &mut buf) {
                Ok(0) => return, // EOF: peer closed cleanly
                Ok(_) => {}
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    // conn_timeout fired with no complete line: reap the
                    // connection, telling the peer why, in-band.
                    self.note_conn_timeout();
                    let mut j = Json::obj();
                    j.set(
                        "error",
                        Json::Str(
                            "connection timed out waiting for a complete request line; \
                             closing (reconnect to continue)"
                                .to_string(),
                        ),
                    );
                    let _ = writeln!(writer, "{j}").and_then(|_| writer.flush());
                    return;
                }
                Err(_) => return, // peer went away
            }
            if buf.len() as u64 > cap && !buf.ends_with(b"\n") {
                // cap + 1 bytes arrived without a newline: this line can
                // never fit. Answer in-band and close — the peer is
                // mid-line, so there is no boundary to resynchronize on.
                self.note_overlong_line();
                let resp = self.error_default(format!(
                    "request line exceeds max_line_bytes ({} bytes); closing connection",
                    self.max_line_bytes
                ));
                let _ = writeln!(writer, "{resp}").and_then(|_| writer.flush());
                return;
            }
            let line = match std::str::from_utf8(&buf) {
                Ok(s) => s,
                Err(e) => {
                    // The newline boundary is intact, so unlike the
                    // overlong case the connection survives.
                    let resp =
                        self.error_default(format!("request line is not valid UTF-8: {e}"));
                    if writeln!(writer, "{resp}").and_then(|_| writer.flush()).is_err() {
                        return;
                    }
                    continue;
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            #[cfg(any(test, feature = "fault-injection"))]
            if let Some(f) = &self.faults {
                f.on_request_line();
            }
            let t_req = crate::obs::trace::begin();
            let (response, stop) = self.respond(line.trim_end(), &mut blocks);
            crate::obs::trace::end(t_req, "request", || {
                use crate::obs::trace::ArgValue;
                vec![("ok", ArgValue::U64(u64::from(response.get("error").is_none())))]
            });
            if let Err(e) = writeln!(writer, "{response}").and_then(|_| writer.flush()) {
                if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
                    // Peer stopped reading (slowloris on the write side).
                    self.note_conn_timeout();
                }
                return;
            }
            if stop {
                // Shutdown acknowledged: stop accepting, then wake the
                // accept loop with a throwaway connection.
                self.shutdown.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(self.wake_addr);
                return;
            }
        }
    }

    /// Timed-out connections are charged to the default model's stats —
    /// the timeout fires between requests, when no model is addressed
    /// (the aggregate view sums it either way).
    fn note_conn_timeout(&self) {
        self.registry.default_entry().stats().note_conn_timeout();
    }

    /// Overlong request lines are likewise charged to the default model:
    /// the line never parsed, so no model was addressed.
    fn note_overlong_line(&self) {
        self.registry.default_entry().stats().note_overlong_line();
    }

    /// One request line → (response line, stop-serving flag).
    fn respond(&self, line: &str, blocks: &mut HashMap<u64, RowBlock>) -> (Json, bool) {
        let t0 = Instant::now();
        let request = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => return (self.error_default(format!("invalid JSON: {e}")), false),
        };
        // Admin commands dispatch before routing: a load targets a name
        // that is *not yet* registered, so resolving first would bounce
        // it with an unknown-model error. Only the strict admin shape
        // (reserved keys exclusively) short-circuits — anything else
        // falls through to normal routing and fails loudly there.
        if let Some(cmd @ ("load" | "swap" | "unload")) =
            request.get("cmd").and_then(|c| c.as_str())
        {
            let reserved_only = matches!(&request, Json::Obj(m)
                if m.keys().all(|k| k == "cmd" || k == "model" || k == "path"));
            if reserved_only {
                return (self.admin(cmd, &request), false);
            }
        }
        // Routing (docs/serving.md): the top-level "model" string selects
        // the serving session. It is protocol-reserved in the canonical
        // {"rows": …} form and in command form, where the top level holds
        // protocol keys only; the bare single-row shorthand is entirely
        // feature keys and always addresses the default model.
        let in_protocol_form =
            request.get("rows").is_some() || request.get("cmd").is_some();
        let routed: Option<&str> = match request.get("model") {
            Some(Json::Str(m)) if in_protocol_form => Some(m.as_str()),
            Some(other) if in_protocol_form => {
                return (
                    self.error_default(format!(
                        "\"model\" must be a string naming a registered model \
                         ({}), got {other}",
                        self.registry.names().join(", ")
                    )),
                    false,
                )
            }
            _ => None,
        };
        let entry = match self.registry.resolve(routed) {
            Ok(x) => x,
            // Unknown model: a clean in-band error reply naming the
            // registered models — never a dropped connection. A model
            // mid-drain after swap/unload lands here too: it is no
            // longer routable the instant the registry changed.
            Err(e) => return (self.error_default(e), false),
        };
        let session = entry.session();
        // Dispatch precedence (docs/serving.md): "cmd"-as-string is a
        // command, "rows"-as-array is a batch request. A model feature
        // that happens to be named "cmd" or "rows" is still reachable —
        // through the canonical {"rows": […]} form, or (for "cmd") via a
        // multi-key shorthand object — the names are only reserved at the
        // top level of the shorthand.
        if let Some(cmd) = request.get("cmd").and_then(|c| c.as_str()) {
            let reserved_only = matches!(&request, Json::Obj(m)
                if m.keys().all(|k| k == "cmd" || k == "model"));
            if reserved_only || !session.has_column("cmd") {
                return self.command(cmd, &entry);
            }
        }
        let rows: Vec<&Json> = match request.get("rows") {
            Some(Json::Arr(items)) => items.iter().collect(),
            Some(other) if !session.has_column("rows") => {
                return (
                    self.error(
                        &entry,
                        format!("\"rows\" must be an array of feature objects, got {other}"),
                    ),
                    false,
                )
            }
            // Single-row shorthand: the object itself is the row (also the
            // path for a non-array "rows" value when the model really has
            // a feature of that name).
            _ => {
                // A "model" key in the shorthand is almost always a
                // routing attempt; unless it is genuinely a feature of the
                // default model, answer with the canonical form instead of
                // a confusing unknown-feature error.
                if let Some(Json::Str(m)) = request.get("model") {
                    if !session.has_column("model") {
                        return (
                            self.error(
                                &entry,
                                format!(
                                    "the single-row shorthand always addresses the default \
                                     model; to route to '{m}', use \
                                     {{\"model\": \"{m}\", \"rows\": [{{…}}]}}"
                                ),
                            ),
                            false,
                        );
                    }
                }
                vec![&request]
            }
        };
        if rows.is_empty() {
            return (self.error(&entry, "request contains no rows".to_string()), false);
        }
        // Scratch is keyed by entry *generation*: a hot swap of this
        // model name must never decode into a block shaped for the old
        // dataspec.
        if blocks.len() >= MAX_SCRATCH_BLOCKS && !blocks.contains_key(&entry.generation()) {
            blocks.clear();
        }
        let block = blocks
            .entry(entry.generation())
            .or_insert_with(|| session.new_block());
        block.clear();
        // Lifecycle spans (decode → wait): error paths drop the start
        // token unrecorded, so a failed request traces only its outer
        // "request" span.
        let t_decode = crate::obs::trace::begin();
        for row in rows {
            if let Err(e) = session.decode_row(block, row) {
                return (self.error(&entry, e), false);
            }
        }
        let n = block.rows();
        crate::obs::trace::end(t_decode, "decode", || {
            vec![("rows", crate::obs::trace::ArgValue::U64(n as u64))]
        });
        let pending = match entry.batcher().submit(block) {
            Ok(p) => p,
            // Rejections (full queue, quota, admission budget) are
            // additionally counted in the `rejected` counter by the
            // batcher; every error response increments `errors`.
            Err(e) => return (self.error(&entry, e.to_string()), false),
        };
        let t_wait = crate::obs::trace::begin();
        let flat = match pending.wait() {
            Ok(f) => f,
            Err(ScoreError::Shed { waited_ms, retry_after_ms }) => {
                // Shed by the queue deadline: retryable by contract, and
                // the hint tells a well-behaved client when.
                let mut j = self.error(
                    &entry,
                    format!(
                        "request shed: queued {waited_ms} ms without being scored \
                         (queue deadline exceeded); retry in {retry_after_ms} ms"
                    ),
                );
                j.set("retryable", Json::Bool(true))
                    .set("retry_after_ms", Json::Num(retry_after_ms as f64));
                return (j, false);
            }
            Err(e) => return (self.error(&entry, e.to_string()), false),
        };
        crate::obs::trace::end(t_wait, "wait", || {
            vec![("rows", crate::obs::trace::ArgValue::U64(n as u64))]
        });
        let dim = session.output_dim();
        let predictions = Json::Arr(
            flat.chunks(dim)
                .map(|row| Json::Arr(row.iter().map(|&p| Json::Num(p)).collect()))
                .collect(),
        );
        let mut resp = Json::obj();
        resp.set("predictions", predictions)
            .set("model", Json::Str(entry.name().to_string()));
        entry.stats().note_request(n, t0.elapsed().as_secs_f64() * 1e6);
        (resp, false)
    }

    /// Control-plane commands: load/swap build the session on *this*
    /// worker with no registry lock held (scoring never pauses), then
    /// atomically install it.
    fn admin(&self, cmd: &str, request: &Json) -> Json {
        let Some(name) = request.get("model").and_then(|m| m.as_str()) else {
            return self.error_default(format!(
                "'{cmd}' needs a \"model\" field naming the target model"
            ));
        };
        let result = match cmd {
            "unload" => self.registry.unload(name),
            _ => {
                let Some(path) = request.get("path").and_then(|p| p.as_str()) else {
                    return self.error_default(format!(
                        "'{cmd}' needs a \"path\" field: a model file on the server's \
                         filesystem"
                    ));
                };
                match self.registry.begin_load(name, cmd == "swap") {
                    Err(e) => Err(e),
                    Ok(ticket) => match Session::open_with(std::path::Path::new(path), self.calibrate)
                    {
                        Ok(session) => self.registry.complete_load(ticket, session),
                        Err(e) => {
                            self.registry.fail_load(ticket);
                            Err(format!("cannot {cmd} model '{name}': {e}"))
                        }
                    },
                }
            }
        };
        match result {
            Ok(generation) => {
                let mut j = Json::obj();
                j.set("ok", Json::Bool(true))
                    .set("cmd", Json::Str(cmd.to_string()))
                    .set("model", Json::Str(name.to_string()))
                    .set("generation", Json::Num(generation as f64));
                j
            }
            Err(e) => self.error_default(e),
        }
    }

    fn command(&self, cmd: &str, entry: &ModelEntry) -> (Json, bool) {
        match cmd {
            "health" => {
                let mut j = Json::obj();
                j.set("ok", Json::Bool(true))
                    .set("model", Json::Str(entry.name().to_string()))
                    .set(
                        "models",
                        Json::Arr(
                            self.registry
                                .names()
                                .into_iter()
                                .map(Json::Str)
                                .collect(),
                        ),
                    )
                    .set("states", self.registry.states_json())
                    .set("transitions", self.registry.transitions_json())
                    .set("engine", Json::Str(entry.session().engine_name()))
                    .set("router", entry.session().router_json())
                    .set(
                        "model_type",
                        Json::Str(entry.session().model().model_type().to_string()),
                    )
                    .set("output_dim", Json::Num(entry.session().output_dim() as f64));
                (j, false)
            }
            "spec" => {
                let mut j = entry.session().spec_json();
                j.set("model", Json::Str(entry.name().to_string()));
                (j, false)
            }
            "stats" => (self.registry.stats_json(), false),
            "metrics" => {
                // Prometheus exposition as one JSON string: the wire
                // protocol is line-delimited JSON, so the multi-line text
                // rides in a field; a scrape bridge unwraps "metrics".
                let mut j = Json::obj();
                j.set(
                    "content_type",
                    Json::Str("text/plain; version=0.0.4".to_string()),
                )
                .set("metrics", Json::Str(self.registry.prometheus()));
                (j, false)
            }
            "shutdown" => {
                let mut j = Json::obj();
                j.set("ok", Json::Bool(true));
                (j, true)
            }
            other => (
                self.error(
                    entry,
                    format!(
                        "unknown command '{other}' (known: health, spec, stats, metrics, \
                         shutdown, load, swap, unload)"
                    ),
                ),
                false,
            ),
        }
    }

    /// Error reply counted against `entry`'s stats.
    fn error(&self, entry: &ModelEntry, message: String) -> Json {
        entry.stats().note_error();
        let mut j = Json::obj();
        j.set("error", Json::Str(message));
        j
    }

    /// Error reply for requests that never resolved to a model (parse
    /// failures, unknown model names, admin failures): counted against
    /// the default model.
    fn error_default(&self, message: String) -> Json {
        self.error(&self.registry.default_entry(), message)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};
    use crate::serving::session::Session;
    use crate::serving::BatcherConfig;

    fn test_session(seed: u64, trees: usize) -> Session {
        let ds = synthetic::adult_like(200, seed);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = trees;
        cfg.max_depth = 3;
        Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
    }

    fn two_model_conn() -> (Connection, Arc<Registry>) {
        let registry = Registry::new(BatcherConfig {
            max_delay: Duration::ZERO,
            ..Default::default()
        });
        registry.register("a", test_session(7, 3)).unwrap();
        registry.register("b", test_session(8, 5)).unwrap();
        let registry = Arc::new(registry);
        let conn = Connection {
            registry: Arc::clone(&registry),
            shutdown: Arc::new(AtomicBool::new(false)),
            wake_addr: "127.0.0.1:1".parse().unwrap(),
            max_line_bytes: 16 << 20,
            calibrate: CalibrateMode::Off,
            faults: None,
        };
        (conn, registry)
    }

    #[test]
    fn respond_handles_requests_commands_and_errors() {
        let (c, registry) = two_model_conn();
        let mut blocks: HashMap<u64, RowBlock> = HashMap::new();

        // Multi-row request (default model: "a").
        let (resp, stop) = c.respond(
            r#"{"rows": [{"age": 30}, {"age": 60, "education": "Doctorate"}]}"#,
            &mut blocks,
        );
        assert!(!stop);
        assert_eq!(resp.req_arr("predictions").unwrap().len(), 2);
        assert_eq!(resp.req_str("model").unwrap(), "a");

        // Routed request.
        let (resp, _) = c.respond(r#"{"model": "b", "rows": [{"age": 41}]}"#, &mut blocks);
        assert_eq!(resp.req_arr("predictions").unwrap().len(), 1);
        assert_eq!(resp.req_str("model").unwrap(), "b");

        // Single-row shorthand goes to the default model.
        let (resp, _) = c.respond(r#"{"age": 41}"#, &mut blocks);
        assert_eq!(resp.req_str("model").unwrap(), "a");

        // Unknown model: clean error naming the registry.
        let (resp, _) = c.respond(r#"{"model": "zzz", "rows": [{"age": 4}]}"#, &mut blocks);
        let err = resp.req_str("error").unwrap();
        assert!(err.contains("zzz") && err.contains("a, b"), "{err}");

        // Non-string "model" in protocol form.
        let (resp, _) = c.respond(r#"{"model": 5, "rows": [{"age": 4}]}"#, &mut blocks);
        assert!(resp.req_str("error").unwrap().contains("must be a string"));

        // Shorthand routing attempt gets the canonical-form hint.
        let (resp, _) = c.respond(r#"{"model": "b", "age": 30}"#, &mut blocks);
        let err = resp.req_str("error").unwrap();
        assert!(err.contains("shorthand") && err.contains("rows"), "{err}");

        // Malformed JSON and unknown features answer with errors, in-band.
        let (resp, _) = c.respond("not json at all", &mut blocks);
        assert!(resp.req_str("error").unwrap().contains("invalid JSON"));
        let (resp, _) = c.respond(r#"{"bogus_feature": 1}"#, &mut blocks);
        assert!(resp.req_str("error").unwrap().contains("bogus_feature"));
        let (resp, _) = c.respond(r#"{"rows": []}"#, &mut blocks);
        assert!(resp.req_str("error").unwrap().contains("no rows"));
        let (resp, _) = c.respond(r#"{"rows": 5}"#, &mut blocks);
        assert!(resp.req_str("error").unwrap().contains("array"));

        // Commands; "model" routes health/spec.
        let (resp, _) = c.respond(r#"{"cmd": "health"}"#, &mut blocks);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.req_str("model").unwrap(), "a");
        assert_eq!(resp.req_arr("models").unwrap().len(), 2);
        assert_eq!(resp.req("states").unwrap().req_str("a").unwrap(), "Serving");
        assert_eq!(resp.req("states").unwrap().req_str("b").unwrap(), "Serving");
        let (resp, _) = c.respond(r#"{"cmd": "spec", "model": "b"}"#, &mut blocks);
        assert_eq!(resp.req_str("label").unwrap(), "income");
        assert_eq!(resp.req_str("model").unwrap(), "b");
        let (resp, _) = c.respond(r#"{"cmd": "dance"}"#, &mut blocks);
        assert!(resp.req_str("error").unwrap().contains("unknown command"));
        let (resp, stop) = c.respond(r#"{"cmd": "shutdown"}"#, &mut blocks);
        assert!(stop);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));

        // Per-model stats: "a" answered 2 requests + the parse/decode
        // errors attributed to the default model; "b" answered 1.
        let (resp, _) = c.respond(r#"{"cmd": "stats"}"#, &mut blocks);
        assert!(resp.req_f64("requests").unwrap() >= 3.0);
        let models = resp.req("models").unwrap();
        assert_eq!(models.req("a").unwrap().req_f64("requests").unwrap(), 2.0);
        assert_eq!(models.req("b").unwrap().req_f64("requests").unwrap(), 1.0);
        assert!(models.req("a").unwrap().req_f64("errors").unwrap() >= 5.0);
        assert_eq!(registry.get("b").unwrap().stats().snapshot().errors, 0);
    }

    #[test]
    fn admin_load_swap_unload_round_trip_over_respond() {
        let (c, registry) = two_model_conn();
        let mut blocks: HashMap<u64, RowBlock> = HashMap::new();
        let dir = std::env::temp_dir().join(format!(
            "ydf_admin_test_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ydf.json");
        let incoming = test_session(42, 7);
        crate::model::io::save_model(incoming.model(), &path).unwrap();
        let path_str = path.to_str().unwrap();

        // load: a third model appears and serves.
        let (resp, stop) =
            c.respond(&format!(r#"{{"cmd": "load", "model": "c", "path": "{path_str}"}}"#), &mut blocks);
        assert!(!stop);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let gen_load = resp.req_f64("generation").unwrap();
        let (resp, _) = c.respond(r#"{"model": "c", "rows": [{"age": 50}]}"#, &mut blocks);
        assert_eq!(resp.req_str("model").unwrap(), "c");

        // swap: same name, new generation; predictions switch to the new
        // session's (model "c" file scored through name "b").
        let before = c.respond(r#"{"model": "b", "rows": [{"age": 50}]}"#, &mut blocks).0;
        let (resp, _) =
            c.respond(&format!(r#"{{"cmd": "swap", "model": "b", "path": "{path_str}"}}"#), &mut blocks);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert!(resp.req_f64("generation").unwrap() > gen_load);
        let after = c.respond(r#"{"model": "b", "rows": [{"age": 50}]}"#, &mut blocks).0;
        assert_ne!(
            before.req_arr("predictions").unwrap(),
            after.req_arr("predictions").unwrap()
        );

        // Admin errors are in-band: bad path fails the load, the name
        // stays free, and the failure lands in the transition log.
        let (resp, _) = c.respond(
            r#"{"cmd": "load", "model": "d", "path": "/nonexistent/nope.json"}"#,
            &mut blocks,
        );
        assert!(resp.req_str("error").unwrap().contains("cannot load"), "{resp}");
        let (resp, _) = c.respond(r#"{"cmd": "health"}"#, &mut blocks);
        assert!(resp.to_string().contains("Failed"), "{resp}");
        // Missing fields are named.
        let (resp, _) = c.respond(r#"{"cmd": "swap", "model": "b"}"#, &mut blocks);
        assert!(resp.req_str("error").unwrap().contains("path"));
        let (resp, _) = c.respond(r#"{"cmd": "unload"}"#, &mut blocks);
        assert!(resp.req_str("error").unwrap().contains("model"));

        // unload: "c" disappears from routing.
        let (resp, _) = c.respond(r#"{"cmd": "unload", "model": "c"}"#, &mut blocks);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let (resp, _) = c.respond(r#"{"model": "c", "rows": [{"age": 50}]}"#, &mut blocks);
        assert!(resp.req_str("error").unwrap().contains("unknown model"));
        assert_eq!(registry.names(), vec!["a", "b"]);

        std::fs::remove_dir_all(&dir).ok();
    }
}
