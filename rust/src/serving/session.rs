//! Serving session: a loaded model pinned to its engine routing table
//! (one engine per batch-size bucket, measured or static — see
//! [`crate::inference::router`]), plus dataspec-driven request decoding.
//!
//! Incoming requests name features by column name; the session maps names
//! to dataspec columns once at construction and materializes rows
//! directly into columnar [`ColumnData`] storage (a [`RowBlock`]) — no
//! intermediate `Observation`, no per-request dataspec scan. Blocks are
//! scratch: callers `clear()` and refill them across requests, so the
//! steady-state decode loop reuses its column and staging allocations
//! (categorical-set rows aside, which own their token lists).

use crate::dataset::{ColumnData, DataSpec, Dataset, FeatureSemantic, MISSING_BOOL, MISSING_CAT};
use crate::inference::router::{CalibrateMode, Router};
use crate::inference::{InferenceEngine, BLOCK_SIZE};
use crate::model::Model;
use crate::utils::json::Json;
use crate::utils::pool::WorkerPool;
use std::collections::HashMap;
use std::path::Path;

/// Columnar decode scratch: one growing column per dataspec column.
/// Obtained from [`Session::new_block`]; reused across requests via
/// [`RowBlock::clear`]. Internally this *is* a [`Dataset`] whose columns
/// are mutated in place, so the engine batch path consumes it directly.
pub struct RowBlock {
    ds: Dataset,
    rows: usize,
    /// Per-row decode staging, reused across calls so a mid-row decode
    /// error never leaves the columns at uneven lengths — and so the
    /// steady-state decode loop performs no per-row allocation.
    staged: Vec<DecodedValue>,
}

impl RowBlock {
    /// Number of decoded rows currently in the block.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Removes all rows, keeping allocations for reuse.
    pub fn clear(&mut self) {
        for c in &mut self.ds.columns {
            c.clear();
        }
        self.rows = 0;
        self.ds.sync_num_rows().expect("cleared columns are even");
    }

    /// Appends every row of `other` (the batcher's coalescing step).
    pub fn append_from(&mut self, other: &RowBlock) {
        for (dst, src) in self.ds.columns.iter_mut().zip(&other.ds.columns) {
            dst.extend_from(src).expect("blocks from the same session share semantics");
        }
        self.rows += other.rows;
    }

    /// Appends `len` rows of `other` starting at row `start`: the
    /// batcher's deadline-shed pass re-packs a flush's surviving rows into
    /// a fresh block without re-decoding the original requests.
    pub fn append_rows(&mut self, other: &RowBlock, start: usize, len: usize) {
        debug_assert!(start + len <= other.rows);
        for (dst, src) in self.ds.columns.iter_mut().zip(&other.ds.columns) {
            dst.extend_from_range(src, start, start + len)
                .expect("blocks from the same session share semantics");
        }
        self.rows += len;
    }

    /// The block as a columnar dataset, row count synced. Only valid until
    /// the next mutation. Public so tests can pin the decode layer against
    /// independently built columnar ground truth.
    pub fn dataset(&mut self) -> &Dataset {
        let n = self.ds.sync_num_rows().expect("decode pushed one value per column per row");
        debug_assert_eq!(n, self.rows);
        &self.ds
    }
}

/// A loaded model pinned to its engine routing table, ready to decode
/// and score requests. Shared across connection handlers and the
/// batcher behind an `Arc`.
pub struct Session {
    model: Box<dyn Model>,
    /// Per-batch-size-bucket engine routes; `None` for wrapper models,
    /// which fall back to the model's own row loop.
    router: Option<Router>,
    col_by_name: HashMap<String, usize>,
    dim: usize,
    /// Empty columnar prototype cloned by [`Session::new_block`].
    prototype: Dataset,
}

impl Session {
    /// Pins `model` to the static engine order (compiled for artifacts,
    /// else QuickScorer → flat SoA → the model's own row loop) — the
    /// same selection `predict_flat` makes for offline batches. No
    /// calibration pass runs; use [`Session::new_calibrated`] or
    /// [`Session::open_with`] for measured per-bucket routing.
    pub fn new(model: Box<dyn Model>) -> Session {
        let router = Router::uncalibrated(model.as_ref());
        Session::assemble(model, router)
    }

    /// As [`Session::new`], but running the router's micro-calibration
    /// pass in memory so every batch-size bucket pins its measured
    /// winner. No table file is read or written — file-backed callers
    /// use [`Session::open_with`], which caches the measurement next to
    /// the model.
    pub fn new_calibrated(model: Box<dyn Model>) -> Session {
        let router = Router::calibrated_in_memory(
            model.as_ref(),
            crate::inference::router::DEFAULT_SEED,
        );
        Session::assemble(model, router)
    }

    fn assemble(model: Box<dyn Model>, router: Option<Router>) -> Session {
        let spec = model.spec();
        let col_by_name: HashMap<String, usize> = spec
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| (c.name.clone(), i))
            .collect();
        let prototype = empty_like(spec);
        let dim = router
            .as_ref()
            .map(|r| r.output_dim())
            .unwrap_or_else(|| model.num_classes().max(1));
        Session { model, router, col_by_name, dim, prototype }
    }

    /// Loads a model file and opens a session on it with
    /// [`CalibrateMode::Load`] semantics: a valid cached calibration
    /// table next to the model routes; a missing one is measured and
    /// cached; a corrupt or stale one falls back to the static order.
    pub fn open(path: &Path) -> Result<Session, String> {
        Session::open_with(path, CalibrateMode::Load)
    }

    /// Loads a model file and opens a session with an explicit router
    /// calibration mode (`ydf serve --calibrate=off|load|force`). See
    /// [`crate::inference::router::for_model_file`] for the exact
    /// policy; no mode can fail the open — every router failure path
    /// degrades to the static engine order.
    pub fn open_with(path: &Path, mode: CalibrateMode) -> Result<Session, String> {
        let model = crate::model::io::load_model(path)?;
        let router = crate::inference::router::for_model_file(model.as_ref(), path, mode);
        Ok(Session::assemble(model, router))
    }

    /// Values per prediction (class count, or 1 for regression).
    pub fn output_dim(&self) -> usize {
        self.dim
    }

    /// Label dictionary for classification models (empty for regression).
    pub fn class_names(&self) -> Vec<String> {
        self.model.class_names()
    }

    pub fn model(&self) -> &dyn Model {
        self.model.as_ref()
    }

    /// Name of the engine scoring this session's workhorse flushes (one
    /// [`BLOCK_SIZE`] block) — what `health` and startup banners report
    /// as *the* session engine. Other flush sizes may route elsewhere;
    /// see [`Session::engine_name_for_rows`].
    pub fn engine_name(&self) -> String {
        self.router
            .as_ref()
            .map(|r| r.primary_name().to_string())
            .unwrap_or_else(|| "model row loop (no engine compiled)".to_string())
    }

    /// Name of the engine a `rows`-row flush routes to; the batcher
    /// labels its per-flush telemetry with this.
    pub fn engine_name_for_rows(&self, rows: usize) -> String {
        self.router
            .as_ref()
            .map(|r| r.engine_name_for_rows(rows).to_string())
            .unwrap_or_else(|| "model row loop (no engine compiled)".to_string())
    }

    /// Whether the session's routes were measured by a calibration pass
    /// (vs the static fallback order).
    pub fn router_calibrated(&self) -> bool {
        self.router.as_ref().map(|r| r.calibrated()).unwrap_or(false)
    }

    /// Router summary for `health`: per-bucket engine tags plus whether
    /// the table was measured or static.
    pub fn router_json(&self) -> Json {
        match &self.router {
            Some(r) => r.to_json(),
            None => {
                let mut j = Json::obj();
                j.set("calibrated", Json::Bool(false)).set("buckets", Json::obj());
                j
            }
        }
    }

    /// Fresh columnar decode scratch matching the model's dataspec.
    pub fn new_block(&self) -> RowBlock {
        RowBlock { ds: self.prototype.clone(), rows: 0, staged: Vec::new() }
    }

    /// Whether the model's dataspec has a column of this name (the server
    /// uses it to resolve "cmd"/"rows" name collisions in the protocol).
    pub fn has_column(&self, name: &str) -> bool {
        self.col_by_name.contains_key(name)
    }

    /// The request-facing feature description: every non-label column's
    /// name, semantic and (for categoricals) dictionary — what a client
    /// needs to build well-formed rows.
    pub fn spec_json(&self) -> Json {
        let spec = self.model.spec();
        let label_col = self.model.label_col();
        let features = spec
            .columns
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != label_col)
            .map(|(_, c)| {
                let mut f = Json::obj();
                f.set("name", Json::Str(c.name.clone()))
                    .set("semantic", Json::Str(c.semantic.name().to_string()));
                if !c.dictionary.is_empty() {
                    f.set(
                        "dictionary",
                        Json::Arr(c.dictionary.iter().map(|d| Json::Str(d.clone())).collect()),
                    );
                }
                f
            })
            .collect();
        let mut j = Json::obj();
        j.set("features", Json::Arr(features))
            .set("label", Json::Str(spec.columns[label_col].name.clone()))
            .set(
                "classes",
                Json::Arr(self.class_names().into_iter().map(Json::Str).collect()),
            );
        j
    }

    /// Decodes one JSON object (`{"feature_name": value, …}`) into the
    /// block. Absent or `null` features are missing; unknown feature names
    /// — including the model's label, which is an output, not an input —
    /// are an error naming the offender (§2.1: misconfiguration reports
    /// what is wrong, not garbage predictions). On error the block is left
    /// unchanged.
    pub fn decode_row(&self, block: &mut RowBlock, row: &Json) -> Result<(), String> {
        let obj = match row {
            Json::Obj(m) => m,
            _ => return Err("each row must be a JSON object of feature_name: value".to_string()),
        };
        let spec = self.model.spec();
        let label_name = &spec.columns[self.model.label_col()].name;
        for key in obj.keys() {
            if key == label_name {
                return Err(format!(
                    "'{key}' is the model's label — an output, not an input feature; \
                     remove it from the request."
                ));
            }
            if !self.col_by_name.contains_key(key) {
                return Err(format!(
                    "unknown feature '{key}'. The model's features are: {}.",
                    self.feature_names().join(", ")
                ));
            }
        }
        // Stage the full row before touching the columns, so a mid-row
        // error cannot leave them at uneven lengths. The staging buffer
        // lives in the block and is reused across calls.
        block.staged.clear();
        for col in &spec.columns {
            block.staged.push(decode_value(col.name.as_str(), col, obj.get(&col.name))?);
        }
        for (c, v) in block.ds.columns.iter_mut().zip(block.staged.drain(..)) {
            v.push_into(c);
        }
        block.rows += 1;
        Ok(())
    }

    /// Non-label feature names, in dataspec order.
    pub fn feature_names(&self) -> Vec<String> {
        let label_col = self.model.label_col();
        self.model
            .spec()
            .columns
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != label_col)
            .map(|(_, c)| c.name.clone())
            .collect()
    }

    /// Scores every row of the block through the engine its row count
    /// routes to (or the model row loop for wrapper models) into a fresh
    /// row-major buffer of `rows * output_dim()` values. Single-threaded:
    /// the whole block is one `predict_batch` call. The batcher's flush
    /// path is [`Session::predict_block_pooled`], which this delegates to.
    pub fn predict_block(&self, block: &mut RowBlock) -> Vec<f64> {
        self.predict_block_pooled(block, None)
    }

    /// As [`Session::predict_block`], but when a scoring pool is provided
    /// and the block spans more than one [`BLOCK_SIZE`] kernel block, the
    /// [`crate::inference::block_spans`] partition is scattered across the
    /// pool's workers with index-disjoint output slices — the
    /// `predict_into` contract, but over persistent `utils/pool.rs`
    /// workers so a large coalesced flush does not score on one thread
    /// (and does not pay per-flush thread spawns). Engines are
    /// row-independent and spans are block-aligned, so the output is
    /// bit-identical to the single-call path.
    pub fn predict_block_pooled(
        &self,
        block: &mut RowBlock,
        pool: Option<&WorkerPool>,
    ) -> Vec<f64> {
        let n = block.rows;
        let dim = self.dim;
        let mut out = vec![0.0f64; n * dim];
        if n == 0 {
            return out;
        }
        let ds = block.dataset();
        match &self.router {
            Some(router) => {
                // The routing decision: the flush's actual row count
                // picks the bucket, the bucket picks the engine (and
                // feeds ydf_router_decisions_total). All candidate
                // engines are bit-identical, so this only changes speed.
                let e: &dyn InferenceEngine = router.route(n);
                let spans = match pool {
                    Some(p) if p.num_workers() > 1 && n > BLOCK_SIZE => {
                        crate::inference::block_spans(n, p.num_workers())
                    }
                    _ => Vec::new(),
                };
                if spans.len() > 1 {
                    let pool = pool.expect("spans are only computed when a pool is present");
                    let engine = e;
                    let mut jobs = Vec::with_capacity(spans.len());
                    let mut rest: &mut [f64] = &mut out;
                    for span in spans {
                        let (head, tail) = std::mem::take(&mut rest)
                            .split_at_mut((span.end - span.start) * dim);
                        rest = tail;
                        jobs.push(move || engine.predict_batch(ds, span, head));
                    }
                    pool.run_scoped(jobs);
                } else {
                    e.predict_batch(ds, 0..n, &mut out);
                }
            }
            None => {
                for r in 0..n {
                    out[r * dim..(r + 1) * dim]
                        .copy_from_slice(&self.model.predict_ds_row(ds, r));
                }
            }
        }
        out
    }
}

/// One decoded attribute value, staged before being pushed columnar.
enum DecodedValue {
    Num(f32),
    Cat(u32),
    Bool(u8),
    Set(Vec<u32>),
}

impl DecodedValue {
    fn push_into(self, col: &mut ColumnData) {
        match (self, col) {
            (DecodedValue::Num(x), ColumnData::Numerical(v)) => v.push(x),
            (DecodedValue::Cat(x), ColumnData::Categorical(v)) => v.push(x),
            (DecodedValue::Bool(x), ColumnData::Boolean(v)) => v.push(x),
            (DecodedValue::Set(xs), ColumnData::CategoricalSet { offsets, values }) => {
                values.extend_from_slice(&xs);
                offsets.push(values.len() as u32);
            }
            _ => unreachable!("decode_value matches the column semantic"),
        }
    }
}

fn empty_like(spec: &DataSpec) -> Dataset {
    let columns = spec
        .columns
        .iter()
        .map(|c| match c.semantic {
            FeatureSemantic::Numerical => ColumnData::Numerical(Vec::new()),
            FeatureSemantic::Categorical => ColumnData::Categorical(Vec::new()),
            FeatureSemantic::Boolean => ColumnData::Boolean(Vec::new()),
            FeatureSemantic::CategoricalSet => {
                ColumnData::CategoricalSet { offsets: vec![0], values: Vec::new() }
            }
        })
        .collect();
    Dataset::new(spec.clone(), columns).expect("empty columns match their spec")
}

/// Formats a JSON number the way the dataspec dictionaries store numeric
/// category names ("1", not "1.0").
fn num_to_category(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn decode_value(
    name: &str,
    col: &crate::dataset::ColumnSpec,
    value: Option<&Json>,
) -> Result<DecodedValue, String> {
    let missing = matches!(value, None | Some(Json::Null));
    match col.semantic {
        FeatureSemantic::Numerical => {
            if missing {
                return Ok(DecodedValue::Num(f32::NAN));
            }
            match value.unwrap() {
                Json::Num(x) => Ok(DecodedValue::Num(*x as f32)),
                Json::Str(s) => s.trim().parse::<f32>().map(DecodedValue::Num).map_err(|_| {
                    format!(
                        "feature '{name}' is NUMERICAL but \"{s}\" does not parse as a number."
                    )
                }),
                other => Err(format!(
                    "feature '{name}' is NUMERICAL but the request holds {other} (expected a \
                     number, a numeric string, or null for missing)."
                )),
            }
        }
        FeatureSemantic::Categorical => {
            if missing {
                return Ok(DecodedValue::Cat(MISSING_CAT));
            }
            let index = match value.unwrap() {
                Json::Str(s) => col.category_index(s),
                Json::Num(x) => col.category_index(&num_to_category(*x)),
                Json::Bool(b) => col.category_index(if *b { "true" } else { "false" }),
                other => {
                    return Err(format!(
                        "feature '{name}' is CATEGORICAL but the request holds {other} \
                         (expected a string category or null for missing)."
                    ))
                }
            };
            // Out-of-dictionary categories map to missing, mirroring
            // dataspec encoding of OOD values at training time.
            Ok(DecodedValue::Cat(index.unwrap_or(MISSING_CAT)))
        }
        FeatureSemantic::Boolean => {
            if missing {
                return Ok(DecodedValue::Bool(MISSING_BOOL));
            }
            match value.unwrap() {
                Json::Bool(b) => Ok(DecodedValue::Bool(*b as u8)),
                Json::Num(x) if *x == 0.0 || *x == 1.0 => Ok(DecodedValue::Bool(*x as u8)),
                Json::Str(s) => match s.trim().to_ascii_lowercase().as_str() {
                    "true" | "1" => Ok(DecodedValue::Bool(1)),
                    "false" | "0" => Ok(DecodedValue::Bool(0)),
                    _ => Err(format!(
                        "feature '{name}' is BOOLEAN but the request holds \"{s}\"."
                    )),
                },
                other => Err(format!(
                    "feature '{name}' is BOOLEAN but the request holds {other} (expected \
                     true/false, 0/1, or null for missing)."
                )),
            }
        }
        FeatureSemantic::CategoricalSet => {
            if missing {
                // Sentinel single-element MISSING_CAT set = missing
                // (distinct from an empty set), as in the dataset layer.
                return Ok(DecodedValue::Set(vec![MISSING_CAT]));
            }
            // Unknown tokens are dropped, as in dataspec encoding.
            let codes: Vec<u32> = match value.unwrap() {
                Json::Arr(items) => {
                    let mut codes = Vec::with_capacity(items.len());
                    for it in items {
                        match it {
                            Json::Str(s) => codes.extend(col.category_index(s)),
                            other => {
                                return Err(format!(
                                    "feature '{name}' is CATEGORICAL_SET; array items \
                                     must be strings, got {other}."
                                ))
                            }
                        }
                    }
                    codes
                }
                Json::Str(s) => s.split_whitespace().filter_map(|t| col.category_index(t)).collect(),
                other => {
                    return Err(format!(
                        "feature '{name}' is CATEGORICAL_SET but the request holds {other} \
                         (expected an array of strings, a whitespace-separated string, or \
                         null for missing)."
                    ))
                }
            };
            Ok(DecodedValue::Set(codes))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};

    fn session() -> Session {
        let ds = synthetic::adult_like(300, 2024);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 5;
        cfg.max_depth = 4;
        Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
    }

    #[test]
    fn decode_matches_dataset_row() {
        let s = session();
        let mut block = s.new_block();
        let row = Json::parse(
            r#"{"age": 44, "fnlwgt": 120000, "workclass": "Private",
                "education": "Masters", "occupation": "Exec-managerial",
                "marital_status": "Never-married", "hours_per_week": 45,
                "capital_gain": 0}"#,
        )
        .unwrap();
        s.decode_row(&mut block, &row).unwrap();
        assert_eq!(block.rows(), 1);
        let out = s.predict_block(&mut block);
        assert_eq!(out.len(), s.output_dim());
        assert!((out.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{out:?}");
    }

    #[test]
    fn missing_and_null_features_decode_as_missing() {
        let s = session();
        let mut block = s.new_block();
        let row = Json::parse(r#"{"age": null, "workclass": "Private"}"#).unwrap();
        s.decode_row(&mut block, &row).unwrap();
        let ds = block.dataset();
        assert!(ds.column(0).is_missing(0)); // age -> NaN
        assert!(ds.column(4).is_missing(0)); // occupation absent -> MISSING_CAT
    }

    #[test]
    fn unknown_feature_is_an_error_naming_it() {
        let s = session();
        let mut block = s.new_block();
        let row = Json::parse(r#"{"agee": 44}"#).unwrap();
        let err = s.decode_row(&mut block, &row).unwrap_err();
        assert!(err.contains("agee"), "{err}");
        assert!(err.contains("age"), "{err}");
        assert_eq!(block.rows(), 0); // block untouched on error
    }

    #[test]
    fn type_mismatch_is_an_error_and_block_stays_even() {
        let s = session();
        let mut block = s.new_block();
        let good = Json::parse(r#"{"age": 30}"#).unwrap();
        s.decode_row(&mut block, &good).unwrap();
        let bad = Json::parse(r#"{"age": "not-a-number"}"#).unwrap();
        let err = s.decode_row(&mut block, &bad).unwrap_err();
        assert!(err.contains("NUMERICAL"), "{err}");
        assert_eq!(block.rows(), 1);
        // Block still scores after a failed decode.
        let out = s.predict_block(&mut block);
        assert_eq!(out.len(), s.output_dim());
    }

    #[test]
    fn label_in_request_is_rejected() {
        let s = session();
        let mut block = s.new_block();
        let row = Json::parse(r#"{"age": 30, "income": ">50K"}"#).unwrap();
        let err = s.decode_row(&mut block, &row).unwrap_err();
        assert!(err.contains("label"), "{err}");
        assert!(err.contains("income"), "{err}");
        assert_eq!(block.rows(), 0);
    }

    #[test]
    fn has_column_covers_all_spec_columns() {
        let s = session();
        assert!(s.has_column("age"));
        assert!(s.has_column("income")); // label is a column too
        assert!(!s.has_column("cmd"));
        assert!(!s.has_column("rows"));
    }

    #[test]
    fn ood_category_maps_to_missing() {
        let s = session();
        let mut block = s.new_block();
        let row = Json::parse(r#"{"workclass": "Space-tourism"}"#).unwrap();
        s.decode_row(&mut block, &row).unwrap();
        assert!(block.dataset().column(2).is_missing(0));
    }

    #[test]
    fn blocks_clear_and_append() {
        let s = session();
        let mut a = s.new_block();
        let mut b = s.new_block();
        let row = Json::parse(r#"{"age": 51, "education": "Doctorate"}"#).unwrap();
        s.decode_row(&mut a, &row).unwrap();
        s.decode_row(&mut b, &row).unwrap();
        s.decode_row(&mut b, &row).unwrap();
        a.append_from(&b);
        assert_eq!(a.rows(), 3);
        let out = s.predict_block(&mut a);
        assert_eq!(out.len(), 3 * s.output_dim());
        // All three rows are identical, so predictions must be too.
        let dim = s.output_dim();
        assert_eq!(out[..dim], out[dim..2 * dim]);
        a.clear();
        assert_eq!(a.rows(), 0);
        assert!(s.predict_block(&mut a).is_empty());
    }

    #[test]
    fn spec_json_lists_features_and_classes() {
        let s = session();
        let j = s.spec_json();
        let features = j.req_arr("features").unwrap();
        assert_eq!(features.len(), 8); // 9 columns minus the label
        assert_eq!(j.req_str("label").unwrap(), "income");
        assert_eq!(j.req_arr("classes").unwrap().len(), 2);
        assert!(features.iter().any(|f| f.req_str("name") == Ok("workclass")));
    }

    #[test]
    fn session_pins_an_optimized_engine_for_forests() {
        let s = session();
        let name = s.engine_name();
        assert!(
            name.contains("QuickScorer") || name.contains("OptPred"),
            "expected an optimized engine, got {name}"
        );
        // Static routing: every bucket reports the same engine, and the
        // health summary says so.
        assert!(!s.router_calibrated());
        for rows in crate::inference::router::BUCKETS {
            assert_eq!(s.engine_name_for_rows(rows), name);
        }
    }

    #[test]
    fn calibrated_session_bit_identical_to_static_across_buckets() {
        let train = || {
            let ds = synthetic::adult_like(300, 2024);
            let mut cfg = GbtConfig::new("income");
            cfg.num_trees = 5;
            cfg.max_depth = 4;
            GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()
        };
        // Training is deterministic, so the two sessions hold the same
        // forest; only the routing differs.
        let fixed = Session::new(train());
        let routed = Session::new_calibrated(train());
        assert!(routed.router_calibrated());
        let j = routed.router_json();
        assert_eq!(j.get("calibrated"), Some(&Json::Bool(true)));
        let row = Json::parse(r#"{"age": 44, "education": "Masters", "hours_per_week": 45}"#)
            .unwrap();
        for rows in [1usize, 9, 65, 200] {
            let mut a = fixed.new_block();
            let mut b = routed.new_block();
            for _ in 0..rows {
                fixed.decode_row(&mut a, &row).unwrap();
                routed.decode_row(&mut b, &row).unwrap();
            }
            let pa = fixed.predict_block(&mut a);
            let pb = routed.predict_block(&mut b);
            assert_eq!(pa.len(), pb.len());
            for (x, y) in pa.iter().zip(&pb) {
                assert_eq!(x.to_bits(), y.to_bits(), "routing changed output at {rows} rows");
            }
        }
    }
}
