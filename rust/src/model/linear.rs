//! Linear model (multinomial logistic regression over a dense encoding).
//!
//! This is the "TF Linear" baseline of the paper's benchmark (§5): numerical
//! features are standardized, categorical features one-hot encoded, booleans
//! 0/1; missing values impute to the global mean / all-zeros.

use super::{Model, SelfEvaluation, Task, VariableImportance};
use crate::dataset::{AttrValue, ColumnData, DataSpec, Dataset, FeatureSemantic, Observation};
use crate::utils::json::Json;
use crate::utils::stats::softmax_in_place;

/// Dense feature encoding shared between training and inference.
#[derive(Clone, Debug)]
pub struct DenseEncoding {
    /// For each source column: (column index, offset into dense vector,
    /// width). Label column excluded.
    pub slots: Vec<EncodingSlot>,
    pub dim: usize,
}

#[derive(Clone, Debug)]
pub struct EncodingSlot {
    pub col: usize,
    pub offset: usize,
    pub width: usize,
    /// Standardization for numerical slots.
    pub mean: f32,
    pub inv_std: f32,
}

impl DenseEncoding {
    /// Builds the encoding from a dataspec, excluding `label_col`.
    pub fn build(spec: &DataSpec, label_col: usize) -> DenseEncoding {
        let mut slots = Vec::new();
        let mut offset = 0usize;
        for (ci, c) in spec.columns.iter().enumerate() {
            if ci == label_col {
                continue;
            }
            let width = match c.semantic {
                FeatureSemantic::Numerical | FeatureSemantic::Boolean => 1,
                FeatureSemantic::Categorical | FeatureSemantic::CategoricalSet => {
                    c.vocab_size()
                }
            };
            let (mean, inv_std) = if c.semantic == FeatureSemantic::Numerical {
                let std = c.num_stats.std;
                (c.num_stats.mean as f32, if std > 1e-12 { 1.0 / std as f32 } else { 1.0 })
            } else {
                (0.0, 1.0)
            };
            slots.push(EncodingSlot { col: ci, offset, width, mean, inv_std });
            offset += width;
        }
        DenseEncoding { slots, dim: offset }
    }

    /// Encodes a dataset row into `out` (must be `dim` long, zeroed by this
    /// function).
    pub fn encode_ds(&self, spec: &DataSpec, ds: &Dataset, row: usize, out: &mut [f32]) {
        out.fill(0.0);
        for s in &self.slots {
            match &ds.columns[s.col] {
                ColumnData::Numerical(v) => {
                    let x = v[row];
                    // Missing -> standardized 0 (the global mean).
                    out[s.offset] = if x.is_nan() { 0.0 } else { (x - s.mean) * s.inv_std };
                }
                ColumnData::Categorical(v) => {
                    let c = v[row];
                    if c != crate::dataset::MISSING_CAT && (c as usize) < s.width {
                        out[s.offset + c as usize] = 1.0;
                    }
                }
                ColumnData::Boolean(v) => {
                    if v[row] == 1 {
                        out[s.offset] = 1.0;
                    }
                }
                col @ ColumnData::CategoricalSet { .. } => {
                    if !col.is_missing(row) {
                        for &t in col.set_values(row).unwrap() {
                            if (t as usize) < s.width {
                                out[s.offset + t as usize] = 1.0;
                            }
                        }
                    }
                }
            }
        }
        let _ = spec;
    }

    /// Encodes a row observation.
    pub fn encode_row(&self, obs: &Observation, out: &mut [f32]) {
        out.fill(0.0);
        for s in &self.slots {
            match &obs[s.col] {
                AttrValue::Num(x) if !x.is_nan() => {
                    out[s.offset] = (x - s.mean) * s.inv_std;
                }
                AttrValue::Cat(c) => {
                    if (*c as usize) < s.width {
                        out[s.offset + *c as usize] = 1.0;
                    }
                }
                AttrValue::Bool(b) => {
                    if *b {
                        out[s.offset] = 1.0;
                    }
                }
                AttrValue::CatSet(items) => {
                    for &t in items {
                        if (t as usize) < s.width {
                            out[s.offset + t as usize] = 1.0;
                        }
                    }
                }
                _ => {}
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let mut slots = Vec::new();
        for s in &self.slots {
            let mut j = Json::obj();
            j.set("col", Json::Num(s.col as f64))
                .set("offset", Json::Num(s.offset as f64))
                .set("width", Json::Num(s.width as f64))
                .set("mean", Json::Num(s.mean as f64))
                .set("inv_std", Json::Num(s.inv_std as f64));
            slots.push(j);
        }
        let mut j = Json::obj();
        j.set("slots", Json::Arr(slots)).set("dim", Json::Num(self.dim as f64));
        j
    }

    pub fn from_json(j: &Json) -> Result<DenseEncoding, String> {
        let slots = j
            .req_arr("slots")?
            .iter()
            .map(|sj| {
                Ok(EncodingSlot {
                    col: sj.req_usize("col")?,
                    offset: sj.req_usize("offset")?,
                    width: sj.req_usize("width")?,
                    mean: sj.req_f64("mean")? as f32,
                    inv_std: sj.req_f64("inv_std")? as f32,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(DenseEncoding { slots, dim: j.req_usize("dim")? })
    }
}

/// Multinomial logistic regression model.
#[derive(Clone)]
pub struct LinearModel {
    pub spec: DataSpec,
    pub label_col: usize,
    pub task: Task,
    pub encoding: DenseEncoding,
    /// `weights[k]` is the weight vector of class k (length `encoding.dim`).
    /// Regression uses a single output.
    pub weights: Vec<Vec<f32>>,
    pub bias: Vec<f32>,
    pub self_eval: Option<SelfEvaluation>,
}

impl LinearModel {
    fn scores(&self, dense: &[f32]) -> Vec<f64> {
        self.weights
            .iter()
            .zip(&self.bias)
            .map(|(w, &b)| {
                b as f64
                    + w.iter().zip(dense).map(|(&wi, &xi)| wi as f64 * xi as f64).sum::<f64>()
            })
            .collect()
    }

    fn finalize(&self, mut scores: Vec<f64>) -> Vec<f64> {
        if self.task == Task::Classification {
            softmax_in_place(&mut scores);
        }
        scores
    }
}

impl Model for LinearModel {
    fn model_type(&self) -> &'static str {
        "LINEAR"
    }
    fn task(&self) -> Task {
        self.task
    }
    fn spec(&self) -> &DataSpec {
        &self.spec
    }
    fn label_col(&self) -> usize {
        self.label_col
    }

    fn input_features(&self) -> Vec<usize> {
        self.encoding.slots.iter().map(|s| s.col).collect()
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        let mut dense = vec![0.0f32; self.encoding.dim];
        self.encoding.encode_row(obs, &mut dense);
        self.finalize(self.scores(&dense))
    }

    fn predict_ds_row(&self, ds: &Dataset, row: usize) -> Vec<f64> {
        let mut dense = vec![0.0f32; self.encoding.dim];
        self.encoding.encode_ds(&self.spec, ds, row, &mut dense);
        self.finalize(self.scores(&dense))
    }

    fn describe(&self) -> String {
        let mut s = format!(
            "Type: \"{}\"\nTask: {}\nLabel: \"{}\"\n\nDense dimension: {}\nClasses: {}\n",
            self.model_type(),
            self.task.name(),
            self.spec.columns[self.label_col].name,
            self.encoding.dim,
            self.weights.len()
        );
        if let Some(e) = &self.self_eval {
            s.push_str(&format!("Self-evaluation: {} = {:.6}\n", e.metric, e.value));
        }
        s
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("format_version", Json::Num(super::io::MODEL_FORMAT_VERSION as f64))
            .set("model_type", Json::Str(self.model_type().into()))
            .set("task", Json::Str(self.task.name().into()))
            .set("label_col", Json::Num(self.label_col as f64))
            .set("spec", self.spec.to_json())
            .set("encoding", self.encoding.to_json())
            .set(
                "weights",
                Json::Arr(
                    self.weights
                        .iter()
                        .map(|w| {
                            Json::Arr(w.iter().map(|&x| Json::Num(x as f64)).collect())
                        })
                        .collect(),
                ),
            )
            .set("bias", Json::Arr(self.bias.iter().map(|&b| Json::Num(b as f64)).collect()));
        j
    }

    fn variable_importances(&self) -> Vec<VariableImportance> {
        // |weight| mass per source column.
        let mut values: Vec<(String, f64)> = self
            .encoding
            .slots
            .iter()
            .map(|s| {
                let mass: f64 = self
                    .weights
                    .iter()
                    .map(|w| {
                        w[s.offset..s.offset + s.width]
                            .iter()
                            .map(|&x| x.abs() as f64)
                            .sum::<f64>()
                    })
                    .sum();
                (self.spec.columns[s.col].name.clone(), mass)
            })
            .collect();
        values.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        vec![VariableImportance { kind: "ABS_WEIGHT_MASS", values }]
    }

    fn self_evaluation(&self) -> Option<&SelfEvaluation> {
        self.self_eval.as_ref()
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::{ColumnSpec, NumericalStats};

    fn spec() -> DataSpec {
        let mut num = ColumnSpec::numerical("x");
        num.num_stats = NumericalStats { mean: 10.0, min: 0.0, max: 20.0, std: 2.0 };
        DataSpec {
            columns: vec![
                num,
                ColumnSpec::categorical("c", vec!["a".into(), "b".into(), "z".into()]),
                ColumnSpec::categorical("y", vec!["no".into(), "yes".into()]),
            ],
        }
    }

    #[test]
    fn encoding_layout() {
        let s = spec();
        let enc = DenseEncoding::build(&s, 2);
        assert_eq!(enc.dim, 4); // 1 numerical + 3 one-hot
        let mut out = vec![0.0; 4];
        enc.encode_row(
            &vec![AttrValue::Num(14.0), AttrValue::Cat(1), AttrValue::Missing],
            &mut out,
        );
        assert_eq!(out, vec![2.0, 0.0, 1.0, 0.0]); // (14-10)/2, one-hot b
    }

    #[test]
    fn missing_encodes_to_zero() {
        let s = spec();
        let enc = DenseEncoding::build(&s, 2);
        let mut out = vec![0.0; 4];
        enc.encode_row(&vec![AttrValue::Missing, AttrValue::Missing, AttrValue::Missing], &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    fn predict_softmax() {
        let s = spec();
        let enc = DenseEncoding::build(&s, 2);
        let m = LinearModel {
            spec: s,
            label_col: 2,
            task: Task::Classification,
            encoding: enc,
            weights: vec![vec![0.0; 4], vec![1.0, 0.0, 0.0, 0.0]],
            bias: vec![0.0, -1.0],
            self_eval: None,
        };
        let p = m.predict_row(&vec![AttrValue::Num(14.0), AttrValue::Cat(0), AttrValue::Missing]);
        // class1 score = 2*1 - 1 = 1, class0 = 0 -> sigmoid-like
        assert!((p[0] + p[1] - 1.0).abs() < 1e-12);
        assert!(p[1] > p[0]);
    }

    #[test]
    fn importances_nonzero() {
        let s = spec();
        let enc = DenseEncoding::build(&s, 2);
        let m = LinearModel {
            spec: s,
            label_col: 2,
            task: Task::Classification,
            encoding: enc,
            weights: vec![vec![0.5, 0.0, 0.0, 0.0], vec![-0.5, 1.0, 0.0, 0.0]],
            bias: vec![0.0, 0.0],
            self_eval: None,
        };
        let vi = m.variable_importances();
        assert_eq!(vi[0].values.len(), 2);
        assert!(vi[0].values.iter().all(|(_, v)| *v > 0.0));
    }
}
