//! Flat engine: all trees compiled into contiguous structure-of-arrays
//! node tables. Removes pointer chasing and per-node heap indirection —
//! the generic fast path for any forest model (§3.7).
//!
//! The batch path walks [`BLOCK_SIZE`]-row blocks tree-major (one tree's
//! node table stays cache-hot across the whole block) against resolved
//! columnar slices, and aggregates into the caller's output buffer —
//! no `Observation`, no per-row `Vec`.
//!
//! Two block kernels exist. The *scalar* kernel ([`FlatEngine`]'s
//! `eval_tree_cols`) walks each row down the tree independently and is
//! the correctness reference. The *lane* kernel (`eval_tree_cols_lanes`)
//! restructures the traversal level-synchronously: all rows of a block
//! advance one tree level per round, the `x >= threshold` comparisons run
//! as one straight-line sweep over contiguous lane arrays (which the
//! compiler auto-vectorizes), and oblique dot products accumulate
//! term-major across lanes while keeping each lane's scalar term order —
//! so the two kernels are bit-identical. The `simd` cargo feature selects
//! the default kernel; [`FlatEngine::set_simd`] overrides it at runtime.

use super::{Aggregate, BLOCK_SIZE, ColumnAccess, InferenceEngine};
use crate::dataset::{AttrValue, Dataset, Observation};
use crate::model::forest::{GradientBoostedTreesModel, RandomForestModel};
use crate::model::tree::{bitmap_contains, Condition, DecisionTree};
use crate::model::{Model, Task};
use std::ops::Range;

const KIND_LEAF: u8 = 0;
const KIND_HIGHER: u8 = 1;
const KIND_CONTAINS: u8 = 2;
const KIND_CONTAINS_SET: u8 = 3;
const KIND_OBLIQUE: u8 = 4;
const KIND_IS_TRUE: u8 = 5;

/// One flattened node. Children are stored adjacently: positive child at
/// `child`, negative child at `child + 1`.
#[derive(Clone, Copy)]
struct FlatNode {
    kind: u8,
    missing_to_positive: bool,
    attr: u32,
    threshold: f32,
    /// Offset+len into `bitmaps` (contains) or `oblique` (oblique terms),
    /// or offset into `leaf_values` for leaves.
    aux: u32,
    aux_len: u32,
    child: u32,
}

pub struct FlatEngine {
    nodes: Vec<FlatNode>,
    roots: Vec<u32>,
    bitmaps: Vec<u64>,
    /// Oblique terms: (attr, weight) pairs.
    oblique: Vec<(u32, f32)>,
    leaf_values: Vec<f32>,
    leaf_dim: usize,
    aggregate: Aggregate,
    /// Per tree: every node is Leaf/Higher/Oblique — the shapes the lane
    /// kernel handles. Trees with categorical(-set) or boolean conditions
    /// fall back to the scalar kernel.
    lane_ok: Vec<bool>,
    /// Per tree: attrs read by Higher nodes. The lane kernel is only used
    /// when each resolves to a numerical column of the dataset at hand.
    lane_attrs: Vec<Vec<u32>>,
    /// Whether `predict_batch` uses the lane kernel where possible.
    /// Defaults to the `simd` cargo feature.
    simd: bool,
}

impl FlatEngine {
    pub fn compile(model: &dyn Model) -> Option<FlatEngine> {
        if let Some(m) = model.as_any().downcast_ref::<RandomForestModel>() {
            let num_classes = match m.task {
                Task::Classification => m.spec.columns[m.label_col].vocab_size(),
                Task::Regression => 1,
            };
            let aggregate = match m.task {
                Task::Classification => Aggregate::RfAverage {
                    num_classes,
                    winner_take_all: m.winner_take_all,
                },
                Task::Regression => Aggregate::RfRegression,
            };
            Some(Self::from_trees(&m.trees, num_classes, aggregate))
        } else if let Some(m) = model.as_any().downcast_ref::<GradientBoostedTreesModel>() {
            let aggregate = Aggregate::Gbt {
                loss: m.loss,
                dim: m.trees_per_iter,
                initial: m.initial_predictions.clone(),
            };
            Some(Self::from_trees(&m.trees, 1, aggregate))
        } else {
            None
        }
    }

    fn from_trees(trees: &[DecisionTree], leaf_dim: usize, aggregate: Aggregate) -> FlatEngine {
        let mut e = FlatEngine {
            nodes: Vec::new(),
            roots: Vec::with_capacity(trees.len()),
            bitmaps: Vec::new(),
            oblique: Vec::new(),
            leaf_values: Vec::new(),
            leaf_dim,
            aggregate,
            lane_ok: Vec::new(),
            lane_attrs: Vec::new(),
            simd: cfg!(feature = "simd"),
        };
        for t in trees {
            let root = e.nodes.len() as u32;
            e.roots.push(root);
            // BFS copy with children-adjacent layout.
            // map: original index -> flat index.
            let mut flat_of = vec![u32::MAX; t.nodes.len()];
            let mut queue = std::collections::VecDeque::new();
            flat_of[0] = e.nodes.len() as u32;
            e.nodes.push(FlatNode {
                kind: KIND_LEAF,
                missing_to_positive: false,
                attr: 0,
                threshold: 0.0,
                aux: 0,
                aux_len: 0,
                child: 0,
            });
            queue.push_back(0usize);
            while let Some(orig) = queue.pop_front() {
                let node = &t.nodes[orig];
                let flat_idx = flat_of[orig] as usize;
                match &node.condition {
                    None => {
                        let aux = e.leaf_values.len() as u32;
                        e.leaf_values.extend_from_slice(&node.value);
                        // pad to leaf_dim
                        for _ in node.value.len()..leaf_dim {
                            e.leaf_values.push(0.0);
                        }
                        e.nodes[flat_idx] = FlatNode {
                            kind: KIND_LEAF,
                            missing_to_positive: false,
                            attr: 0,
                            threshold: 0.0,
                            aux,
                            aux_len: leaf_dim as u32,
                            child: 0,
                        };
                    }
                    Some(cond) => {
                        // Allocate both children adjacently.
                        let child = e.nodes.len() as u32;
                        for _ in 0..2 {
                            e.nodes.push(FlatNode {
                                kind: KIND_LEAF,
                                missing_to_positive: false,
                                attr: 0,
                                threshold: 0.0,
                                aux: 0,
                                aux_len: 0,
                                child: 0,
                            });
                        }
                        flat_of[node.positive as usize] = child;
                        flat_of[node.negative as usize] = child + 1;
                        queue.push_back(node.positive as usize);
                        queue.push_back(node.negative as usize);
                        let fl = match cond {
                            Condition::Higher { attr, threshold } => FlatNode {
                                kind: KIND_HIGHER,
                                missing_to_positive: node.missing_to_positive,
                                attr: *attr as u32,
                                threshold: *threshold,
                                aux: 0,
                                aux_len: 0,
                                child,
                            },
                            Condition::ContainsBitmap { attr, bitmap } => {
                                let aux = e.bitmaps.len() as u32;
                                e.bitmaps.extend_from_slice(bitmap);
                                FlatNode {
                                    kind: KIND_CONTAINS,
                                    missing_to_positive: node.missing_to_positive,
                                    attr: *attr as u32,
                                    threshold: 0.0,
                                    aux,
                                    aux_len: bitmap.len() as u32,
                                    child,
                                }
                            }
                            Condition::ContainsSetBitmap { attr, bitmap } => {
                                let aux = e.bitmaps.len() as u32;
                                e.bitmaps.extend_from_slice(bitmap);
                                FlatNode {
                                    kind: KIND_CONTAINS_SET,
                                    missing_to_positive: node.missing_to_positive,
                                    attr: *attr as u32,
                                    threshold: 0.0,
                                    aux,
                                    aux_len: bitmap.len() as u32,
                                    child,
                                }
                            }
                            Condition::Oblique { attrs, weights, threshold } => {
                                let aux = e.oblique.len() as u32;
                                for (&a, &w) in attrs.iter().zip(weights) {
                                    e.oblique.push((a as u32, w));
                                }
                                FlatNode {
                                    kind: KIND_OBLIQUE,
                                    missing_to_positive: node.missing_to_positive,
                                    attr: 0,
                                    threshold: *threshold,
                                    aux,
                                    aux_len: attrs.len() as u32,
                                    child,
                                }
                            }
                            Condition::IsTrue { attr } => FlatNode {
                                kind: KIND_IS_TRUE,
                                missing_to_positive: node.missing_to_positive,
                                attr: *attr as u32,
                                threshold: 0.0,
                                aux: 0,
                                aux_len: 0,
                                child,
                            },
                        };
                        e.nodes[flat_idx] = fl;
                    }
                }
            }
        }
        // Lane-kernel metadata. Each tree's nodes occupy the contiguous
        // range [roots[ti], roots[ti+1]) thanks to the BFS copy above.
        for ti in 0..e.roots.len() {
            let lo = e.roots[ti] as usize;
            let hi = e.roots.get(ti + 1).map(|&r| r as usize).unwrap_or(e.nodes.len());
            let mut ok = true;
            let mut attrs: Vec<u32> = Vec::new();
            for n in &e.nodes[lo..hi] {
                match n.kind {
                    KIND_LEAF | KIND_OBLIQUE => {}
                    KIND_HIGHER => attrs.push(n.attr),
                    _ => ok = false,
                }
            }
            attrs.sort_unstable();
            attrs.dedup();
            e.lane_ok.push(ok);
            e.lane_attrs.push(attrs);
        }
        e
    }

    /// Selects the lane-wise (`true`) or scalar (`false`) block kernel for
    /// `predict_batch`. The default follows the `simd` cargo feature; the
    /// scalar kernel always stays available as the correctness reference
    /// and the two are bit-identical (see `prop_simd_lanes_match_scalar`
    /// in `rust/tests/properties.rs`).
    pub fn set_simd(&mut self, on: bool) {
        self.simd = on;
    }

    /// Lane-wise traversal of one tree over the block rows
    /// `start..start + bs`: every active row advances one level per round,
    /// and the `x >= threshold` decisions of a round run as one
    /// straight-line sweep over contiguous lane arrays. Gated by
    /// `lane_ok`/`lane_attrs` (Leaf/Higher/Oblique nodes only, all Higher
    /// attrs resolved to numerical columns). Leaf offsets are written to
    /// `leaves[row * stride + ti]`.
    fn eval_tree_cols_lanes(
        &self,
        root: u32,
        cols: &ColumnAccess,
        start: usize,
        bs: usize,
        leaves: &mut [u32],
        stride: usize,
        ti: usize,
    ) {
        debug_assert!(bs <= BLOCK_SIZE);
        // Lane state: node index and block-local row of each active lane.
        let mut idx = [0u32; BLOCK_SIZE];
        let mut row = [0u32; BLOCK_SIZE];
        // Per-round gathered operands for the lane sweep.
        let mut xs = [0.0f32; BLOCK_SIZE];
        let mut ts = [0.0f32; BLOCK_SIZE];
        let mut m2p = [false; BLOCK_SIZE];
        let mut ch = [0u32; BLOCK_SIZE];
        for i in 0..bs {
            idx[i] = root;
            row[i] = i as u32;
        }
        let mut m = bs;
        while m > 0 {
            // Retire lanes that reached a leaf; keep the rest in row order
            // (runs below then read their columns with ascending indices).
            let mut w = 0usize;
            for i in 0..m {
                let n = &self.nodes[idx[i] as usize];
                if n.kind == KIND_LEAF {
                    leaves[row[i] as usize * stride + ti] = n.aux;
                } else {
                    idx[w] = idx[i];
                    row[w] = row[i];
                    w += 1;
                }
            }
            m = w;
            if m == 0 {
                break;
            }
            // Gather (x, threshold, child) per lane. Consecutive lanes on
            // the same node (a "run" — all of them, at the root) share the
            // node decode and stream the column in row order.
            let mut i = 0usize;
            while i < m {
                let node_idx = idx[i];
                let mut j = i + 1;
                while j < m && idx[j] == node_idx {
                    j += 1;
                }
                let n = &self.nodes[node_idx as usize];
                match n.kind {
                    KIND_HIGHER => {
                        let col = cols.num[n.attr as usize]
                            .expect("lane kernel requires resolved numerical columns");
                        for k in i..j {
                            xs[k] = col[start + row[k] as usize];
                        }
                        for k in i..j {
                            ts[k] = n.threshold;
                            m2p[k] = n.missing_to_positive;
                            ch[k] = n.child;
                        }
                    }
                    KIND_OBLIQUE => {
                        xs[i..j].fill(0.0);
                        // Term-major across the run's lanes; each lane still
                        // accumulates in the scalar kernel's term order, so
                        // the dot product is bit-identical to it.
                        for &(a, wgt) in
                            &self.oblique[n.aux as usize..(n.aux + n.aux_len) as usize]
                        {
                            if let Some(col) = cols.num[a as usize] {
                                for k in i..j {
                                    let x = col[start + row[k] as usize];
                                    if !x.is_nan() {
                                        xs[k] += wgt * x;
                                    }
                                }
                            }
                        }
                        for k in i..j {
                            ts[k] = n.threshold;
                            // The scalar kernel never routes oblique nodes by
                            // the missing policy: `acc >= threshold` with a
                            // NaN accumulator is plain false.
                            m2p[k] = false;
                            ch[k] = n.child;
                        }
                    }
                    _ => unreachable!("lane kernel gated on node kinds"),
                }
                i = j;
            }
            // The lane sweep: branch-free compare + advance, vectorizable.
            for i in 0..m {
                let x = xs[i];
                let nan = x.is_nan();
                let go_pos = (!nan && x >= ts[i]) | (nan & m2p[i]);
                idx[i] = ch[i] + (!go_pos) as u32;
            }
        }
    }

    /// Evaluates one tree on a row observation; returns leaf-value offset.
    #[inline]
    fn eval_tree_row(&self, root: u32, obs: &Observation) -> u32 {
        let mut idx = root;
        loop {
            let n = &self.nodes[idx as usize];
            let go_pos = match n.kind {
                KIND_LEAF => return n.aux,
                KIND_HIGHER => match &obs[n.attr as usize] {
                    AttrValue::Num(x) if !x.is_nan() => *x >= n.threshold,
                    _ => n.missing_to_positive,
                },
                KIND_CONTAINS => match &obs[n.attr as usize] {
                    AttrValue::Cat(c) => bitmap_contains(
                        &self.bitmaps[n.aux as usize..(n.aux + n.aux_len) as usize],
                        *c,
                    ),
                    _ => n.missing_to_positive,
                },
                KIND_CONTAINS_SET => match &obs[n.attr as usize] {
                    AttrValue::CatSet(items) => {
                        let bm = &self.bitmaps[n.aux as usize..(n.aux + n.aux_len) as usize];
                        items.iter().any(|&i| bitmap_contains(bm, i))
                    }
                    _ => n.missing_to_positive,
                },
                KIND_OBLIQUE => {
                    let mut acc = 0.0f32;
                    for &(a, w) in
                        &self.oblique[n.aux as usize..(n.aux + n.aux_len) as usize]
                    {
                        if let AttrValue::Num(x) = &obs[a as usize] {
                            if !x.is_nan() {
                                acc += w * x;
                            }
                        }
                    }
                    acc >= n.threshold
                }
                KIND_IS_TRUE => match &obs[n.attr as usize] {
                    AttrValue::Bool(b) => *b,
                    _ => n.missing_to_positive,
                },
                _ => unreachable!(),
            };
            idx = if go_pos { n.child } else { n.child + 1 };
        }
    }

    /// Same traversal against resolved columnar slices (batch path).
    #[inline]
    fn eval_tree_cols(&self, root: u32, cols: &ColumnAccess, row: usize) -> u32 {
        let mut idx = root;
        loop {
            let n = &self.nodes[idx as usize];
            let go_pos = match n.kind {
                KIND_LEAF => return n.aux,
                KIND_HIGHER => match cols.num[n.attr as usize] {
                    Some(v) => {
                        let x = v[row];
                        if x.is_nan() {
                            n.missing_to_positive
                        } else {
                            x >= n.threshold
                        }
                    }
                    None => n.missing_to_positive,
                },
                KIND_CONTAINS => match cols.cat[n.attr as usize] {
                    Some(v) => {
                        let c = v[row];
                        if c == crate::dataset::MISSING_CAT {
                            n.missing_to_positive
                        } else {
                            bitmap_contains(
                                &self.bitmaps[n.aux as usize..(n.aux + n.aux_len) as usize],
                                c,
                            )
                        }
                    }
                    None => n.missing_to_positive,
                },
                KIND_CONTAINS_SET => {
                    let col = &cols.columns[n.attr as usize];
                    if col.is_missing(row) {
                        n.missing_to_positive
                    } else {
                        let bm = &self.bitmaps[n.aux as usize..(n.aux + n.aux_len) as usize];
                        col.set_values(row)
                            .map(|items| items.iter().any(|&i| bitmap_contains(bm, i)))
                            .unwrap_or(n.missing_to_positive)
                    }
                }
                KIND_OBLIQUE => {
                    let mut acc = 0.0f32;
                    for &(a, w) in
                        &self.oblique[n.aux as usize..(n.aux + n.aux_len) as usize]
                    {
                        if let Some(v) = cols.num[a as usize] {
                            let x = v[row];
                            if !x.is_nan() {
                                acc += w * x;
                            }
                        }
                    }
                    acc >= n.threshold
                }
                KIND_IS_TRUE => match cols.boolean[n.attr as usize] {
                    Some(v) => match v[row] {
                        1 => true,
                        0 => false,
                        _ => n.missing_to_positive,
                    },
                    None => n.missing_to_positive,
                },
                _ => unreachable!(),
            };
            idx = if go_pos { n.child } else { n.child + 1 };
        }
    }

    /// Aggregates one example's per-tree leaf offsets into `out`
    /// (`out.len() == output_dim()`). `scores` is caller-owned scratch of
    /// `aggregate.score_dim()` values, reused across examples.
    fn aggregate_leaves_into(&self, leaf_offsets: &[u32], scores: &mut [f64], out: &mut [f64]) {
        match &self.aggregate {
            Aggregate::RfAverage { winner_take_all, .. } => {
                out.fill(0.0);
                for &off in leaf_offsets {
                    let v = &self.leaf_values[off as usize..off as usize + self.leaf_dim];
                    if *winner_take_all {
                        let mut best = 0usize;
                        for (i, &x) in v.iter().enumerate().skip(1) {
                            if x > v[best] {
                                best = i;
                            }
                        }
                        out[best] += 1.0;
                    } else {
                        for (a, &x) in out.iter_mut().zip(v) {
                            *a += x as f64;
                        }
                    }
                }
                let n = leaf_offsets.len().max(1) as f64;
                for a in out.iter_mut() {
                    *a /= n;
                }
            }
            Aggregate::RfRegression => {
                let sum: f64 = leaf_offsets
                    .iter()
                    .map(|&off| self.leaf_values[off as usize] as f64)
                    .sum();
                out[0] = sum / leaf_offsets.len().max(1) as f64;
            }
            Aggregate::Gbt { loss, dim, initial } => {
                scores.copy_from_slice(initial);
                for (i, &off) in leaf_offsets.iter().enumerate() {
                    scores[i % dim] += self.leaf_values[off as usize] as f64;
                }
                Aggregate::apply_gbt_link(*loss, scores, out);
            }
        }
    }
}

impl InferenceEngine for FlatEngine {
    fn name(&self) -> String {
        let kind = match self.aggregate {
            Aggregate::RfAverage { .. } | Aggregate::RfRegression => "RandomForest",
            Aggregate::Gbt { .. } => "GradientBoostedTrees",
        };
        // YDF's name for its flat SoA engine. Stable across kernel choice:
        // `benchmark_inference` tags its scalar-kernel variants itself, so
        // BENCH_inference.json keys stay comparable across feature configs.
        format!("{kind}OptPred")
    }

    fn output_dim(&self) -> usize {
        self.aggregate.output_dim()
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        let leaves: Vec<u32> =
            self.roots.iter().map(|&r| self.eval_tree_row(r, obs)).collect();
        let mut scores = vec![0.0f64; self.aggregate.score_dim()];
        let mut out = vec![0.0f64; self.aggregate.output_dim()];
        self.aggregate_leaves_into(&leaves, &mut scores, &mut out);
        out
    }

    fn predict_batch(&self, ds: &Dataset, rows: Range<usize>, out: &mut [f64]) {
        let dim = self.output_dim();
        debug_assert_eq!(out.len(), rows.len() * dim);
        let cols = ColumnAccess::new(ds);
        let num_trees = self.roots.len();
        // Kernel choice per tree, once per call: the lane kernel needs
        // compatible node kinds and every Higher attr resolved to a
        // numerical column of *this* dataset.
        let use_lanes: Vec<bool> = if self.simd {
            (0..num_trees)
                .map(|ti| {
                    self.lane_ok[ti]
                        && self.lane_attrs[ti]
                            .iter()
                            .all(|&a| cols.num[a as usize].is_some())
                })
                .collect()
        } else {
            vec![false; num_trees]
        };
        // Scratch sized once per batch call; the per-row loop is
        // allocation-free.
        let mut leaves = vec![0u32; BLOCK_SIZE * num_trees];
        let mut scores = vec![0.0f64; self.aggregate.score_dim()];
        let mut start = rows.start;
        let mut out_off = 0usize;
        while start < rows.end {
            let bs = BLOCK_SIZE.min(rows.end - start);
            // Tree-major over the block: one tree's node table stays hot
            // across all `bs` examples.
            for (ti, &root) in self.roots.iter().enumerate() {
                if use_lanes[ti] {
                    self.eval_tree_cols_lanes(root, &cols, start, bs, &mut leaves, num_trees, ti);
                } else {
                    for bi in 0..bs {
                        leaves[bi * num_trees + ti] =
                            self.eval_tree_cols(root, &cols, start + bi);
                    }
                }
            }
            for bi in 0..bs {
                let o = out_off + bi * dim;
                self.aggregate_leaves_into(
                    &leaves[bi * num_trees..(bi + 1) * num_trees],
                    &mut scores,
                    &mut out[o..o + dim],
                );
            }
            start += bs;
            out_off += bs * dim;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::random_forest::RandomForestConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};

    fn close(a: &[f64], b: &[f64]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-9, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn flat_matches_naive_gbt() {
        let ds = synthetic::adult_like(200, 131);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 10;
        cfg.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let flat = FlatEngine::compile(model.as_ref()).unwrap();
        for r in 0..50 {
            close(&flat.predict_row(&ds.row(r)), &model.predict_ds_row(&ds, r));
        }
        let batch = flat.predict_dataset(&ds);
        for r in 0..50 {
            close(&batch[r], &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn flat_matches_naive_rf_with_missing() {
        let ds = synthetic::adult_like(200, 133);
        let mut cfg = RandomForestConfig::new("income");
        cfg.num_trees = 8;
        cfg.compute_oob = false;
        let model = RandomForestLearner::new(cfg).train(&ds).unwrap();
        let flat = FlatEngine::compile(model.as_ref()).unwrap();
        for r in 0..ds.num_rows() {
            close(&flat.predict_row(&ds.row(r)), &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn flat_matches_naive_oblique_model() {
        let ds = synthetic::adult_like(150, 137);
        let mut cfg = GbtConfig::benchmark_rank1("income");
        cfg.num_trees = 6;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let flat = FlatEngine::compile(model.as_ref()).unwrap();
        for r in 0..ds.num_rows() {
            close(&flat.predict_row(&ds.row(r)), &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn batch_handles_unaligned_tail_and_offset_ranges() {
        // 150 rows = 2 full 64-row blocks + a 22-row tail.
        let ds = synthetic::adult_like(150, 138);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 7;
        cfg.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let flat = FlatEngine::compile(model.as_ref()).unwrap();
        let dim = flat.output_dim();
        // Offset, non-block-aligned range.
        let range = 13..97;
        let mut out = vec![0.0f64; (97 - 13) * dim];
        flat.predict_batch(&ds, range.clone(), &mut out);
        for (i, r) in range.enumerate() {
            close(&out[i * dim..(i + 1) * dim], &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn lane_kernel_matches_scalar_bitwise() {
        // Oblique splits included: their lane-wise dot products must stay
        // bit-identical to the scalar term order.
        let ds = synthetic::adult_like(150, 151);
        let mut cfg = GbtConfig::benchmark_rank1("income");
        cfg.num_trees = 6;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let mut scalar = FlatEngine::compile(model.as_ref()).unwrap();
        scalar.set_simd(false);
        let mut lanes = FlatEngine::compile(model.as_ref()).unwrap();
        lanes.set_simd(true);
        let dim = scalar.output_dim();
        let n = ds.num_rows();
        let mut a = vec![0.0f64; n * dim];
        let mut b = vec![0.0f64; n * dim];
        scalar.predict_batch(&ds, 0..n, &mut a);
        lanes.predict_batch(&ds, 0..n, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "scalar vs lane kernel");
        }
    }

    #[test]
    fn linear_model_not_compilable() {
        let ds = synthetic::adult_like(50, 139);
        let model = crate::learner::LinearLearner::default_config("income")
            .train(&ds)
            .unwrap();
        assert!(FlatEngine::compile(model.as_ref()).is_none());
    }
}
