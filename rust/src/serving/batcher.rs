//! Micro-batching request coalescer: a bounded submission queue feeding
//! one scorer thread.
//!
//! Concurrent single/multi-row requests are appended, in arrival order,
//! to a shared columnar accumulation block. The scorer flushes — one
//! engine `predict_batch` call over everything pending — when
//!
//! * the pending rows reach [`BatcherConfig::flush_rows`] (a
//!   [`BLOCK_SIZE`] multiple by default, so the engine kernels run full
//!   blocks), or
//! * the *oldest* pending request has waited [`BatcherConfig::max_delay`]
//!   (the latency deadline; `0` means "flush whenever the scorer is
//!   free" — adaptive batching that coalesces only the backlog that
//!   accumulates while the previous batch scores).
//!
//! Results are scattered back to per-request waiters over one-shot
//! channels. Coalescing is pure concatenation and engines are
//! row-independent, so outputs are **bit-identical** to a single
//! `predict_batch` over the same rows (pinned by
//! `rust/tests/serving.rs`).
//!
//! Admission is layered, every rejection immediate and in-band:
//!
//! * the queue is bounded by [`BatcherConfig::max_queue_rows`]
//!   ([`SubmitError::QueueFull`] beyond it);
//! * an optional per-model quota ([`BatcherConfig::quota_rows`]) rejects
//!   a hot model's submissions before they can crowd out its neighbors
//!   ([`SubmitError::QuotaExceeded`]);
//! * an optional registry-wide [`AdmissionControl`] budget caps the
//!   total rows pending across every model ([`SubmitError::AdmissionFull`]).
//!
//! Accepted requests are additionally covered by the queue deadline
//! ([`BatcherConfig::queue_deadline`]): a request still unscored when its
//! flush finally starts is *shed* with a retryable
//! [`ScoreError::Shed`] reply carrying a `retry_after_ms` hint, instead
//! of aging unboundedly behind a slow engine.
//!
//! The scorer is panic-isolated: an engine panic mid-flush is caught,
//! every waiter of that flush receives an in-band [`ScoreError::Failed`]
//! reply, and the batcher keeps serving subsequent flushes. Only a panic
//! outside the scoring boundary fails the batcher open (shutdown +
//! waiters answered with errors), never a silent wedge.

use super::session::{RowBlock, Session};
use super::stats::ServingStats;
use crate::inference::BLOCK_SIZE;
use crate::utils::pool::WorkerPool;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy knobs. The defaults suit a low-latency online service;
/// the b5 bench and the CLI expose them.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Flush as soon as this many rows are pending. Kept a multiple of
    /// [`BLOCK_SIZE`] by [`Batcher::new`] (rounded up) so coalesced
    /// batches fill whole kernel blocks.
    pub flush_rows: usize,
    /// Latency deadline: flush when the oldest pending request has waited
    /// this long, even if `flush_rows` was not reached. `Duration::ZERO`
    /// disables the wait — the scorer drains whatever is pending the
    /// moment it is free.
    pub max_delay: Duration,
    /// Queue capacity in rows; submissions beyond it are rejected
    /// ([`SubmitError::QueueFull`]). Also the per-request row cap.
    pub max_queue_rows: usize,
    /// Worker threads a flush may fan block spans out over when the
    /// coalesced batch exceeds one [`BLOCK_SIZE`] block (the
    /// `predict_into` contract over persistent `utils/pool.rs` workers).
    /// `0` resolves to [`crate::inference::batch_threads`] (the
    /// `YDF_INFER_THREADS` knob / available parallelism); `1` keeps
    /// flushes single-threaded. Ignored when the batcher is handed a
    /// shared scoring pool ([`Batcher::with_scoring_pool`]).
    pub score_threads: usize,
    /// Per-request queue deadline: a request still unscored when its
    /// flush starts, `queue_deadline` after submission, is shed with a
    /// retryable [`ScoreError::Shed`] reply instead of being scored late.
    /// `Duration::ZERO` (the default) disables shedding.
    pub queue_deadline: Duration,
    /// Per-model pending-row quota, checked against this batcher's own
    /// queue on submit; `0` (the default) disables it. Meaningful below
    /// `max_queue_rows` when several models share one server — it stops
    /// one hot model from monopolizing worker and scoring capacity.
    pub quota_rows: usize,
    /// Registry-wide admission budget: total rows pending across *all* of
    /// a registry's batchers; `0` (the default) disables it. Read by
    /// `Registry::new` (which builds the shared [`AdmissionControl`]);
    /// standalone batchers ignore it.
    pub admission_rows: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            flush_rows: BLOCK_SIZE,
            max_delay: Duration::from_millis(2),
            max_queue_rows: 64 * BLOCK_SIZE,
            score_threads: 0,
            queue_deadline: Duration::ZERO,
            quota_rows: 0,
            admission_rows: 0,
        }
    }
}

impl BatcherConfig {
    /// Resolves [`BatcherConfig::score_threads`] into a scoring pool:
    /// `None` when flushes should score single-threaded. The single
    /// source of truth for the resolution rule — used by standalone
    /// batchers ([`Batcher::with_stats`]) and shared across a registry's
    /// batchers (`Registry::new`).
    pub fn resolve_score_pool(&self) -> Option<Arc<WorkerPool>> {
        let threads = if self.score_threads == 0 {
            crate::inference::batch_threads()
        } else {
            self.score_threads
        };
        if threads > 1 {
            Some(Arc::new(WorkerPool::new(threads)))
        } else {
            None
        }
    }
}

/// Registry-wide admission budget: one shared counter of rows pending
/// (queued but not yet taken by a scorer) across every model's batcher.
/// Reserved on submit, released when a flush takes the rows — so the
/// budget bounds queued memory and queueing delay, not scoring itself.
pub struct AdmissionControl {
    pending: AtomicUsize,
    capacity: usize,
}

impl AdmissionControl {
    pub fn new(capacity: usize) -> AdmissionControl {
        AdmissionControl { pending: AtomicUsize::new(0), capacity: capacity.max(1) }
    }

    /// Rows currently reserved across all participating batchers.
    pub fn pending_rows(&self) -> usize {
        self.pending.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Reserves `n` rows; on overflow returns `(pending, capacity)`
    /// without reserving anything.
    fn try_reserve(&self, n: usize) -> Result<(), (usize, usize)> {
        let mut cur = self.pending.load(Ordering::Relaxed);
        loop {
            if cur + n > self.capacity {
                return Err((cur, self.capacity));
            }
            match self.pending.compare_exchange_weak(
                cur,
                cur + n,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Ok(()),
                Err(now) => cur = now,
            }
        }
    }

    fn release(&self, n: usize) {
        self.pending.fetch_sub(n, Ordering::Relaxed);
    }
}

/// Why a submission was rejected. All variants are immediate — the
/// batcher never blocks a submitter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity; retry after in-flight requests drain.
    QueueFull { pending_rows: usize, capacity: usize },
    /// This model's pending rows are at its quota
    /// ([`BatcherConfig::quota_rows`]); retry after its queue drains.
    QuotaExceeded { pending_rows: usize, quota: usize },
    /// The shared admission budget across every model is exhausted
    /// ([`BatcherConfig::admission_rows`]); retry shortly.
    AdmissionFull { pending_rows: usize, capacity: usize },
    /// The request alone exceeds the queue capacity (or this model's
    /// quota) and can never be accepted; split it into smaller requests.
    RequestTooLarge { rows: usize, capacity: usize },
    /// Zero-row requests have no result to wait for.
    EmptyRequest,
    /// The batcher is shutting down.
    Shutdown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { pending_rows, capacity } => write!(
                f,
                "serving queue full ({pending_rows}/{capacity} rows pending); retry shortly"
            ),
            SubmitError::QuotaExceeded { pending_rows, quota } => write!(
                f,
                "model queue quota exhausted ({pending_rows}/{quota} rows pending for this \
                 model); retry shortly"
            ),
            SubmitError::AdmissionFull { pending_rows, capacity } => write!(
                f,
                "serving admission budget exhausted ({pending_rows}/{capacity} rows pending \
                 across all models); retry shortly"
            ),
            SubmitError::RequestTooLarge { rows, capacity } => write!(
                f,
                "request of {rows} rows exceeds the queue capacity of {capacity} rows; \
                 split it into smaller requests"
            ),
            SubmitError::EmptyRequest => write!(f, "request contains no rows"),
            SubmitError::Shutdown => write!(f, "serving batcher is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why an *accepted* request was not scored. Unlike [`SubmitError`] this
/// arrives through [`Pending::wait`], after the request sat in the queue.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScoreError {
    /// Scoring did not happen (engine panic, batcher shutdown). The
    /// request was not served; it is safe to retry.
    Failed(String),
    /// Shed by the queue deadline ([`BatcherConfig::queue_deadline`]):
    /// the request aged out before its flush started. `retry_after_ms`
    /// estimates when the queue should have drained (about twice the
    /// recent flush wall time).
    Shed { waited_ms: u64, retry_after_ms: u64 },
}

impl fmt::Display for ScoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScoreError::Failed(why) => write!(f, "{why}"),
            ScoreError::Shed { waited_ms, retry_after_ms } => write!(
                f,
                "request shed before scoring: queued for {waited_ms} ms, past the queue \
                 deadline; retry in ~{retry_after_ms} ms"
            ),
        }
    }
}

impl std::error::Error for ScoreError {}

/// A submitted request's pending result.
pub struct Pending {
    rx: Receiver<Result<Vec<f64>, ScoreError>>,
}

impl Pending {
    /// Blocks until the coalesced batch containing this request is scored
    /// (or shed / failed — always an answer, never a hang). Returns the
    /// request's own predictions, row-major (`rows * output_dim()`
    /// values).
    pub fn wait(self) -> Result<Vec<f64>, ScoreError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ScoreError::Failed(
                "serving batcher shut down before scoring the request".to_string(),
            )),
        }
    }
}

struct Waiter {
    /// First row of this request inside the accumulation block.
    start_row: usize,
    rows: usize,
    /// Submission time: the queue-deadline anchor.
    enqueued: Instant,
    tx: Sender<Result<Vec<f64>, ScoreError>>,
}

struct QueueState {
    /// Arrival-order concatenation of all pending request rows.
    acc: RowBlock,
    waiters: Vec<Waiter>,
    /// Arrival time of the oldest pending request (deadline anchor).
    oldest: Option<Instant>,
    shutdown: bool,
    /// Set (under the lock, before the final `notify_all`) when the
    /// scorer thread exits — clean drain or fail-open. Gates
    /// [`Batcher::await_drained`].
    scorer_exited: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    /// Wakes the scorer on submission and shutdown, and `await_drained`
    /// callers on scorer exit.
    bell: Condvar,
    #[cfg(any(test, feature = "fault-injection"))]
    faults: Arc<super::faults::FaultPlan>,
}

/// The micro-batching coalescer. Clone-free: share it behind an `Arc`.
/// Dropping the batcher flushes and scores everything still pending, then
/// joins the scorer thread — no waiter is left hanging.
pub struct Batcher {
    shared: Arc<Shared>,
    session: Arc<Session>,
    stats: Arc<ServingStats>,
    flush_rows: usize,
    max_queue_rows: usize,
    quota_rows: usize,
    admission: Option<Arc<AdmissionControl>>,
    scorer: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn new(session: Arc<Session>, config: BatcherConfig) -> Batcher {
        Batcher::with_stats(session, config, Arc::new(ServingStats::new()))
    }

    /// As [`Batcher::new`], recording batch/queue counters into `stats`.
    /// The scoring pool is resolved from [`BatcherConfig::score_threads`]
    /// and owned by this batcher alone.
    pub fn with_stats(
        session: Arc<Session>,
        config: BatcherConfig,
        stats: Arc<ServingStats>,
    ) -> Batcher {
        let pool = config.resolve_score_pool();
        Batcher::with_scoring_pool(session, config, stats, pool)
    }

    /// As [`Batcher::with_admission`] without a shared admission budget.
    /// Score large flushes over `score_pool` when one is given (the
    /// registry shares one pool across all of its models' batchers),
    /// single-threaded otherwise. The pool must be dedicated to scoring —
    /// handing over a pool whose workers can block on serving requests
    /// (like the TCP connection pool) would deadlock.
    pub fn with_scoring_pool(
        session: Arc<Session>,
        config: BatcherConfig,
        stats: Arc<ServingStats>,
        score_pool: Option<Arc<WorkerPool>>,
    ) -> Batcher {
        Batcher::with_admission(session, config, stats, score_pool, None)
    }

    /// The most general constructor: everything [`Batcher::with_scoring_pool`]
    /// takes, plus an optional shared [`AdmissionControl`] charged on
    /// every submit (the registry hands the same controller to each of
    /// its batchers so the budget spans models).
    pub fn with_admission(
        session: Arc<Session>,
        config: BatcherConfig,
        stats: Arc<ServingStats>,
        score_pool: Option<Arc<WorkerPool>>,
        admission: Option<Arc<AdmissionControl>>,
    ) -> Batcher {
        let flush_rows = config.flush_rows.max(1).div_ceil(BLOCK_SIZE) * BLOCK_SIZE;
        let max_queue_rows = config.max_queue_rows.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                acc: session.new_block(),
                waiters: Vec::new(),
                oldest: None,
                shutdown: false,
                scorer_exited: false,
            }),
            bell: Condvar::new(),
            #[cfg(any(test, feature = "fault-injection"))]
            faults: Arc::new(super::faults::FaultPlan::new()),
        });
        let scorer = {
            let shared = Arc::clone(&shared);
            let session = Arc::clone(&session);
            let stats = Arc::clone(&stats);
            let admission = admission.clone();
            let max_delay = config.max_delay;
            let queue_deadline = config.queue_deadline;
            std::thread::Builder::new()
                .name("ydf-serving-scorer".to_string())
                .spawn(move || {
                    scorer_loop(
                        shared,
                        session,
                        stats,
                        flush_rows,
                        max_delay,
                        queue_deadline,
                        score_pool,
                        admission,
                    )
                })
                .expect("failed to spawn serving scorer thread")
        };
        Batcher {
            shared,
            session,
            stats,
            flush_rows,
            max_queue_rows,
            quota_rows: config.quota_rows,
            admission,
            scorer: Some(scorer),
        }
    }

    /// The session this batcher scores through.
    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    /// Counters shared with the scorer (queue depth, batch sizes).
    pub fn stats(&self) -> &Arc<ServingStats> {
        &self.stats
    }

    /// Rows pending at the threshold that triggers an immediate flush.
    pub fn flush_rows(&self) -> usize {
        self.flush_rows
    }

    /// Queue capacity in rows.
    pub fn capacity_rows(&self) -> usize {
        self.max_queue_rows
    }

    /// This batcher's fault-injection plan (chaos tests arm it; the hot
    /// path checks a few relaxed atomics per flush in test builds and
    /// does not exist otherwise).
    #[cfg(any(test, feature = "fault-injection"))]
    pub fn faults(&self) -> &Arc<super::faults::FaultPlan> {
        &self.shared.faults
    }

    /// Initiates shutdown without waiting: new submissions are rejected
    /// with [`SubmitError::Shutdown`] from this point on, while every
    /// already-accepted request is still scored and answered (the scorer's
    /// drain pass). Idempotent; `Drop` calls it and then joins the scorer.
    pub fn shutdown(&self) {
        // A poisoned lock must not stop the shutdown flag from being set
        // (submitters would keep queueing into a dead batcher): recover
        // the guard — the flag write is valid on any state.
        let mut state = match self.shared.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        state.shutdown = true;
        drop(state);
        self.shared.bell.notify_all();
    }

    /// Blocks until the scorer thread has exited — i.e. until the drain
    /// pass after [`Batcher::shutdown`] has answered every accepted
    /// request (or the scorer failed open). The registry's swap/unload
    /// path parks its detached drain thread here before marking the old
    /// generation `Retired`.
    pub fn await_drained(&self) {
        let mut state = match self.shared.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        while !state.scorer_exited {
            state = match self.shared.bell.wait(state) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    /// Enqueues every row of `rows` as one request, copied in arrival
    /// order into the shared accumulation block. Returns immediately —
    /// with a [`Pending`] handle, or with the backpressure error if the
    /// bounded queue (or a quota) cannot take the rows.
    pub fn submit(&self, rows: &RowBlock) -> Result<Pending, SubmitError> {
        let n = rows.rows();
        if n == 0 {
            return Err(SubmitError::EmptyRequest);
        }
        let hard_cap = if self.quota_rows > 0 {
            self.max_queue_rows.min(self.quota_rows)
        } else {
            self.max_queue_rows
        };
        if n > hard_cap {
            return Err(SubmitError::RequestTooLarge { rows: n, capacity: hard_cap });
        }
        let (tx, rx) = channel();
        {
            // A poisoned lock means the scorer thread panicked: the
            // batcher can never score again, which to a submitter is
            // indistinguishable from shutdown. Answering with an error —
            // instead of propagating the panic — keeps server workers
            // alive to deliver the error reply (serving/server.rs audit).
            let mut state = match self.shared.state.lock() {
                Ok(s) => s,
                Err(_) => return Err(SubmitError::Shutdown),
            };
            if state.shutdown {
                return Err(SubmitError::Shutdown);
            }
            let pending = state.acc.rows();
            if pending + n > self.max_queue_rows {
                self.stats.note_rejected();
                return Err(SubmitError::QueueFull {
                    pending_rows: pending,
                    capacity: self.max_queue_rows,
                });
            }
            if self.quota_rows > 0 && pending + n > self.quota_rows {
                self.stats.note_rejected();
                return Err(SubmitError::QuotaExceeded {
                    pending_rows: pending,
                    quota: self.quota_rows,
                });
            }
            if let Some(admission) = &self.admission {
                // Reserved here, released when a flush takes the rows
                // (scorer_loop) or the scorer fails open.
                if let Err((pending_rows, capacity)) = admission.try_reserve(n) {
                    self.stats.note_rejected();
                    return Err(SubmitError::AdmissionFull { pending_rows, capacity });
                }
            }
            state.acc.append_from(rows);
            state.waiters.push(Waiter { start_row: pending, rows: n, enqueued: Instant::now(), tx });
            if state.oldest.is_none() {
                state.oldest = Some(Instant::now());
            }
            self.stats.set_queue_rows(state.acc.rows());
        }
        self.shared.bell.notify_one();
        Ok(Pending { rx })
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.scorer.take() {
            let _ = h.join();
        }
    }
}

/// Best-effort text from a caught panic payload.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

#[allow(clippy::too_many_arguments)]
fn scorer_loop(
    shared: Arc<Shared>,
    session: Arc<Session>,
    stats: Arc<ServingStats>,
    flush_rows: usize,
    max_delay: Duration,
    queue_deadline: Duration,
    score_pool: Option<Arc<WorkerPool>>,
    admission: Option<Arc<AdmissionControl>>,
) {
    // If this thread unwinds past the scoring boundary (a lost scoped
    // job, a bug outside the catch_unwind below), fail open: mark
    // shutdown so later submissions get an error reply instead of
    // queueing forever, drop the queued waiters so their `Pending::wait`
    // returns the shutdown error instead of blocking on a channel nobody
    // will ever answer, and give the queued rows back to the shared
    // admission budget so the rest of the registry is not permanently
    // charged for them. On a clean exit the guard only records the
    // scorer's exit for `await_drained`: shutdown is already set and the
    // waiter list and queue are empty.
    struct FailOpen {
        shared: Arc<Shared>,
        admission: Option<Arc<AdmissionControl>>,
    }
    impl Drop for FailOpen {
        fn drop(&mut self) {
            // Recover a poisoned lock rather than skip: leaving the
            // waiters in place would hang their Pending::wait forever —
            // the exact wedge this guard exists to prevent. Every write
            // below is valid on any state.
            let mut state = match self.shared.state.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            state.shutdown = true;
            state.waiters.clear();
            if let Some(admission) = &self.admission {
                admission.release(state.acc.rows());
            }
            state.acc.clear();
            state.scorer_exited = true;
            drop(state);
            self.shared.bell.notify_all();
        }
    }
    let _fail_open = FailOpen { shared: Arc::clone(&shared), admission: admission.clone() };
    // Per-flush observability: counters labeled by the engine each flush
    // routes to. The session's router pins one engine per batch-size
    // bucket for its lifetime, so the counter handles are resolved once
    // per bucket up front (a metric update below stays one relaxed
    // fetch_add) and each flush picks its bucket's set by actual row
    // count — the per-flush engine timing record the router's calibration
    // tables are validated against in production.
    let flush_obs: Vec<(String, [crate::obs::Counter; 4])> = crate::inference::router::BUCKETS
        .iter()
        .map(|&rows| {
            let engine = session.engine_name_for_rows(rows);
            let m = crate::obs::metrics();
            let labels: &[(&str, &str)] = &[("engine", engine.as_str())];
            let counters = [
                m.counter_with("ydf_flush_total", "Batcher flushes scored, by engine.", labels),
                m.counter_with(
                    "ydf_flush_rows_total",
                    "Rows scored by batcher flushes, by engine.",
                    labels,
                ),
                m.counter_with(
                    "ydf_flush_blocks_total",
                    "Inference blocks scored by batcher flushes, by engine.",
                    labels,
                ),
                m.counter_with(
                    "ydf_flush_micros_total",
                    "Wall-clock microseconds spent scoring batcher flushes, by engine.",
                    labels,
                ),
            ];
            (engine, counters)
        })
        .collect();
    // Double buffer: while one block scores, submissions fill the other.
    // `spare` is moved into the queue at flush and recovered (cleared)
    // after scattering, so steady-state flushing allocates nothing.
    let mut spare = session.new_block();
    // Recent flush wall time (EWMA, ms): the basis of the shed replies'
    // retry_after_ms hint. `None` until the first flush completes — a
    // fabricated seed (the old `1.0`) made pre-first-flush sheds tell
    // clients to retry in ~2 ms even when real flushes take 100+ ms,
    // inviting a stampede exactly when the server is saturated. Until a
    // flush has been observed, the hint falls back to the configured
    // max_delay (the floor of any flush's end-to-end latency).
    let mut ewma_flush_ms: Option<f64> = None;
    let mut state = shared.state.lock().expect("serving queue poisoned");
    loop {
        // Wait for work or a flush condition. Spurious wakeups just
        // re-evaluate the conditions.
        loop {
            let pending = state.acc.rows();
            if state.shutdown {
                break; // flush the remainder, then exit below
            }
            if pending >= flush_rows {
                break;
            }
            if pending > 0 {
                let age = state.oldest.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
                if age >= max_delay {
                    break;
                }
                let (s, _timeout) = shared
                    .bell
                    .wait_timeout(state, max_delay - age)
                    .expect("serving queue poisoned");
                state = s;
            } else {
                state = shared.bell.wait(state).expect("serving queue poisoned");
            }
        }
        if state.acc.rows() == 0 {
            if state.shutdown {
                return;
            }
            continue;
        }
        // Take the whole pending batch; submissions continue concurrently
        // into the spare block while this one scores.
        let mut score_batch = std::mem::replace(&mut state.acc, spare);
        let mut waiters = std::mem::take(&mut state.waiters);
        state.oldest = None;
        let exiting = state.shutdown;
        stats.set_queue_rows(0);
        drop(state);
        // The rows now belong to this flush, not the queue: give them
        // back to the shared admission budget.
        if let Some(admission) = &admission {
            admission.release(score_batch.rows());
        }

        // Deadline shed pass: answer aged-out waiters with a retryable
        // error and re-pack the survivors (start_row-compacted) into a
        // fresh block. The exceptional path — it allocates; the common
        // all-on-time flush stays allocation-free.
        let mut retained: Option<RowBlock> = None;
        if queue_deadline > Duration::ZERO {
            let now = Instant::now();
            if waiters.iter().any(|w| now.duration_since(w.enqueued) > queue_deadline) {
                let retry_after_ms = match ewma_flush_ms {
                    Some(w) => (w * 2.0).clamp(1.0, 10_000.0).ceil() as u64,
                    // No flush observed yet: report the batching delay —
                    // honest (a retry cannot be answered sooner than one
                    // flush cycle) and stampede-free.
                    None => (max_delay.as_millis() as u64).clamp(1, 10_000),
                };
                let mut kept_block = session.new_block();
                let mut kept = Vec::with_capacity(waiters.len());
                let mut at = 0usize;
                for mut w in waiters {
                    let waited = now.duration_since(w.enqueued);
                    if waited > queue_deadline {
                        stats.note_shed();
                        let _ = w.tx.send(Err(ScoreError::Shed {
                            waited_ms: waited.as_millis() as u64,
                            retry_after_ms,
                        }));
                    } else {
                        kept_block.append_rows(&score_batch, w.start_row, w.rows);
                        w.start_row = at;
                        at += w.rows;
                        kept.push(w);
                    }
                }
                waiters = kept;
                retained = Some(std::mem::replace(&mut score_batch, kept_block));
            }
        }

        if !waiters.is_empty() {
            let dim = session.output_dim();
            let flushed_rows = score_batch.rows();
            let t_span = crate::obs::trace::begin();
            let t_flush = Instant::now();
            // Panic boundary: an engine panic mid-flush (or an injected
            // fault) must cost exactly this flush — in-band error replies
            // to its waiters — and nothing else. Large coalesced batches
            // fan block spans out across the scoring pool (bit-identical
            // to the single-call path); small ones score inline on this
            // thread. AssertUnwindSafe: on panic, `score_batch` is only
            // ever cleared afterwards, never read.
            let scored = catch_unwind(AssertUnwindSafe(|| {
                #[cfg(any(test, feature = "fault-injection"))]
                shared.faults.on_flush();
                session.predict_block_pooled(&mut score_batch, score_pool.as_deref())
            }));
            match scored {
                Ok(out) => {
                    stats.note_batch(score_batch.rows(), waiters.len());
                    for w in waiters {
                        let chunk = out[w.start_row * dim..(w.start_row + w.rows) * dim].to_vec();
                        // A submitter that dropped its Pending just
                        // doesn't collect.
                        let _ = w.tx.send(Ok(chunk));
                    }
                }
                Err(payload) => {
                    let why = panic_message(payload.as_ref());
                    for w in waiters {
                        let _ = w.tx.send(Err(ScoreError::Failed(format!(
                            "scoring failed: the engine panicked mid-flush ({why}); the \
                             request was not served — retry"
                        ))));
                    }
                }
            }
            let flush_us = t_flush.elapsed().as_secs_f64() * 1e6;
            let blocks = flushed_rows.div_ceil(crate::inference::BLOCK_SIZE);
            // Attribute the flush to the engine its row count routed to
            // (the same bucket predict_block_pooled just used).
            let bucket = crate::inference::router::bucket_index(flushed_rows);
            let (engine, counters) = &flush_obs[bucket];
            counters[0].inc();
            counters[1].add(flushed_rows as u64);
            counters[2].add(blocks as u64);
            counters[3].add(flush_us as u64);
            crate::obs::trace::end(t_span, "flush", || {
                use crate::obs::trace::ArgValue;
                vec![
                    ("engine", ArgValue::Str(engine.clone())),
                    ("rows", ArgValue::U64(flushed_rows as u64)),
                    ("blocks", ArgValue::U64(blocks as u64)),
                    ("us", ArgValue::F64(flush_us)),
                ]
            });
            let wall_ms = (flush_us / 1e3).max(0.01);
            ewma_flush_ms = Some(match ewma_flush_ms {
                // The first observation sets the level exactly; after
                // that the usual 0.7/0.3 smoothing tracks drift.
                None => wall_ms,
                Some(prev) => 0.7 * prev + 0.3 * wall_ms,
            });
        }
        // Restore the double buffer: when the shed pass swapped in a
        // fresh block, the original (larger) allocation is the one worth
        // keeping.
        let mut back = match retained {
            Some(original) => original,
            None => score_batch,
        };
        back.clear();
        spare = back;
        if exiting {
            // One drain pass under shutdown: anything submitted between
            // the flush and now still gets scored on the next iteration;
            // `submit` rejects new work once `shutdown` is set, so this
            // terminates.
            state = shared.state.lock().expect("serving queue poisoned");
            if state.acc.rows() == 0 {
                return;
            }
            continue;
        }
        state = shared.state.lock().expect("serving queue poisoned");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};
    use crate::utils::json::Json;

    fn session() -> Arc<Session> {
        let ds = synthetic::adult_like(300, 99);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 4;
        cfg.max_depth = 4;
        Arc::new(Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()))
    }

    fn one_row(s: &Session, age: f64) -> RowBlock {
        let mut b = s.new_block();
        let row = Json::parse(&format!(r#"{{"age": {age}, "education": "Masters"}}"#)).unwrap();
        s.decode_row(&mut b, &row).unwrap();
        b
    }

    #[test]
    fn single_request_scores_after_deadline() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { max_delay: Duration::from_millis(1), ..Default::default() },
        );
        let block = one_row(&s, 40.0);
        let out = b.submit(&block).unwrap().wait().unwrap();
        assert_eq!(out.len(), s.output_dim());
        assert!((out.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_delay_drains_immediately() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { max_delay: Duration::ZERO, ..Default::default() },
        );
        for _ in 0..3 {
            let block = one_row(&s, 33.0);
            let out = b.submit(&block).unwrap().wait().unwrap();
            assert_eq!(out.len(), s.output_dim());
        }
        assert!(b.stats().snapshot().batches >= 1);
    }

    #[test]
    fn flush_feeds_obs_metrics() {
        let s = session();
        let engine = s.engine_name();
        let labels: &[(&str, &str)] = &[("engine", engine.as_str())];
        let m = crate::obs::metrics();
        let flushes = m.counter_with("ydf_flush_total", "Batcher flushes scored, by engine.", labels);
        let rows = m.counter_with(
            "ydf_flush_rows_total",
            "Rows scored by batcher flushes, by engine.",
            labels,
        );
        let micros = m.counter_with(
            "ydf_flush_micros_total",
            "Wall-clock microseconds spent scoring batcher flushes, by engine.",
            labels,
        );
        let (flushes0, rows0) = (flushes.get(), rows.get());
        let _ = micros.get();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { max_delay: Duration::ZERO, ..Default::default() },
        );
        let mut block = s.new_block();
        for _ in 0..3 {
            block.append_from(&one_row(&s, 35.0));
        }
        b.submit(&block).unwrap().wait().unwrap();
        // Counters are process-global (other tests flush too), so assert
        // deltas as lower bounds on handles captured before the flush.
        assert!(flushes.get() >= flushes0 + 1, "flush counted");
        assert!(rows.get() >= rows0 + 3, "rows counted");
    }

    #[test]
    fn empty_and_oversized_requests_rejected() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { max_queue_rows: 4, ..Default::default() },
        );
        assert_eq!(b.submit(&s.new_block()).unwrap_err(), SubmitError::EmptyRequest);
        let mut big = s.new_block();
        for _ in 0..5 {
            big.append_from(&one_row(&s, 30.0));
        }
        assert!(matches!(
            b.submit(&big).unwrap_err(),
            SubmitError::RequestTooLarge { rows: 5, capacity: 4 }
        ));
    }

    #[test]
    fn flush_rows_rounds_up_to_block_multiple() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { flush_rows: 65, ..Default::default() },
        );
        assert_eq!(b.flush_rows(), 2 * crate::inference::BLOCK_SIZE);
    }

    #[test]
    fn pooled_flush_bit_identical_to_single_call() {
        let s = session();
        // A multi-block request forced through a 3-worker scoring pool
        // must not change a single bit vs the single-threaded score.
        let b = Batcher::with_scoring_pool(
            Arc::clone(&s),
            BatcherConfig { max_delay: Duration::ZERO, ..Default::default() },
            Arc::new(ServingStats::new()),
            Some(Arc::new(crate::utils::pool::WorkerPool::new(3))),
        );
        let mut big = s.new_block();
        for i in 0..201 {
            // Unaligned tail (201 = 3*64 + 9) and varied feature values.
            big.append_from(&one_row(&s, 20.0 + (i % 45) as f64));
        }
        let mut reference_block = s.new_block();
        reference_block.append_from(&big);
        let reference = s.predict_block(&mut reference_block);
        let out = b.submit(&big).unwrap().wait().unwrap();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&out), bits(&reference));
    }

    #[test]
    fn explicit_shutdown_rejects_new_and_drains_accepted() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig {
                max_delay: Duration::from_secs(30),
                flush_rows: 1024,
                ..Default::default()
            },
        );
        let pending = b.submit(&one_row(&s, 41.0)).unwrap();
        b.shutdown();
        assert_eq!(b.submit(&one_row(&s, 42.0)).unwrap_err(), SubmitError::Shutdown);
        // The accepted request is still scored by the drain pass.
        assert_eq!(pending.wait().unwrap().len(), s.output_dim());
    }

    #[test]
    fn drop_flushes_pending_requests() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            // Deadline far away, flush threshold unreachable: only the
            // shutdown drain can score this request.
            BatcherConfig {
                max_delay: Duration::from_secs(30),
                flush_rows: 1024,
                ..Default::default()
            },
        );
        let block = one_row(&s, 55.0);
        let pending = b.submit(&block).unwrap();
        drop(b);
        let out = pending.wait().unwrap();
        assert_eq!(out.len(), s.output_dim());
    }

    #[test]
    fn await_drained_returns_after_shutdown() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig {
                max_delay: Duration::from_secs(30),
                flush_rows: 1024,
                ..Default::default()
            },
        );
        let pending = b.submit(&one_row(&s, 44.0)).unwrap();
        b.shutdown();
        b.await_drained();
        // The drain completed before await_drained returned: the result
        // is already in the channel.
        assert_eq!(pending.wait().unwrap().len(), s.output_dim());
    }

    #[test]
    fn scorer_panic_answers_in_band_and_keeps_serving() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { max_delay: Duration::ZERO, ..Default::default() },
        );
        b.faults().arm_scorer_panics(1);
        let err = b.submit(&one_row(&s, 35.0)).unwrap().wait().unwrap_err();
        match err {
            ScoreError::Failed(why) => assert!(why.contains("panicked"), "{why}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(b.faults().fired_panics(), 1);
        // The batcher survives the panic: the very next flush scores.
        let out = b.submit(&one_row(&s, 36.0)).unwrap().wait().unwrap();
        assert_eq!(out.len(), s.output_dim());
    }

    #[test]
    fn queue_deadline_sheds_with_retry_hint() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig {
                max_delay: Duration::ZERO,
                queue_deadline: Duration::from_millis(20),
                ..Default::default()
            },
        );
        // Flush 1 (the first request) sleeps 200 ms in the scorer; the
        // second request queues behind it, ages past the 20 ms deadline,
        // and must be shed when flush 2 starts.
        b.faults().arm_flush_delay(1, 200);
        let p1 = b.submit(&one_row(&s, 30.0)).unwrap();
        std::thread::sleep(Duration::from_millis(50)); // flush 1 is now sleeping
        let p2 = b.submit(&one_row(&s, 31.0)).unwrap();
        assert_eq!(p1.wait().unwrap().len(), s.output_dim());
        match p2.wait().unwrap_err() {
            ScoreError::Shed { waited_ms, retry_after_ms } => {
                assert!(waited_ms >= 20, "waited {waited_ms} ms");
                assert!(retry_after_ms >= 1);
            }
            other => panic!("expected Shed, got {other:?}"),
        }
        assert_eq!(b.stats().snapshot().shed_deadline, 1);
        // Shedding is not shutdown: the batcher keeps serving.
        assert!(b.submit(&one_row(&s, 32.0)).unwrap().wait().is_ok());
    }

    #[test]
    fn shed_before_first_flush_hints_max_delay_not_a_fabricated_seed() {
        let s = session();
        // Flush threshold unreachable and a long batching delay: the one
        // submitted row waits out max_delay, and when its flush finally
        // starts it is already past the queue deadline — shed before any
        // flush has ever completed.
        let max_delay = Duration::from_millis(150);
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig {
                max_delay,
                flush_rows: 1024,
                queue_deadline: Duration::from_millis(10),
                ..Default::default()
            },
        );
        match b.submit(&one_row(&s, 30.0)).unwrap().wait().unwrap_err() {
            ScoreError::Shed { waited_ms, retry_after_ms } => {
                assert!(waited_ms >= 100, "waited {waited_ms} ms");
                // The old 1.0 ms EWMA seed produced a ~2 ms hint here; a
                // pre-first-flush shed must report the configured
                // batching delay instead.
                assert!(
                    retry_after_ms >= max_delay.as_millis() as u64,
                    "retry_after_ms = {retry_after_ms}"
                );
            }
            other => panic!("expected Shed, got {other:?}"),
        }
    }

    #[test]
    fn quota_and_admission_budget_reject_in_band() {
        let s = session();
        let admission = Arc::new(AdmissionControl::new(3));
        let cfg = BatcherConfig {
            max_delay: Duration::from_secs(30),
            flush_rows: 1024,
            max_queue_rows: 100,
            quota_rows: 2,
            ..Default::default()
        };
        let hot = Batcher::with_admission(
            Arc::clone(&s),
            cfg.clone(),
            Arc::new(ServingStats::new()),
            None,
            Some(Arc::clone(&admission)),
        );
        let neighbor = Batcher::with_admission(
            Arc::clone(&s),
            cfg,
            Arc::new(ServingStats::new()),
            None,
            Some(Arc::clone(&admission)),
        );
        // A request larger than the quota can never be accepted.
        let mut big = s.new_block();
        for _ in 0..3 {
            big.append_from(&one_row(&s, 30.0));
        }
        assert!(matches!(
            hot.submit(&big).unwrap_err(),
            SubmitError::RequestTooLarge { rows: 3, capacity: 2 }
        ));
        // The hot model fills its quota (2 rows), then is rejected —
        // while its neighbor still gets the remaining shared budget.
        let _h1 = hot.submit(&one_row(&s, 31.0)).unwrap();
        let _h2 = hot.submit(&one_row(&s, 32.0)).unwrap();
        assert!(matches!(
            hot.submit(&one_row(&s, 33.0)).unwrap_err(),
            SubmitError::QuotaExceeded { pending_rows: 2, quota: 2 }
        ));
        assert_eq!(hot.stats().snapshot().rejected, 1);
        let _n1 = neighbor.submit(&one_row(&s, 34.0)).unwrap();
        assert_eq!(admission.pending_rows(), 3);
        // The shared budget is now exhausted: the neighbor's next row is
        // rejected by admission, not by its (empty-ish) own queue.
        assert!(matches!(
            neighbor.submit(&one_row(&s, 35.0)).unwrap_err(),
            SubmitError::AdmissionFull { pending_rows: 3, capacity: 3 }
        ));
        // Draining gives the budget back.
        drop(hot);
        drop(neighbor);
        assert_eq!(admission.pending_rows(), 0);
        assert_eq!(_h1.wait().unwrap().len(), s.output_dim());
        assert_eq!(_n1.wait().unwrap().len(), s.output_dim());
    }
}
