//! Fleet routing tier: one logical serving endpoint over N backend
//! `ydf serve` processes.
//!
//! `ydf route --backend=host:port --backend=host:port … --port=…` binds a
//! TCP front end speaking the *same* newline-delimited JSON protocol as
//! `ydf serve` (`docs/serving.md`) and forwards each request to one of
//! the configured backends. This is the sharding/replication layer the
//! ROADMAP's "millions of users" item calls for: backends are plain
//! single-process servers; the router adds placement, health tracking
//! and failover on top, without touching the wire protocol clients speak.
//!
//! ## Placement: rendezvous hashing on the `"model"` field
//!
//! Each predict request hashes its top-level `"model"` string (absent ⇒
//! the default route, a stable sentinel key) through **rendezvous
//! (highest-random-weight) hashing**: every backend is scored with
//! `splitmix64(fnv1a(model) ^ fnv1a(backend_addr))` and the top
//! [`RouteConfig::replicas`] scores form the model's **replica set**, in
//! preference order ([`replica_order`]). Rendezvous hashing keeps the
//! mapping stable under membership change — a backend going down moves
//! only the models it hosted, never reshuffles the fleet — and needs no
//! coordination: every router instance computes the same answer.
//!
//! ## Health: probes and the per-backend state machine
//!
//! A prober thread sends `{"cmd": "health"}` to every backend each
//! [`RouteConfig::probe_interval`]; the data path reports per-hop
//! successes and failures as they happen. Both feed one per-backend
//! [`HealthFsm`]:
//!
//! ```text
//! Healthy -> Suspect -> Down -> Recovering -> Healthy
//!    ^---------/                    \--> Down (relapse)
//! ```
//!
//! `Healthy` and one strike (`Suspect`) stay routable — a single lost
//! packet must not evict a backend; the second consecutive failure goes
//! `Down` (unroutable). Only the *prober* can re-admit: a probe success
//! on a `Down` backend moves it to `Recovering`, and
//! [`RECOVERY_SUCCESSES`] consecutive successes restore `Healthy` — a
//! flapping backend is not trusted with traffic on its first good probe.
//!
//! ## Forwarding, retries and the budget
//!
//! Requests are relayed **verbatim**: the router forwards the client's
//! exact request line and relays the backend's exact reply line. Routed
//! responses are therefore byte-identical to direct ones, and a backend's
//! in-band reply — including an error or a shed carrying its own
//! `retry_after_ms` hint — is *final*: the router never rewrites it and
//! never overwrites the backend's hint with a front-end guess. Only
//! **transport** failures (connect/read/write timeout, reset, EOF
//! mid-reply) trigger failover: the request is retried on the next
//! routable replica with exponential backoff + deterministic jitter
//! ([`backoff_delay_ms`]), spending at most [`RouteConfig::retry_budget`]
//! retries. Predict requests are idempotent (scoring is pure), so
//! retrying is safe; non-idempotent admin commands (`load`/`swap`/
//! `unload`) are forwarded **once**, with no retry. When the budget is
//! exhausted — or every replica of a model is down — the router degrades
//! in band with the Shed reply shape:
//! `{"error": …, "retryable": true, "retry_after_ms": N}`, the hint
//! derived from the EWMA of observed hop latency (before the first
//! observation: the probe interval, never a fabricated seed).
//!
//! ## Draining a backend
//!
//! `{"cmd": "drain", "backend": "host:port"}` marks a backend
//! `Draining` (the PR-6 lifecycle vocabulary): it leaves every replica
//! set immediately, in-flight hops complete, and nothing is dropped —
//! the zero-drop removal path for maintenance. `undrain` reverses it.
//!
//! ## Observability
//!
//! Every hop is counted through the global `obs` registry —
//! `ydf_route_{forwarded,retries,failovers}_total{backend=,model=}`,
//! `ydf_route_shed_total{model=}`, `ydf_route_backend_up{backend=}`,
//! `ydf_route_backend_latency_us{backend=}` — so `{"cmd": "metrics"}`
//! on the router returns them inside the standard Prometheus exposition;
//! `{"cmd": "health"}` and `{"cmd": "stats"}` answer locally with a
//! `"router"` block (per-backend state, draining flag, forward/failure
//! counters, hop-latency EWMA). See `docs/serving.md` ("Fleet routing").

use crate::utils::json::Json;
use crate::utils::pool::WorkerPool;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Consecutive probe successes a `Down` backend must string together
/// (the first moves it to `Recovering`) before it is `Healthy` — and
/// routable — again.
pub const RECOVERY_SUCCESSES: u32 = 2;

/// Router configuration. Backends are `host:port` strings, exactly as
/// passed to `--backend=`; the address string is also the backend's
/// identity in hashing, metrics labels and `drain` commands.
#[derive(Clone, Debug)]
pub struct RouteConfig {
    /// Bind address; port 0 picks an ephemeral port (printed on stdout,
    /// same machine-parsable `listening on <addr>` line as `ydf serve`).
    pub addr: String,
    /// Worker threads for client connections (one connection occupies a
    /// worker until the peer disconnects, as in the server).
    pub workers: usize,
    /// Backend `host:port` addresses, in `--backend=` order.
    pub backends: Vec<String>,
    /// Read/write timeout on every accepted *client* connection
    /// (`None` = never reap). Same semantics as the server's
    /// `--conn-timeout`.
    pub conn_timeout: Option<Duration>,
    /// Bound on one backend dial.
    pub connect_timeout: Duration,
    /// Read/write timeout on one forwarded hop (request write + reply
    /// read). A backend that accepts but never answers is a transport
    /// failure at this deadline, triggering failover.
    pub hop_timeout: Duration,
    /// Health-probe period.
    pub probe_interval: Duration,
    /// Transport-failure retries one predict request may spend across
    /// replicas (total attempts = budget + 1). `0` disables failover.
    pub retry_budget: usize,
    /// Exponential-backoff base for the first retry, in ms.
    pub backoff_base_ms: u64,
    /// Backoff ceiling in ms.
    pub backoff_cap_ms: u64,
    /// Replica-set size per model; `0` resolves to
    /// `min(2, backends.len())`.
    pub replicas: usize,
    /// Hard cap on one client request line (same contract as
    /// `ServerConfig::max_line_bytes`).
    pub max_line_bytes: usize,
    /// Fault plan consulted once per forwarded hop (the forward-drop /
    /// forward-stall fault points). Test-only plumbing.
    #[cfg(any(test, feature = "fault-injection"))]
    pub faults: Option<Arc<super::faults::FaultPlan>>,
}

impl Default for RouteConfig {
    fn default() -> Self {
        RouteConfig {
            addr: "127.0.0.1:8200".to_string(),
            workers: 4,
            backends: Vec::new(),
            conn_timeout: Some(Duration::from_secs(60)),
            connect_timeout: Duration::from_secs(2),
            hop_timeout: Duration::from_secs(10),
            probe_interval: Duration::from_secs(1),
            retry_budget: 3,
            backoff_base_ms: 10,
            backoff_cap_ms: 500,
            replicas: 0,
            max_line_bytes: 16 << 20,
            #[cfg(any(test, feature = "fault-injection"))]
            faults: None,
        }
    }
}

/// Health of one backend as seen by this router.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Answering; routable.
    Healthy,
    /// One strike (a failed hop or probe); still routable — one lost
    /// packet must not evict a backend.
    Suspect,
    /// Two consecutive strikes; unroutable until the prober re-admits it.
    Down,
    /// A probe succeeded on a `Down` backend; unroutable until
    /// [`RECOVERY_SUCCESSES`] consecutive successes confirm it.
    Recovering,
}

impl HealthState {
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Healthy => "Healthy",
            HealthState::Suspect => "Suspect",
            HealthState::Down => "Down",
            HealthState::Recovering => "Recovering",
        }
    }
}

/// The per-backend health state machine. Pure — no clocks, no I/O:
/// callers feed it success/failure observations from probes and data-path
/// hops, and read [`HealthFsm::routable`]. Deterministically
/// unit-testable for exactly that reason.
#[derive(Debug)]
pub struct HealthFsm {
    state: HealthState,
    /// Consecutive successes while `Recovering`.
    streak: u32,
}

impl Default for HealthFsm {
    fn default() -> Self {
        HealthFsm::new()
    }
}

impl HealthFsm {
    /// Starts `Healthy`: backends are presumed good until observed
    /// otherwise, so a cold router routes immediately.
    pub fn new() -> HealthFsm {
        HealthFsm { state: HealthState::Healthy, streak: 0 }
    }

    pub fn state(&self) -> HealthState {
        self.state
    }

    /// Whether traffic may be placed on this backend. `Suspect` stays
    /// routable (one strike is noise); `Recovering` does not — a backend
    /// is not trusted with traffic until its streak completes.
    pub fn routable(&self) -> bool {
        matches!(self.state, HealthState::Healthy | HealthState::Suspect)
    }

    /// A successful probe or data-path hop.
    pub fn on_success(&mut self) {
        self.state = match self.state {
            HealthState::Healthy | HealthState::Suspect => HealthState::Healthy,
            HealthState::Down => {
                self.streak = 1;
                if RECOVERY_SUCCESSES <= 1 {
                    HealthState::Healthy
                } else {
                    HealthState::Recovering
                }
            }
            HealthState::Recovering => {
                self.streak += 1;
                if self.streak >= RECOVERY_SUCCESSES {
                    HealthState::Healthy
                } else {
                    HealthState::Recovering
                }
            }
        };
    }

    /// A failed probe or data-path hop (transport-level only — an
    /// in-band error reply is a *successful* hop).
    pub fn on_failure(&mut self) {
        self.streak = 0;
        self.state = match self.state {
            HealthState::Healthy => HealthState::Suspect,
            // Second consecutive strike, or a relapse mid-recovery.
            HealthState::Suspect | HealthState::Recovering | HealthState::Down => {
                HealthState::Down
            }
        };
    }
}

/// FNV-1a over bytes: the same dependency-free hash the artifact and
/// router-table checksums use, here as the rendezvous-hash ingredient.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// SplitMix64 finalizer: decorrelates the xor of two FNV hashes so
/// near-identical backend addresses (`…:8001` vs `…:8002`) still score
/// independently per model.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Rendezvous (highest-random-weight) hashing: scores every backend for
/// `model` and returns the indices of the top `replicas` scores, highest
/// first — the model's replica set in preference order. Computed over
/// the **full** backend list (health filtering happens at routing time),
/// so the mapping is stable across backend flaps: a backend going down
/// never reshuffles models it did not host.
pub fn replica_order(model: &str, backends: &[String], replicas: usize) -> Vec<usize> {
    let mh = fnv1a(model.as_bytes());
    let mut scored: Vec<(u64, usize)> = backends
        .iter()
        .enumerate()
        .map(|(i, addr)| (splitmix64(mh ^ fnv1a(addr.as_bytes())), i))
        .collect();
    // Highest score first; index breaks the (astronomically unlikely) tie
    // deterministically so every router instance agrees.
    scored.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    scored.into_iter().take(replicas.max(1)).map(|(_, i)| i).collect()
}

/// Backoff before retry number `attempt` (0-based): exponential
/// `base << attempt`, capped, with deterministic equal-jitter in
/// `[capped/2, capped]` drawn from `seed` — deterministic for a given
/// `(seed, attempt)`, which is what makes the retry schedule
/// unit-testable without a clock, while distinct request seeds still
/// de-synchronize a thundering herd.
pub fn backoff_delay_ms(attempt: u32, base_ms: u64, cap_ms: u64, seed: u64) -> u64 {
    let exp = base_ms
        .saturating_mul(1u64 << attempt.min(16))
        .min(cap_ms.max(base_ms.min(1)));
    let half = exp / 2;
    // Equal jitter: uniform in [half, exp].
    half + splitmix64(seed ^ (attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)) % (exp - half + 1)
}

/// What one forwarded request came to.
#[derive(Debug)]
pub enum ForwardOutcome {
    /// A backend answered (any in-band reply, error replies included —
    /// they are final, never retried).
    Reply {
        /// Index into the candidate list of the backend that answered.
        backend: usize,
        reply: String,
        /// Transport-failure retries spent getting here.
        retries: u32,
        /// True when the answering backend was not the first candidate —
        /// the request failed over.
        failover: bool,
    },
    /// The retry budget ran out with every attempt failing at the
    /// transport level.
    Exhausted { retries: u32, last_error: String },
    /// No routable replica existed to even try.
    AllDown,
}

/// The retry/failover core, parameterized over the actual hop (`hop(i)`
/// forwards to candidate `i` and returns the reply line or a transport
/// error) and the sleep — so unit tests inject a recording closure
/// instead of a wall clock and the schedule is checked deterministically.
///
/// Attempts cycle through `candidates` in preference order; every retry
/// first sleeps the deterministic backoff for its attempt number.
pub fn try_replicas<H, S>(
    candidates: &[usize],
    retry_budget: usize,
    base_ms: u64,
    cap_ms: u64,
    seed: u64,
    mut hop: H,
    mut sleep: S,
) -> ForwardOutcome
where
    H: FnMut(usize) -> Result<String, String>,
    S: FnMut(Duration),
{
    if candidates.is_empty() {
        return ForwardOutcome::AllDown;
    }
    let mut retries = 0u32;
    let mut last_error = String::new();
    for attempt in 0..=retry_budget {
        if attempt > 0 {
            retries += 1;
            sleep(Duration::from_millis(backoff_delay_ms(
                (attempt - 1) as u32,
                base_ms,
                cap_ms,
                seed,
            )));
        }
        let at = attempt % candidates.len();
        match hop(candidates[at]) {
            Ok(reply) => {
                return ForwardOutcome::Reply {
                    backend: candidates[at],
                    reply,
                    retries,
                    failover: at != 0,
                }
            }
            Err(e) => last_error = e,
        }
    }
    ForwardOutcome::Exhausted { retries, last_error }
}

/// Idle forward connections kept per backend beyond which extras are
/// dropped rather than pooled.
const IDLE_POOL_CAP: usize = 8;

/// Why one request/reply exchange failed: before the request was flushed
/// (`Unsent` — the backend never saw it, re-sending is always safe) or
/// after (`Sent` — delivery is unknown, re-sending risks executing a
/// non-idempotent command twice).
enum ExchangeFail {
    Unsent(String),
    Sent(String),
}

impl ExchangeFail {
    fn into_message(self) -> String {
        match self {
            ExchangeFail::Unsent(m) | ExchangeFail::Sent(m) => m,
        }
    }
}

/// One backend as this router sees it: address, health, drain flag,
/// pooled forward connections and hop telemetry.
struct Backend {
    addr: String,
    health: Mutex<HealthFsm>,
    /// Admin-requested removal from every replica set (`drain` command).
    /// Orthogonal to health: a draining backend may be perfectly
    /// `Healthy` — it is just not accepting placements.
    draining: AtomicBool,
    /// Idle pooled connections; one request-one reply framing means a
    /// returned connection never holds buffered leftovers.
    idle: Mutex<Vec<BufReader<TcpStream>>>,
    forwarded: AtomicU64,
    failures: AtomicU64,
    /// EWMA of successful hop wall time, ms. `None` until the first
    /// observation — the same `Option` discipline as the batcher's
    /// flush EWMA, so nothing downstream ever sees a fabricated seed.
    ewma_hop_ms: Mutex<Option<f64>>,
}

impl Backend {
    fn new(addr: String) -> Backend {
        Backend {
            addr,
            health: Mutex::new(HealthFsm::new()),
            draining: AtomicBool::new(false),
            idle: Mutex::new(Vec::new()),
            forwarded: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            ewma_hop_ms: Mutex::new(None),
        }
    }

    fn health(&self) -> std::sync::MutexGuard<'_, HealthFsm> {
        match self.health.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn state(&self) -> HealthState {
        self.health().state()
    }

    /// Placeable: routable by health and not admin-drained.
    fn accepts_placement(&self) -> bool {
        !self.draining.load(Ordering::SeqCst) && self.health().routable()
    }

    fn note_success(&self) {
        self.health().on_success();
    }

    fn note_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
        self.health().on_failure();
    }

    fn note_hop_ms(&self, ms: f64) {
        let mut g = match self.ewma_hop_ms.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *g = Some(match *g {
            Some(w) => 0.7 * w + 0.3 * ms,
            None => ms,
        });
    }

    fn ewma(&self) -> Option<f64> {
        match self.ewma_hop_ms.lock() {
            Ok(g) => *g,
            Err(poisoned) => *poisoned.into_inner(),
        }
    }

    fn pop_idle(&self) -> Option<BufReader<TcpStream>> {
        match self.idle.lock() {
            Ok(mut g) => g.pop(),
            Err(poisoned) => poisoned.into_inner().pop(),
        }
    }

    fn push_idle(&self, conn: BufReader<TcpStream>) {
        let mut g = match self.idle.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if g.len() < IDLE_POOL_CAP {
            g.push(conn);
        }
    }

    /// Fresh dial with bounded connect + hop deadlines.
    fn dial(&self, connect_timeout: Duration, hop_timeout: Duration) -> Result<BufReader<TcpStream>, String> {
        let addr: SocketAddr = self
            .addr
            .to_socket_addrs()
            .map_err(|e| format!("cannot resolve backend {}: {e}", self.addr))?
            .next()
            .ok_or_else(|| format!("backend address {} resolves to nothing", self.addr))?;
        let stream = TcpStream::connect_timeout(&addr, connect_timeout)
            .map_err(|e| format!("cannot connect to backend {}: {e}", self.addr))?;
        let _ = stream.set_read_timeout(Some(hop_timeout));
        let _ = stream.set_write_timeout(Some(hop_timeout));
        let _ = stream.set_nodelay(true);
        Ok(BufReader::new(stream))
    }

    /// One write-line / read-line exchange on an open connection.
    fn exchange(
        conn: &mut BufReader<TcpStream>,
        line: &str,
    ) -> Result<String, ExchangeFail> {
        {
            // &TcpStream implements Write; the BufReader keeps the read half.
            let mut w = conn.get_ref();
            writeln!(w, "{line}")
                .and_then(|_| w.flush())
                .map_err(|e| ExchangeFail::Unsent(format!("write: {e}")))?;
        }
        let mut reply = String::new();
        match conn.read_line(&mut reply) {
            Ok(0) => Err(ExchangeFail::Sent(
                "backend closed the connection before replying".to_string(),
            )),
            Ok(_) if !reply.ends_with('\n') => {
                Err(ExchangeFail::Sent("backend reply truncated mid-line".to_string()))
            }
            Ok(_) => Ok(reply.trim_end().to_string()),
            Err(e) => Err(ExchangeFail::Sent(format!("read: {e}"))),
        }
    }

    /// One hop: try a pooled connection first, then — only if the pooled
    /// write failed, i.e. the request never left this process — one fresh
    /// dial. A pooled failure *after* the request was flushed (read
    /// timeout, EOF mid-reply) is a hop failure: the backend may already
    /// be executing the request, so re-sending on a fresh dial could
    /// deliver a non-idempotent command twice within what `forward_once`
    /// treats as a single delivery, and a read timeout has already spent
    /// this hop's deadline. Success returns the connection to the pool.
    fn forward(&self, line: &str, connect_timeout: Duration, hop_timeout: Duration) -> Result<String, String> {
        if let Some(mut conn) = self.pop_idle() {
            match Self::exchange(&mut conn, line) {
                Ok(reply) => {
                    self.push_idle(conn);
                    return Ok(reply);
                }
                // Stale pooled connection caught before the request was
                // sent: drop it, fall through to a fresh dial before
                // charging this backend with a failure.
                Err(ExchangeFail::Unsent(_)) => {}
                Err(ExchangeFail::Sent(e)) => return Err(e),
            }
        }
        let mut conn = self.dial(connect_timeout, hop_timeout)?;
        let reply = Self::exchange(&mut conn, line).map_err(ExchangeFail::into_message)?;
        self.push_idle(conn);
        Ok(reply)
    }

    /// `{"cmd": "health"}` fragment for one backend.
    fn json(&self) -> Json {
        let mut j = Json::obj();
        j.set("addr", Json::Str(self.addr.clone()))
            .set("state", Json::Str(self.state().name().to_string()))
            .set("draining", Json::Bool(self.draining.load(Ordering::SeqCst)))
            .set("forwarded", Json::Num(self.forwarded.load(Ordering::Relaxed) as f64))
            .set("failures", Json::Num(self.failures.load(Ordering::Relaxed) as f64));
        match self.ewma() {
            Some(w) => j.set("ewma_hop_ms", Json::Num(w)),
            None => j.set("ewma_hop_ms", Json::Null),
        };
        j
    }
}

/// Stable hash key for requests with no `"model"` field (the default
/// route). Not a legal wire model name (names come from `--model=` /
/// admin commands and are never empty in practice), so it cannot collide
/// with a real model's replica set by accident in the metrics labels.
const DEFAULT_ROUTE_KEY: &str = "default";

/// Shared router state: the backend table plus routing knobs.
struct Router {
    backends: Vec<Arc<Backend>>,
    addrs: Vec<String>,
    replicas: usize,
    retry_budget: usize,
    backoff_base_ms: u64,
    backoff_cap_ms: u64,
    connect_timeout: Duration,
    hop_timeout: Duration,
    probe_interval: Duration,
    shutdown: Arc<AtomicBool>,
    /// Router-wide successful-hop EWMA (ms); the shed-hint source when
    /// the router must fabricate a `retry_after_ms` because no backend
    /// answered at all. `None` until the first successful hop — early
    /// sheds fall back to the probe interval (a real, configured clock)
    /// instead of a made-up seed.
    ewma_hop_ms: Mutex<Option<f64>>,
    /// Monotone per-request counter; seeds the deterministic retry
    /// jitter so concurrent exhausted requests de-synchronize.
    seq: AtomicU64,
    #[cfg(any(test, feature = "fault-injection"))]
    faults: Option<Arc<super::faults::FaultPlan>>,
}

impl Router {
    fn new(config: &RouteConfig, shutdown: Arc<AtomicBool>) -> Router {
        let backends: Vec<Arc<Backend>> =
            config.backends.iter().map(|a| Arc::new(Backend::new(a.clone()))).collect();
        Router {
            addrs: config.backends.clone(),
            backends,
            replicas: if config.replicas == 0 {
                config.backends.len().min(2).max(1)
            } else {
                config.replicas.min(config.backends.len().max(1))
            },
            retry_budget: config.retry_budget,
            backoff_base_ms: config.backoff_base_ms,
            backoff_cap_ms: config.backoff_cap_ms,
            connect_timeout: config.connect_timeout,
            hop_timeout: config.hop_timeout,
            probe_interval: config.probe_interval,
            shutdown,
            ewma_hop_ms: Mutex::new(None),
            seq: AtomicU64::new(0),
            #[cfg(any(test, feature = "fault-injection"))]
            faults: config.faults.clone(),
        }
    }

    fn backend_by_addr(&self, addr: &str) -> Option<&Arc<Backend>> {
        self.backends.iter().find(|b| b.addr == addr)
    }

    fn note_hop_ms(&self, ms: f64) {
        let mut g = match self.ewma_hop_ms.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *g = Some(match *g {
            Some(w) => 0.7 * w + 0.3 * ms,
            None => ms,
        });
    }

    /// The shed `retry_after_ms` hint: twice the observed hop EWMA,
    /// clamped sane; before any observation, the probe interval — the
    /// soonest a down backend could be re-admitted anyway.
    fn shed_hint_ms(&self) -> u64 {
        let observed = match self.ewma_hop_ms.lock() {
            Ok(g) => *g,
            Err(poisoned) => *poisoned.into_inner(),
        };
        match observed {
            Some(w) => (w * 2.0).clamp(1.0, 10_000.0).ceil() as u64,
            None => (self.probe_interval.as_millis() as u64).clamp(1, 10_000),
        }
    }

    /// The model's replica candidates that currently accept placement,
    /// in preference order.
    fn routable_candidates(&self, model: &str) -> Vec<usize> {
        replica_order(model, &self.addrs, self.replicas)
            .into_iter()
            .filter(|&i| self.backends[i].accepts_placement())
            .collect()
    }

    /// Forwards `line` for `model` with retry/failover; returns the
    /// reply line to relay (verbatim on success, a router-fabricated
    /// shed otherwise).
    fn forward_predict(&self, model: &str, line: &str) -> String {
        let candidates = self.routable_candidates(model);
        let seed = splitmix64(self.seq.fetch_add(1, Ordering::Relaxed) ^ fnv1a(model.as_bytes()));
        let outcome = try_replicas(
            &candidates,
            self.retry_budget,
            self.backoff_base_ms,
            self.backoff_cap_ms,
            seed,
            |i| self.hop(i, line),
            |d| std::thread::sleep(d),
        );
        match outcome {
            ForwardOutcome::Reply { backend, reply, retries, failover } => {
                let b = &self.backends[backend];
                let m = crate::obs::metrics();
                m.counter_with(
                    "ydf_route_forwarded_total",
                    "Requests forwarded to a backend by the routing tier.",
                    &[("backend", &b.addr), ("model", model)],
                )
                .inc();
                if retries > 0 {
                    m.counter_with(
                        "ydf_route_retries_total",
                        "Transport-failure retries spent by the routing tier.",
                        &[("backend", &b.addr), ("model", model)],
                    )
                    .add(retries as u64);
                }
                if failover {
                    m.counter_with(
                        "ydf_route_failovers_total",
                        "Requests answered by a non-primary replica after failover.",
                        &[("backend", &b.addr), ("model", model)],
                    )
                    .inc();
                }
                reply
            }
            ForwardOutcome::Exhausted { retries, last_error } => {
                self.shed(model, retries, &format!(
                    "no replica of model '{model}' answered within the retry budget \
                     ({retries} retries; last error: {last_error})"
                ))
            }
            ForwardOutcome::AllDown => self.shed(model, 0, &format!(
                "all replicas of model '{model}' are down or draining"
            )),
        }
    }

    /// Router-fabricated degradation reply, reusing the Shed shape the
    /// batcher's queue deadline uses — clients handle one contract.
    /// Only reached when *no* backend produced a reply; a backend's own
    /// shed rides through `forward_predict` verbatim, hint and all.
    fn shed(&self, model: &str, retries: u32, message: &str) -> String {
        crate::obs::metrics()
            .counter_with(
                "ydf_route_shed_total",
                "Requests shed by the routing tier (no replica answered).",
                &[("model", model)],
            )
            .inc();
        if retries > 0 {
            crate::obs::metrics()
                .counter_with(
                    "ydf_route_retries_total",
                    "Transport-failure retries spent by the routing tier.",
                    &[("backend", "none"), ("model", model)],
                )
                .add(retries as u64);
        }
        let hint = self.shed_hint_ms();
        let mut j = Json::obj();
        j.set("error", Json::Str(format!("{message}; retry in {hint} ms")))
            .set("retryable", Json::Bool(true))
            .set("retry_after_ms", Json::Num(hint as f64));
        j.to_string()
    }

    /// One hop to backend `i`, feeding health and latency telemetry.
    fn hop(&self, i: usize, line: &str) -> Result<String, String> {
        #[cfg(any(test, feature = "fault-injection"))]
        if let Some(f) = &self.faults {
            if f.on_forward() {
                self.backends[i].note_failure();
                return Err("fault-injection: forward blackholed".to_string());
            }
        }
        let b = &self.backends[i];
        let t0 = Instant::now();
        match b.forward(line, self.connect_timeout, self.hop_timeout) {
            Ok(reply) => {
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                b.forwarded.fetch_add(1, Ordering::Relaxed);
                b.note_success();
                b.note_hop_ms(ms);
                self.note_hop_ms(ms);
                crate::obs::metrics()
                    .gauge_with(
                        "ydf_route_backend_latency_us",
                        "EWMA of successful hop latency per backend, in microseconds.",
                        &[("backend", &b.addr)],
                    )
                    .set((b.ewma().unwrap_or(0.0).max(0.0) * 1000.0).round() as u64);
                Ok(reply)
            }
            Err(e) => {
                b.note_failure();
                Err(format!("backend {}: {e}", b.addr))
            }
        }
    }

    /// Forwards a non-idempotent (or unknown) command exactly once to
    /// the first routable replica for `model`; no retry — a `load` that
    /// timed out may still have happened.
    fn forward_once(&self, model: &str, line: &str) -> String {
        let candidates = self.routable_candidates(model);
        let Some(&first) = candidates.first() else {
            return self.shed(model, 0, &format!(
                "all replicas of model '{model}' are down or draining"
            ));
        };
        match self.hop(first, line) {
            Ok(reply) => {
                crate::obs::metrics()
                    .counter_with(
                        "ydf_route_forwarded_total",
                        "Requests forwarded to a backend by the routing tier.",
                        &[("backend", &self.backends[first].addr), ("model", model)],
                    )
                    .inc();
                reply
            }
            Err(e) => {
                let mut j = Json::obj();
                j.set("error", Json::Str(format!(
                    "cannot forward command to backend: {e} (commands are never retried; \
                     re-issue once the backend recovers, or address it directly)"
                )));
                j.to_string()
            }
        }
    }

    /// The `"router"` block for `health`/`stats` replies.
    fn router_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("backends", Json::Arr(self.backends.iter().map(|b| b.json()).collect()))
            .set("replicas", Json::Num(self.replicas as f64))
            .set("retry_budget", Json::Num(self.retry_budget as f64))
            .set("probe_interval_ms", Json::Num(self.probe_interval.as_millis() as f64));
        match self.ewma_hop_ms.lock().map(|g| *g).unwrap_or(None) {
            Some(w) => j.set("ewma_hop_ms", Json::Num(w)),
            None => j.set("ewma_hop_ms", Json::Null),
        };
        j
    }

    /// One client request line → (reply line, stop flag). Local
    /// commands answer here; predict requests forward with failover;
    /// other commands forward once.
    fn respond(&self, line: &str) -> (String, bool) {
        let request = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                let mut j = Json::obj();
                j.set("error", Json::Str(format!("invalid JSON: {e}")));
                return (j.to_string(), false);
            }
        };
        // Admin commands carry a "path" key (the server's admin shape is
        // cmd/model/path), so the general reserved gate below would miss
        // them and drop them into the *retried* predict path — dispatch
        // them by name first, mirroring the server's own admin dispatch.
        if matches!(request.get("cmd").and_then(|c| c.as_str()), Some("load" | "swap" | "unload")) {
            let admin_shape = matches!(&request, Json::Obj(m)
                if m.keys().all(|k| k == "cmd" || k == "model" || k == "path"));
            if admin_shape {
                let model = request
                    .get("model")
                    .and_then(|m| m.as_str())
                    .unwrap_or(DEFAULT_ROUTE_KEY);
                return (self.forward_once(model, line), false);
            }
        }
        // Router-local commands use the same reserved-keys-only shape
        // discipline as the server's admin dispatch: only a strict
        // command object short-circuits here; anything else routes.
        if let Some(cmd) = request.get("cmd").and_then(|c| c.as_str()) {
            let reserved_only = matches!(&request, Json::Obj(m)
                if m.keys().all(|k| k == "cmd" || k == "model" || k == "backend"));
            if reserved_only {
                match cmd {
                    "health" => {
                        let mut j = Json::obj();
                        j.set("ok", Json::Bool(true)).set("router", self.router_json());
                        return (j.to_string(), false);
                    }
                    "stats" => {
                        let mut j = Json::obj();
                        j.set("router", self.router_json());
                        return (j.to_string(), false);
                    }
                    "metrics" => {
                        let mut j = Json::obj();
                        j.set(
                            "content_type",
                            Json::Str("text/plain; version=0.0.4".to_string()),
                        )
                        .set("metrics", Json::Str(crate::obs::prom::render_global()));
                        return (j.to_string(), false);
                    }
                    "shutdown" => {
                        // Stops the *router* only: backends belong to
                        // their own operators.
                        let mut j = Json::obj();
                        j.set("ok", Json::Bool(true));
                        return (j.to_string(), true);
                    }
                    "drain" | "undrain" => {
                        return (self.drain_cmd(cmd, &request), false);
                    }
                    // spec/load/swap/unload and anything else the
                    // backends know: forward once, no retry.
                    _ => {
                        let model = request
                            .get("model")
                            .and_then(|m| m.as_str())
                            .unwrap_or(DEFAULT_ROUTE_KEY);
                        return (self.forward_once(model, line), false);
                    }
                }
            }
        }
        // Predict request (canonical rows form, or the bare shorthand):
        // idempotent, forwarded with retry/failover. The "model" field
        // is only routing-relevant in protocol form, mirroring the
        // server's dispatch precedence.
        let in_protocol_form = request.get("rows").is_some() || request.get("cmd").is_some();
        let model = match request.get("model") {
            Some(Json::Str(m)) if in_protocol_form => m.as_str(),
            _ => DEFAULT_ROUTE_KEY,
        };
        (self.forward_predict(model, line), false)
    }

    /// `drain`/`undrain`: flips one backend's placement flag. Zero-drop
    /// by construction — in-flight hops hold their connection and
    /// complete; the backend merely stops receiving *new* placements.
    fn drain_cmd(&self, cmd: &str, request: &Json) -> String {
        let Some(addr) = request.get("backend").and_then(|b| b.as_str()) else {
            let mut j = Json::obj();
            j.set("error", Json::Str(format!(
                "'{cmd}' needs a \"backend\" field naming a configured backend \
                 (configured: {})",
                self.addrs.join(", ")
            )));
            return j.to_string();
        };
        let Some(b) = self.backend_by_addr(addr) else {
            let mut j = Json::obj();
            j.set("error", Json::Str(format!(
                "unknown backend '{addr}'. Configured backends: {}.",
                self.addrs.join(", ")
            )));
            return j.to_string();
        };
        let draining = cmd == "drain";
        b.draining.store(draining, Ordering::SeqCst);
        let mut j = Json::obj();
        j.set("ok", Json::Bool(true))
            .set("cmd", Json::Str(cmd.to_string()))
            .set("backend", Json::Str(addr.to_string()))
            // The PR-6 lifecycle vocabulary: a draining backend reads
            // exactly like a draining model generation.
            .set("state", Json::Str(if draining { "Draining" } else { "Serving" }.to_string()));
        j.to_string()
    }

    /// One probe pass over every backend: fresh dial + `{"cmd":"health"}`.
    /// The only path that can re-admit a `Down` backend.
    fn probe_all(&self) {
        for b in &self.backends {
            let ok = b
                .dial(self.connect_timeout, self.hop_timeout)
                .ok()
                .and_then(|mut conn| Backend::exchange(&mut conn, r#"{"cmd": "health"}"#).ok())
                .and_then(|reply| Json::parse(&reply).ok())
                .map(|j| j.get("ok") == Some(&Json::Bool(true)))
                .unwrap_or(false);
            if ok {
                b.note_success();
            } else {
                // note_failure, not a bare FSM poke: probe failures must
                // show in the per-backend "failures" counter too.
                b.note_failure();
            }
            crate::obs::metrics()
                .gauge_with(
                    "ydf_route_backend_up",
                    "1 when the backend is routable (Healthy/Suspect), else 0.",
                    &[("backend", &b.addr)],
                )
                .set(u64::from(b.health().routable()));
        }
    }
}

/// Binds, prints `listening on <addr>` (the same machine-parsable line
/// as `ydf serve`), and routes until a `{"cmd": "shutdown"}` arrives.
/// See the module docs for the full routing contract.
pub fn route(config: &RouteConfig) -> Result<(), String> {
    if config.backends.is_empty() {
        return Err("cannot route without backends: pass at least one --backend=host:port"
            .to_string());
    }
    let listener = TcpListener::bind(&config.addr)
        .map_err(|e| format!("cannot bind {}: {e}", config.addr))?;
    let local = listener
        .local_addr()
        .map_err(|e| format!("cannot resolve bound address: {e}"))?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let router = Arc::new(Router::new(config, Arc::clone(&shutdown)));
    for b in &router.backends {
        println!("routing to backend {}", b.addr);
    }
    println!(
        "router: {} backend(s), {} replica(s) per model, retry budget {}",
        router.backends.len(),
        router.replicas,
        router.retry_budget
    );
    println!("listening on {local}");

    // Prober: periodic health checks; sleeps in short slices so shutdown
    // is prompt even with a long probe interval.
    let prober = {
        let router = Arc::clone(&router);
        let shutdown = Arc::clone(&shutdown);
        std::thread::Builder::new()
            .name("ydf-route-prober".to_string())
            .spawn(move || {
                while !shutdown.load(Ordering::SeqCst) {
                    router.probe_all();
                    let mut left = router.probe_interval;
                    while !left.is_zero() && !shutdown.load(Ordering::SeqCst) {
                        let step = left.min(Duration::from_millis(50));
                        std::thread::sleep(step);
                        left = left.saturating_sub(step);
                    }
                }
            })
            .map_err(|e| format!("cannot spawn prober thread: {e}"))?
    };

    // Client-connection registry + worker pool: the same shutdown
    // discipline as serve_shared (close read halves to unpark workers).
    let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
    let next_conn = AtomicU64::new(0);
    let pool = WorkerPool::new(config.workers.max(1));
    let loads: Arc<Vec<AtomicUsize>> =
        Arc::new((0..pool.num_workers()).map(|_| AtomicUsize::new(0)).collect());
    let max_line_bytes = config.max_line_bytes.max(1);
    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connection from the shutdown handler
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let _ = stream.set_read_timeout(config.conn_timeout);
        let _ = stream.set_write_timeout(config.conn_timeout);
        let id = next_conn.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            let mut g = match conns.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            g.insert(id, clone);
        }
        let w = loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap_or(0);
        loads[w].fetch_add(1, Ordering::Relaxed);
        let router = Arc::clone(&router);
        let shutdown = Arc::clone(&shutdown);
        let conns2 = Arc::clone(&conns);
        let loads2 = Arc::clone(&loads);
        pool.submit_to(w, move || {
            handle_client(&router, stream, &shutdown, local, max_line_bytes);
            let mut g = match conns2.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            g.remove(&id);
            drop(g);
            loads2[w].fetch_sub(1, Ordering::Relaxed);
        });
    }
    {
        let mut g = match conns.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        for (_, s) in g.drain() {
            // Read half only: unblocks parked workers, lets in-flight
            // replies finish writing.
            let _ = s.shutdown(Shutdown::Read);
        }
    }
    drop(pool); // join workers
    let _ = prober.join();
    println!("router stopped");
    Ok(())
}

/// One client connection: Take-bounded line reads (the server's
/// overlong/timeout discipline), one routed reply per line.
fn handle_client(
    router: &Router,
    stream: TcpStream,
    shutdown: &AtomicBool,
    wake_addr: SocketAddr,
    max_line_bytes: usize,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    let cap = max_line_bytes as u64;
    loop {
        buf.clear();
        match reader.by_ref().take(cap + 1).read_until(b'\n', &mut buf) {
            Ok(0) => return, // EOF: peer closed cleanly
            Ok(_) => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                let mut j = Json::obj();
                j.set(
                    "error",
                    Json::Str(
                        "connection timed out waiting for a complete request line; \
                         closing (reconnect to continue)"
                            .to_string(),
                    ),
                );
                let _ = writeln!(writer, "{j}").and_then(|_| writer.flush());
                return;
            }
            Err(_) => return,
        }
        if buf.len() as u64 > cap && !buf.ends_with(b"\n") {
            let mut j = Json::obj();
            j.set(
                "error",
                Json::Str(format!(
                    "request line exceeds max_line_bytes ({max_line_bytes} bytes); \
                     closing connection"
                )),
            );
            let _ = writeln!(writer, "{j}").and_then(|_| writer.flush());
            return;
        }
        let line = match std::str::from_utf8(&buf) {
            Ok(s) => s,
            Err(e) => {
                let mut j = Json::obj();
                j.set("error", Json::Str(format!("request line is not valid UTF-8: {e}")));
                if writeln!(writer, "{j}").and_then(|_| writer.flush()).is_err() {
                    return;
                }
                continue;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let (reply, stop) = router.respond(line.trim_end());
        if writeln!(writer, "{reply}").and_then(|_| writer.flush()).is_err() {
            return;
        }
        if stop {
            shutdown.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(wake_addr);
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_fsm_walks_the_full_cycle() {
        let mut f = HealthFsm::new();
        assert_eq!(f.state(), HealthState::Healthy);
        assert!(f.routable());

        // One strike: Suspect, still routable.
        f.on_failure();
        assert_eq!(f.state(), HealthState::Suspect);
        assert!(f.routable());
        // A success clears the strike.
        f.on_success();
        assert_eq!(f.state(), HealthState::Healthy);

        // Two consecutive strikes: Down, unroutable.
        f.on_failure();
        f.on_failure();
        assert_eq!(f.state(), HealthState::Down);
        assert!(!f.routable());
        // Further failures keep it Down.
        f.on_failure();
        assert_eq!(f.state(), HealthState::Down);

        // First probe success: Recovering — still unroutable.
        f.on_success();
        assert_eq!(f.state(), HealthState::Recovering);
        assert!(!f.routable());
        // Relapse mid-recovery drops straight back to Down.
        f.on_failure();
        assert_eq!(f.state(), HealthState::Down);

        // Full recovery: RECOVERY_SUCCESSES consecutive successes.
        for _ in 0..RECOVERY_SUCCESSES {
            f.on_success();
        }
        assert_eq!(f.state(), HealthState::Healthy);
        assert!(f.routable());
    }

    #[test]
    fn replica_order_is_deterministic_stable_and_distinct() {
        let backends: Vec<String> =
            (0..5).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect();

        // Deterministic: two computations agree.
        let a = replica_order("fraud", &backends, 2);
        assert_eq!(a, replica_order("fraud", &backends, 2));
        assert_eq!(a.len(), 2);
        assert_ne!(a[0], a[1]);
        assert!(a.iter().all(|&i| i < backends.len()));

        // The replica set is a prefix of the full preference order:
        // growing the set never reorders existing replicas.
        let full = replica_order("fraud", &backends, backends.len());
        assert_eq!(full.len(), backends.len());
        assert_eq!(&full[..2], &a[..]);

        // Rendezvous stability: removing a backend that was NOT in a
        // model's top set leaves the model's placement unchanged
        // (recompute over the survivors and map indices back by addr).
        let dropped = full[full.len() - 1]; // the least-preferred backend
        let survivors: Vec<String> =
            backends.iter().enumerate().filter(|&(i, _)| i != dropped).map(|(_, b)| b.clone()).collect();
        let after = replica_order("fraud", &survivors, 2);
        let after_addrs: Vec<&String> = after.iter().map(|&i| &survivors[i]).collect();
        let before_addrs: Vec<&String> = a.iter().map(|&i| &backends[i]).collect();
        assert_eq!(before_addrs, after_addrs);

        // Different models spread: over many models, more than one
        // backend gets a primary slot.
        let mut primaries: std::collections::HashSet<usize> = std::collections::HashSet::new();
        for m in 0..32 {
            primaries.insert(replica_order(&format!("model_{m}"), &backends, 2)[0]);
        }
        assert!(primaries.len() > 1, "rendezvous hashing never spread primaries");
    }

    #[test]
    fn backoff_is_deterministic_bounded_and_grows() {
        // Deterministic for a given (seed, attempt).
        for attempt in 0..6 {
            assert_eq!(
                backoff_delay_ms(attempt, 10, 500, 42),
                backoff_delay_ms(attempt, 10, 500, 42)
            );
        }
        // Equal-jitter bounds: [capped/2, capped].
        for seed in 0..50u64 {
            for attempt in 0..8 {
                let exp = 10u64.saturating_mul(1 << attempt).min(500);
                let d = backoff_delay_ms(attempt, 10, 500, seed);
                assert!(d >= exp / 2 && d <= exp, "attempt {attempt} seed {seed}: {d}");
            }
        }
        // The cap holds even for absurd attempt numbers (no shift overflow).
        assert!(backoff_delay_ms(63, 10, 500, 7) <= 500);
        // Different seeds de-synchronize at least sometimes.
        let spread: std::collections::HashSet<u64> =
            (0..20).map(|s| backoff_delay_ms(3, 10, 500, s)).collect();
        assert!(spread.len() > 1);
    }

    #[test]
    fn try_replicas_first_hop_success_spends_nothing() {
        let mut sleeps: Vec<Duration> = Vec::new();
        let outcome = try_replicas(
            &[2, 0, 1],
            3,
            10,
            500,
            7,
            |i| {
                assert_eq!(i, 2, "first candidate must be tried first");
                Ok("reply".to_string())
            },
            |d| sleeps.push(d),
        );
        match outcome {
            ForwardOutcome::Reply { backend, reply, retries, failover } => {
                assert_eq!(backend, 2);
                assert_eq!(reply, "reply");
                assert_eq!(retries, 0);
                assert!(!failover);
            }
            other => panic!("expected Reply, got {other:?}"),
        }
        assert!(sleeps.is_empty(), "no backoff on a first-hop success");
    }

    #[test]
    fn try_replicas_fails_over_with_deterministic_backoff() {
        let mut sleeps: Vec<u64> = Vec::new();
        let mut hops: Vec<usize> = Vec::new();
        let outcome = try_replicas(
            &[0, 1],
            3,
            10,
            500,
            99,
            |i| {
                hops.push(i);
                if hops.len() < 3 {
                    Err("connect refused".to_string())
                } else {
                    Ok("late reply".to_string())
                }
            },
            |d| sleeps.push(d.as_millis() as u64),
        );
        match outcome {
            ForwardOutcome::Reply { backend, reply, retries, failover } => {
                // Attempts cycle 0, 1, 0: the third lands back on 0.
                assert_eq!(backend, 0);
                assert_eq!(reply, "late reply");
                assert_eq!(retries, 2);
                assert!(!failover, "candidate 0 answered: primary, not a failover");
            }
            other => panic!("expected Reply, got {other:?}"),
        }
        assert_eq!(hops, vec![0, 1, 0]);
        // The recorded schedule is exactly the deterministic backoff.
        assert_eq!(
            sleeps,
            vec![backoff_delay_ms(0, 10, 500, 99), backoff_delay_ms(1, 10, 500, 99)]
        );

        // Second hop answering marks a failover.
        let outcome = try_replicas(
            &[0, 1],
            1,
            0,
            0,
            1,
            |i| if i == 0 { Err("down".into()) } else { Ok("standby".into()) },
            |_| {},
        );
        assert!(matches!(
            outcome,
            ForwardOutcome::Reply { backend: 1, retries: 1, failover: true, .. }
        ));
    }

    #[test]
    fn try_replicas_exhausts_budget_and_reports_all_down() {
        let mut attempts = 0usize;
        let outcome = try_replicas(
            &[0, 1, 2],
            2,
            0,
            0,
            5,
            |_| {
                attempts += 1;
                Err(format!("fail {attempts}"))
            },
            |_| {},
        );
        match outcome {
            ForwardOutcome::Exhausted { retries, last_error } => {
                assert_eq!(retries, 2);
                assert_eq!(attempts, 3, "budget 2 = 3 total attempts");
                assert_eq!(last_error, "fail 3");
            }
            other => panic!("expected Exhausted, got {other:?}"),
        }
        assert!(matches!(
            try_replicas(&[], 5, 0, 0, 0, |_| Ok(String::new()), |_| {}),
            ForwardOutcome::AllDown
        ));
    }

    #[test]
    fn shed_hint_follows_the_option_ewma_discipline() {
        let config = RouteConfig {
            backends: vec!["127.0.0.1:1".to_string()],
            probe_interval: Duration::from_millis(250),
            ..Default::default()
        };
        let router = Router::new(&config, Arc::new(AtomicBool::new(false)));
        // Before any observation: the configured probe interval, never a
        // fabricated EWMA seed.
        assert_eq!(router.shed_hint_ms(), 250);
        // After observations: twice the EWMA, clamped sane.
        router.note_hop_ms(8.0);
        assert_eq!(router.shed_hint_ms(), 16);
        router.note_hop_ms(8.0); // ewma stays 8.0
        assert_eq!(router.shed_hint_ms(), 16);
        router.note_hop_ms(100_000.0);
        assert_eq!(router.shed_hint_ms(), 10_000, "hint is clamped to 10s");
    }

    #[test]
    fn drain_undrain_flip_placement_and_unknown_backend_errors() {
        let config = RouteConfig {
            backends: vec!["127.0.0.1:9101".to_string(), "127.0.0.1:9102".to_string()],
            ..Default::default()
        };
        let router = Router::new(&config, Arc::new(AtomicBool::new(false)));
        assert!(router.backends[0].accepts_placement());

        let reply = router.drain_cmd("drain", &Json::parse(
            r#"{"cmd": "drain", "backend": "127.0.0.1:9101"}"#).unwrap());
        let j = Json::parse(&reply).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.req_str("state").unwrap(), "Draining");
        assert!(!router.backends[0].accepts_placement());
        assert!(router.backends[1].accepts_placement());
        // A drained backend leaves every replica set.
        for m in 0..8 {
            for &i in &router.routable_candidates(&format!("m{m}")) {
                assert_ne!(i, 0);
            }
        }

        let reply = router.drain_cmd("undrain", &Json::parse(
            r#"{"cmd": "undrain", "backend": "127.0.0.1:9101"}"#).unwrap());
        assert_eq!(Json::parse(&reply).unwrap().req_str("state").unwrap(), "Serving");
        assert!(router.backends[0].accepts_placement());

        let reply = router.drain_cmd("drain", &Json::parse(
            r#"{"cmd": "drain", "backend": "nope:1"}"#).unwrap());
        assert!(Json::parse(&reply).unwrap().req_str("error").unwrap().contains("unknown backend"));
        let reply = router.drain_cmd("drain", &Json::parse(r#"{"cmd": "drain"}"#).unwrap());
        assert!(Json::parse(&reply).unwrap().req_str("error").unwrap().contains("backend"));
    }

    #[test]
    fn respond_sheds_in_band_when_every_replica_is_down() {
        let config = RouteConfig {
            backends: vec!["127.0.0.1:9201".to_string()],
            retry_budget: 0,
            ..Default::default()
        };
        let router = Router::new(&config, Arc::new(AtomicBool::new(false)));
        // Mark the only backend Down (two strikes).
        router.backends[0].note_failure();
        router.backends[0].note_failure();
        assert_eq!(router.backends[0].state(), HealthState::Down);

        let (reply, stop) = router.respond(r#"{"rows": [{"age": 30}]}"#);
        assert!(!stop);
        let j = Json::parse(&reply).unwrap();
        assert_eq!(j.get("retryable"), Some(&Json::Bool(true)), "{reply}");
        assert!(j.req_f64("retry_after_ms").unwrap() >= 1.0);
        assert!(j.req_str("error").unwrap().contains("down"), "{reply}");
    }

    /// An address that refuses connections: bind an ephemeral port, then
    /// release it.
    fn dead_addr() -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        addr
    }

    #[test]
    fn admin_commands_take_the_forward_once_path() {
        // The admin wire shape carries a "path" key; it must dispatch to
        // forward_once, not fall through to the retried predict path.
        let config = RouteConfig {
            backends: vec![dead_addr()],
            retry_budget: 3,
            backoff_base_ms: 1,
            backoff_cap_ms: 2,
            connect_timeout: Duration::from_millis(500),
            ..Default::default()
        };
        for line in [
            r#"{"cmd": "load", "model": "fraud", "path": "/models/fraud.ydf"}"#,
            r#"{"cmd": "swap", "model": "fraud", "path": "/models/fraud_v2.ydf"}"#,
            r#"{"cmd": "unload", "model": "fraud"}"#,
        ] {
            // Fresh router per command: each failed hop strikes the
            // backend's health FSM, and a Down backend sheds instead.
            let router = Router::new(&config, Arc::new(AtomicBool::new(false)));
            let (reply, stop) = router.respond(line);
            assert!(!stop);
            let j = Json::parse(&reply).unwrap();
            // A failed hop surfaces as a non-retryable command error —
            // never as a retryable shed inviting the client to re-send a
            // possibly-already-applied command.
            assert!(j.get("retryable").is_none(), "{line} -> {reply}");
            assert!(j.req_str("error").unwrap().contains("never retried"), "{line} -> {reply}");
        }
    }

    #[test]
    fn pooled_failure_after_send_is_a_hop_failure_not_a_resend() {
        // A "backend" that answers the first request (so the connection
        // gets pooled), then reads the second request and closes without
        // replying — a failure *after* the request was flushed.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let mut w = &stream;
            writeln!(w, "{{\"ok\": true}}").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            // Close the connection *and* the listener without replying:
            // a re-send would need a fresh dial and fail differently.
        });
        let b = Backend::new(addr);
        let t = Duration::from_millis(2_000);
        assert_eq!(b.forward(r#"{"cmd": "health"}"#, t, t).unwrap(), r#"{"ok": true}"#);
        let err = b.forward(r#"{"cmd": "swap"}"#, t, t).unwrap_err();
        // The request left on the pooled connection, so its failure must
        // surface as a hop failure ("closed before replying"), not fall
        // through to a fresh dial ("cannot connect") that would deliver
        // the command a second time.
        assert!(err.contains("before replying"), "after-send failure was re-sent: {err}");
        server.join().unwrap();
    }

    #[test]
    fn probe_failures_show_in_the_failures_counter() {
        let config = RouteConfig {
            backends: vec![dead_addr()],
            connect_timeout: Duration::from_millis(500),
            ..Default::default()
        };
        let router = Router::new(&config, Arc::new(AtomicBool::new(false)));
        router.probe_all();
        router.probe_all();
        let b = &router.backends[0];
        assert_eq!(b.failures.load(Ordering::Relaxed), 2);
        assert_eq!(b.state(), HealthState::Down);
    }

    #[test]
    fn respond_answers_local_commands_without_backends() {
        let config = RouteConfig {
            backends: vec!["127.0.0.1:9301".to_string()],
            ..Default::default()
        };
        let router = Router::new(&config, Arc::new(AtomicBool::new(false)));

        let (reply, stop) = router.respond(r#"{"cmd": "health"}"#);
        assert!(!stop);
        let j = Json::parse(&reply).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        let block = j.req("router").unwrap();
        assert_eq!(block.req_arr("backends").unwrap().len(), 1);
        assert_eq!(block.req_f64("retry_budget").unwrap(), 3.0);

        let (reply, _) = router.respond(r#"{"cmd": "metrics"}"#);
        let j = Json::parse(&reply).unwrap();
        assert!(j.req_str("content_type").unwrap().contains("text/plain"));

        let (reply, _) = router.respond("not json");
        assert!(Json::parse(&reply).unwrap().req_str("error").unwrap().contains("invalid JSON"));

        let (_, stop) = router.respond(r#"{"cmd": "shutdown"}"#);
        assert!(stop);
    }
}
