//! Compiled-forest engine: a trained RF/GBT lowered to one flat,
//! position-independent word array that is also the on-disk artifact
//! (`ydf compile` → a versioned, checksummed `.bin` mmap-ed back at serve
//! time). This closes the AOT path the feature-gated PJRT stub left open
//! (ROADMAP "Compiled-forest engine"): the SIMD-evaluation paper
//! (arXiv 2205.07307) shows flat if-converted layouts win 2-4x over
//! pointer trees, and the database-perspective comparison
//! (arXiv 2302.04430) shows compiled strategies must slot into *measured*
//! selection — so [`CompiledEngine`] registers as one more
//! `compile_engines`/`benchmark_inference` row rather than replacing the
//! flat engine.
//!
//! Traversal semantics are an exact mirror of [`super::flat`]: the same
//! BFS children-adjacent node layout, the same scalar block kernel as the
//! correctness reference, the same level-synchronous lane kernel over
//! [`BLOCK_SIZE`]-row blocks (gated per tree on Leaf/Higher/Oblique node
//! kinds and numerical column resolution), and the shared [`Aggregate`]
//! output shaping — so compiled predictions are bit-identical to the
//! naive/flat/QuickScorer engines (pinned by
//! `rust/tests/compiled.rs::prop_compiled_engine_matches_naive`).
//!
//! ## Artifact format (version 1)
//!
//! Little-endian. A 24-byte header:
//!
//! | bytes  | field                                        |
//! |--------|----------------------------------------------|
//! | 0..4   | magic `"YDFC"`                               |
//! | 4..8   | u32 format version (`ARTIFACT_VERSION`)      |
//! | 8..12  | u32 length of the meta JSON in bytes         |
//! | 12..16 | u32 length of the payload in u32 words       |
//! | 16..24 | u64 FNV-1a checksum of every byte after the header |
//!
//! then the meta JSON (`{"artifact":"ydf-compiled-forest","model_type":…,
//! "task":…,"label_col":…,"spec":{…}}`), zero-padded so the payload starts
//! at the next multiple of 8, then the payload words. The file length must
//! equal `pad8(24 + meta_len) + 4 * words_len` exactly.
//!
//! The payload is self-describing: 10 section-size words
//! (aggregate kind/params, leaf dim, tree/node/bitmap/oblique/leaf/initial
//! counts), then per-tree root indices, 6-word nodes
//! (`[kind | m2p<<8, attr, f32 threshold bits, aux, aux_len, child]`),
//! categorical bitmaps (u64s as lo/hi word pairs), oblique terms
//! (attr + f32 weight bits), leaf values (f32 bits) and GBT initial
//! predictions (f64s as lo/hi word pairs).
//!
//! Loading validates magic, version, length and checksum before touching
//! the payload, then bounds-checks every structural reference (roots
//! strictly increasing, children inside the tree range and strictly
//! forward — traversal provably terminates — attrs inside the dataspec,
//! aux ranges inside their sections). A truncated, bit-flipped or
//! hand-corrupted artifact is a descriptive `Err`, never a panic or an
//! out-of-bounds read: the mmap-backed and heap-backed code paths read the
//! exact same validated words. (One caveat inherent to mmap: truncating
//! the file *while* another process is serving from it can SIGBUS — see
//! `docs/serving.md`; artifacts should be replaced atomically via rename.)

use super::{Aggregate, BLOCK_SIZE, ColumnAccess, InferenceEngine};
use crate::dataset::{AttrValue, DataSpec, Dataset, Observation};
use crate::model::forest::{GbtLoss, GradientBoostedTreesModel, RandomForestModel};
use crate::model::tree::Condition;
use crate::model::{Model, Task};
use crate::utils::json::Json;
use std::ops::Range;
use std::path::Path;
use std::sync::Arc;

const KIND_LEAF: u8 = 0;
const KIND_HIGHER: u8 = 1;
const KIND_CONTAINS: u8 = 2;
const KIND_CONTAINS_SET: u8 = 3;
const KIND_OBLIQUE: u8 = 4;
const KIND_IS_TRUE: u8 = 5;

/// First bytes of every compiled-forest artifact.
pub const ARTIFACT_MAGIC: [u8; 4] = *b"YDFC";
/// Current artifact format version. Bump only with a loader branch —
/// like the JSON model format, old artifacts must load forever.
pub const ARTIFACT_VERSION: u32 = 1;
/// Header length in bytes: magic + version + meta_len + words_len + checksum.
const HEADER_LEN: usize = 24;
/// Section-size words at the start of the payload.
const META_WORDS: usize = 10;
/// Words per packed node.
const NODE_WORDS: usize = 6;

/// FNV-1a 64-bit hash — the artifact checksum. Dependency-free and fast
/// enough to verify a model file once at open time.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn pad8(x: usize) -> usize {
    (x + 7) & !7
}

/// Read-only memory mapping of an artifact file. Gated to little-endian
/// unix targets: the artifact is little-endian on disk, and mapping it
/// is only zero-copy where the host matches; everywhere else
/// [`CompiledForest::open`] falls back to an owned read + decode.
#[cfg(all(unix, target_endian = "little"))]
mod mmap {
    use std::os::unix::io::AsRawFd;
    use std::path::Path;

    extern "C" {
        fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    pub struct MappedFile {
        ptr: *mut core::ffi::c_void,
        len: usize,
    }

    // The mapping is PROT_READ and never mutated after construction.
    unsafe impl Send for MappedFile {}
    unsafe impl Sync for MappedFile {}

    impl MappedFile {
        pub fn open(path: &Path) -> Result<MappedFile, String> {
            let file = std::fs::File::open(path)
                .map_err(|e| format!("cannot open {}: {e}", path.display()))?;
            let len = file
                .metadata()
                .map_err(|e| format!("cannot stat {}: {e}", path.display()))?
                .len();
            if len == 0 || len > usize::MAX as u64 {
                return Err(format!("{}: unmappable size {len}", path.display()));
            }
            let len = len as usize;
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
            };
            if ptr as isize == -1 {
                return Err(format!("mmap of {} failed", path.display()));
            }
            Ok(MappedFile { ptr, len })
        }

        pub fn bytes(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for MappedFile {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

/// The payload word array: owned (lowered in memory, or decoded from a
/// file on hosts without mmap) or a view into a mapped artifact. Both are
/// validated identically by [`CompiledForest::build`] before any
/// traversal touches them.
enum Words {
    Owned(Vec<u32>),
    #[cfg(all(unix, target_endian = "little"))]
    Mapped {
        map: mmap::MappedFile,
        words_off: usize,
        words_len: usize,
    },
}

impl Words {
    #[inline]
    fn as_slice(&self) -> &[u32] {
        match self {
            Words::Owned(v) => v,
            #[cfg(all(unix, target_endian = "little"))]
            Words::Mapped { map, words_off, words_len } => unsafe {
                // `words_off` is 8-aligned within a page-aligned map and
                // `words_off + 4 * words_len == file length` was checked by
                // `parse_artifact`, so the cast is aligned and in bounds.
                std::slice::from_raw_parts(
                    map.bytes().as_ptr().add(*words_off) as *const u32,
                    *words_len,
                )
            },
        }
    }

    fn is_mapped(&self) -> bool {
        match self {
            Words::Owned(_) => false,
            #[cfg(all(unix, target_endian = "little"))]
            Words::Mapped { .. } => true,
        }
    }
}

/// One decoded node (6 payload words). Children are adjacent: positive at
/// `child`, negative at `child + 1` — the flat engine's layout.
#[derive(Clone, Copy)]
struct CNode {
    kind: u8,
    missing_to_positive: bool,
    attr: u32,
    threshold: f32,
    aux: u32,
    aux_len: u32,
    child: u32,
}

/// A forest lowered to the artifact word layout, servable in place
/// (possibly straight off an mmap). Produced by [`CompiledForest::lower`]
/// from a trained model or by [`CompiledForest::open`] /
/// [`CompiledForest::from_artifact_bytes`] from an artifact; both paths
/// run the same structural validation.
pub struct CompiledForest {
    words: Words,
    num_trees: usize,
    num_nodes: usize,
    nodes_off: usize,
    bitmaps_off: usize,
    oblique_off: usize,
    leaves_off: usize,
    initial_off: usize,
    leaf_dim: usize,
    aggregate: Aggregate,
    spec: DataSpec,
    task: Task,
    label_col: usize,
    /// Per tree: every node is Leaf/Higher/Oblique (the lane kernel's
    /// envelope, same gate as the flat engine).
    lane_ok: Vec<bool>,
    /// Per tree: attrs read by Higher nodes; the lane kernel requires each
    /// to resolve to a numerical column of the dataset at hand.
    lane_attrs: Vec<Vec<u32>>,
}

impl CompiledForest {
    // ----- lowering (model -> words) -----

    /// Lowers a trained model to the compiled layout. Only RF/GBT forests
    /// lower; anything else is a descriptive error.
    pub fn lower(model: &dyn Model) -> Result<CompiledForest, String> {
        let (trees, leaf_dim, aggregate, spec, task, label_col) = if let Some(m) =
            model.as_any().downcast_ref::<RandomForestModel>()
        {
            let num_classes = match m.task {
                Task::Classification => m.spec.columns[m.label_col].vocab_size(),
                Task::Regression => 1,
            };
            let aggregate = match m.task {
                Task::Classification => Aggregate::RfAverage {
                    num_classes,
                    winner_take_all: m.winner_take_all,
                },
                Task::Regression => Aggregate::RfRegression,
            };
            (&m.trees, num_classes, aggregate, m.spec.clone(), m.task, m.label_col)
        } else if let Some(m) = model.as_any().downcast_ref::<GradientBoostedTreesModel>() {
            let aggregate = Aggregate::Gbt {
                loss: m.loss,
                dim: m.trees_per_iter,
                initial: m.initial_predictions.clone(),
            };
            (&m.trees, 1, aggregate, m.spec.clone(), m.task, m.label_col)
        } else {
            return Err(format!(
                "model type {} has no compiled-forest lowering (only RANDOM_FOREST and \
                 GRADIENT_BOOSTED_TREES models compile)",
                model.model_type()
            ));
        };

        // BFS copy with children-adjacent layout — identical to the flat
        // engine, so both engines route every example to the same leaf.
        let mut nodes: Vec<CNode> = Vec::new();
        let mut roots: Vec<u32> = Vec::with_capacity(trees.len());
        let mut bitmaps: Vec<u64> = Vec::new();
        let mut oblique: Vec<(u32, f32)> = Vec::new();
        let mut leaf_values: Vec<f32> = Vec::new();
        let placeholder = CNode {
            kind: KIND_LEAF,
            missing_to_positive: false,
            attr: 0,
            threshold: 0.0,
            aux: 0,
            aux_len: 0,
            child: 0,
        };
        for t in trees.iter() {
            roots.push(nodes.len() as u32);
            let mut flat_of = vec![u32::MAX; t.nodes.len()];
            let mut queue = std::collections::VecDeque::new();
            flat_of[0] = nodes.len() as u32;
            nodes.push(placeholder);
            queue.push_back(0usize);
            while let Some(orig) = queue.pop_front() {
                let node = &t.nodes[orig];
                let flat_idx = flat_of[orig] as usize;
                match &node.condition {
                    None => {
                        let aux = leaf_values.len() as u32;
                        leaf_values.extend_from_slice(&node.value);
                        for _ in node.value.len()..leaf_dim {
                            leaf_values.push(0.0);
                        }
                        nodes[flat_idx] = CNode {
                            aux,
                            aux_len: leaf_dim as u32,
                            ..placeholder
                        };
                    }
                    Some(cond) => {
                        let child = nodes.len() as u32;
                        nodes.push(placeholder);
                        nodes.push(placeholder);
                        flat_of[node.positive as usize] = child;
                        flat_of[node.negative as usize] = child + 1;
                        queue.push_back(node.positive as usize);
                        queue.push_back(node.negative as usize);
                        let m2p = node.missing_to_positive;
                        let cn = match cond {
                            Condition::Higher { attr, threshold } => CNode {
                                kind: KIND_HIGHER,
                                missing_to_positive: m2p,
                                attr: *attr as u32,
                                threshold: *threshold,
                                child,
                                ..placeholder
                            },
                            Condition::ContainsBitmap { attr, bitmap } => {
                                let aux = bitmaps.len() as u32;
                                bitmaps.extend_from_slice(bitmap);
                                CNode {
                                    kind: KIND_CONTAINS,
                                    missing_to_positive: m2p,
                                    attr: *attr as u32,
                                    aux,
                                    aux_len: bitmap.len() as u32,
                                    child,
                                    ..placeholder
                                }
                            }
                            Condition::ContainsSetBitmap { attr, bitmap } => {
                                let aux = bitmaps.len() as u32;
                                bitmaps.extend_from_slice(bitmap);
                                CNode {
                                    kind: KIND_CONTAINS_SET,
                                    missing_to_positive: m2p,
                                    attr: *attr as u32,
                                    aux,
                                    aux_len: bitmap.len() as u32,
                                    child,
                                    ..placeholder
                                }
                            }
                            Condition::Oblique { attrs, weights, threshold } => {
                                let aux = oblique.len() as u32;
                                for (&a, &w) in attrs.iter().zip(weights) {
                                    oblique.push((a as u32, w));
                                }
                                CNode {
                                    kind: KIND_OBLIQUE,
                                    missing_to_positive: m2p,
                                    threshold: *threshold,
                                    aux,
                                    aux_len: attrs.len() as u32,
                                    child,
                                    ..placeholder
                                }
                            }
                            Condition::IsTrue { attr } => CNode {
                                kind: KIND_IS_TRUE,
                                missing_to_positive: m2p,
                                attr: *attr as u32,
                                child,
                                ..placeholder
                            },
                        };
                        nodes[flat_idx] = cn;
                    }
                }
            }
        }
        if nodes.len() >= u32::MAX as usize
            || leaf_values.len() >= u32::MAX as usize
            || bitmaps.len() >= u32::MAX as usize
            || oblique.len() >= u32::MAX as usize
        {
            return Err("forest too large for the compiled artifact's u32 indices".into());
        }

        // Pack into the payload word layout.
        let (agg_kind, k1, k2, initial): (u32, u32, u32, &[f64]) = match &aggregate {
            Aggregate::RfAverage { num_classes, winner_take_all } => {
                (0, *num_classes as u32, *winner_take_all as u32, &[])
            }
            Aggregate::RfRegression => (1, 0, 0, &[]),
            Aggregate::Gbt { loss, dim, initial } => {
                let code = match loss {
                    GbtLoss::BinomialLogLikelihood => 0,
                    GbtLoss::MultinomialLogLikelihood => 1,
                    GbtLoss::SquaredError => 2,
                };
                (2, *dim as u32, code, initial.as_slice())
            }
        };
        let total = META_WORDS
            + roots.len()
            + nodes.len() * NODE_WORDS
            + 2 * bitmaps.len()
            + 2 * oblique.len()
            + leaf_values.len()
            + 2 * initial.len();
        let mut w: Vec<u32> = Vec::with_capacity(total);
        w.extend_from_slice(&[
            agg_kind,
            k1,
            k2,
            leaf_dim as u32,
            roots.len() as u32,
            nodes.len() as u32,
            bitmaps.len() as u32,
            oblique.len() as u32,
            leaf_values.len() as u32,
            initial.len() as u32,
        ]);
        w.extend_from_slice(&roots);
        for n in &nodes {
            w.push(n.kind as u32 | (n.missing_to_positive as u32) << 8);
            w.push(n.attr);
            w.push(n.threshold.to_bits());
            w.push(n.aux);
            w.push(n.aux_len);
            w.push(n.child);
        }
        for &b in &bitmaps {
            w.push(b as u32);
            w.push((b >> 32) as u32);
        }
        for &(a, wgt) in &oblique {
            w.push(a);
            w.push(wgt.to_bits());
        }
        for &v in &leaf_values {
            w.push(v.to_bits());
        }
        for &x in initial {
            let bits = x.to_bits();
            w.push(bits as u32);
            w.push((bits >> 32) as u32);
        }
        debug_assert_eq!(w.len(), total);
        // Single read path: lowering goes through the same validation as
        // loading, so a lowered forest and its round-tripped artifact are
        // the same structure by construction.
        Self::build(Words::Owned(w), spec, task, label_col)
    }

    // ----- artifact write -----

    /// Serializes to the artifact byte format (header + meta + payload).
    pub fn to_artifact_bytes(&self) -> Vec<u8> {
        let w = self.words.as_slice();
        let mut meta = Json::obj();
        meta.set("artifact", Json::Str("ydf-compiled-forest".into()))
            .set("model_type", Json::Str(self.model_type_name().into()))
            .set("task", Json::Str(self.task.name().into()))
            .set("label_col", Json::Num(self.label_col as f64))
            .set("spec", self.spec.to_json());
        let meta_bytes = meta.to_string().into_bytes();
        let words_off = pad8(HEADER_LEN + meta_bytes.len());
        let mut out = Vec::with_capacity(words_off + 4 * w.len());
        out.extend_from_slice(&ARTIFACT_MAGIC);
        out.extend_from_slice(&ARTIFACT_VERSION.to_le_bytes());
        out.extend_from_slice(&(meta_bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&(w.len() as u32).to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes()); // checksum patched below
        out.extend_from_slice(&meta_bytes);
        out.resize(words_off, 0);
        for &x in w {
            out.extend_from_slice(&x.to_le_bytes());
        }
        let ck = fnv1a64(&out[HEADER_LEN..]);
        out[16..24].copy_from_slice(&ck.to_le_bytes());
        out
    }

    /// Writes the artifact to a file.
    pub fn write_artifact(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_artifact_bytes())
            .map_err(|e| format!("cannot write compiled artifact {}: {e}", path.display()))
    }

    // ----- artifact read -----

    /// Decodes an artifact from bytes already in memory (always heap-owned;
    /// [`CompiledForest::open`] is the mmap path).
    pub fn from_artifact_bytes(bytes: &[u8]) -> Result<CompiledForest, String> {
        Self::from_bytes_origin(bytes, "<memory>")
    }

    /// Opens an artifact file, mmap-ing it read-only where the platform
    /// allows (little-endian unix) and falling back to an owned read
    /// elsewhere or when the map fails. Full validation either way.
    pub fn open(path: &Path) -> Result<CompiledForest, String> {
        let origin = path.display().to_string();
        #[cfg(all(unix, target_endian = "little"))]
        {
            if let Ok(map) = mmap::MappedFile::open(path) {
                let (meta, words_off, words_len) = parse_artifact(map.bytes(), &origin)?;
                let words = Words::Mapped { map, words_off, words_len };
                return Self::build_from_meta(words, &meta, &origin);
            }
        }
        let bytes = std::fs::read(path)
            .map_err(|e| format!("cannot read compiled artifact {origin}: {e}"))?;
        Self::from_bytes_origin(&bytes, &origin)
    }

    fn from_bytes_origin(bytes: &[u8], origin: &str) -> Result<CompiledForest, String> {
        let (meta, words_off, words_len) = parse_artifact(bytes, origin)?;
        let mut words = Vec::with_capacity(words_len);
        for ch in bytes[words_off..words_off + 4 * words_len].chunks_exact(4) {
            words.push(u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
        }
        Self::build_from_meta(Words::Owned(words), &meta, origin)
    }

    fn build_from_meta(words: Words, meta: &Json, origin: &str) -> Result<CompiledForest, String> {
        let wrap = |e: String| format!("compiled artifact {origin}: {e}");
        let tag = meta.req_str("artifact").map_err(|e| wrap(e.to_string()))?;
        if tag != "ydf-compiled-forest" {
            return Err(wrap(format!("unexpected artifact tag '{tag}'")));
        }
        let model_type = meta.req_str("model_type").map_err(|e| wrap(e.to_string()))?.to_string();
        let task = match meta.req_str("task").map_err(|e| wrap(e.to_string()))? {
            "CLASSIFICATION" => Task::Classification,
            "REGRESSION" => Task::Regression,
            t => return Err(wrap(format!("unknown task '{t}'"))),
        };
        let label_col = meta.req_usize("label_col").map_err(|e| wrap(e.to_string()))?;
        let spec = meta
            .req("spec")
            .and_then(DataSpec::from_json)
            .map_err(|e| wrap(e.to_string()))?;
        let forest = Self::build(words, spec, task, label_col).map_err(wrap)?;
        if model_type != forest.model_type_name() {
            return Err(format!(
                "compiled artifact {origin}: meta model_type '{model_type}' does not match the \
                 payload aggregate ({})",
                forest.model_type_name()
            ));
        }
        Ok(forest)
    }

    /// Validates the payload structurally and constructs the forest. The
    /// single gate every construction path (lowering, heap decode, mmap)
    /// funnels through: after it succeeds, traversal can index the words
    /// without further bounds checks.
    fn build(
        words: Words,
        spec: DataSpec,
        task: Task,
        label_col: usize,
    ) -> Result<CompiledForest, String> {
        let w = words.as_slice();
        if w.len() < META_WORDS {
            return Err(format!(
                "payload holds {} words; at least {META_WORDS} are required",
                w.len()
            ));
        }
        let leaf_dim = w[3] as usize;
        let num_trees = w[4] as usize;
        let num_nodes = w[5] as usize;
        let num_bitmap_words = w[6] as usize;
        let num_oblique_terms = w[7] as usize;
        let num_leaf_values = w[8] as usize;
        let num_initial = w[9] as usize;

        // Section offsets, computed in u64 so hostile counts cannot wrap.
        let roots_off = META_WORDS as u64;
        let nodes_off = roots_off + num_trees as u64;
        let bitmaps_off = nodes_off + num_nodes as u64 * NODE_WORDS as u64;
        let oblique_off = bitmaps_off + 2 * num_bitmap_words as u64;
        let leaves_off = oblique_off + 2 * num_oblique_terms as u64;
        let initial_off = leaves_off + num_leaf_values as u64;
        let total = initial_off + 2 * num_initial as u64;
        if total != w.len() as u64 {
            return Err(format!(
                "section sizes require {total} payload words but {} are present",
                w.len()
            ));
        }

        // Aggregate decode, strict: every parameter combination that the
        // writer cannot produce is rejected.
        let aggregate = match w[0] {
            0 => {
                if w[1] == 0 || w[2] > 1 || leaf_dim != w[1] as usize || num_initial != 0 {
                    return Err(format!(
                        "invalid RF-classification aggregate (classes={}, wta={}, leaf_dim={}, \
                         initial={})",
                        w[1], w[2], leaf_dim, num_initial
                    ));
                }
                Aggregate::RfAverage {
                    num_classes: w[1] as usize,
                    winner_take_all: w[2] == 1,
                }
            }
            1 => {
                if w[1] != 0 || w[2] != 0 || leaf_dim != 1 || num_initial != 0 {
                    return Err("invalid RF-regression aggregate parameters".into());
                }
                Aggregate::RfRegression
            }
            2 => {
                let loss = match w[2] {
                    0 => GbtLoss::BinomialLogLikelihood,
                    1 => GbtLoss::MultinomialLogLikelihood,
                    2 => GbtLoss::SquaredError,
                    c => return Err(format!("unknown GBT loss code {c}")),
                };
                let dim = w[1] as usize;
                if dim == 0 || leaf_dim != 1 || num_initial != dim {
                    return Err(format!(
                        "invalid GBT aggregate (dim={dim}, leaf_dim={leaf_dim}, \
                         initial={num_initial})"
                    ));
                }
                let io = initial_off as usize;
                let initial: Vec<f64> = (0..dim)
                    .map(|i| {
                        let lo = w[io + 2 * i] as u64;
                        let hi = w[io + 2 * i + 1] as u64;
                        f64::from_bits(lo | hi << 32)
                    })
                    .collect();
                Aggregate::Gbt { loss, dim, initial }
            }
            k => return Err(format!("unknown aggregate kind {k}")),
        };

        // Meta / payload consistency.
        let expect_task = match &aggregate {
            Aggregate::RfAverage { .. } => Task::Classification,
            Aggregate::RfRegression => Task::Regression,
            Aggregate::Gbt { loss, .. } => {
                if *loss == GbtLoss::SquaredError {
                    Task::Regression
                } else {
                    Task::Classification
                }
            }
        };
        if task != expect_task {
            return Err(format!(
                "task {} does not match the payload aggregate (expected {})",
                task.name(),
                expect_task.name()
            ));
        }
        let ncols = spec.columns.len();
        if label_col >= ncols {
            return Err(format!(
                "label column {label_col} is outside the {ncols}-column dataspec"
            ));
        }
        let spec_dim = match task {
            Task::Classification => spec.columns[label_col].vocab_size(),
            Task::Regression => 1,
        };
        if aggregate.output_dim() != spec_dim {
            return Err(format!(
                "aggregate output dimension {} does not match the dataspec label ({spec_dim})",
                aggregate.output_dim()
            ));
        }

        // Roots: strictly increasing from 0, all in range.
        if num_trees == 0 {
            return Err("artifact contains no trees".into());
        }
        let ro = roots_off as usize;
        if w[ro] != 0 {
            return Err(format!("first tree root is {} (must be 0)", w[ro]));
        }
        for ti in 1..num_trees {
            if w[ro + ti] <= w[ro + ti - 1] {
                return Err(format!("tree roots are not strictly increasing at tree {ti}"));
            }
        }
        if num_nodes == 0 || w[ro + num_trees - 1] as usize >= num_nodes {
            return Err(format!(
                "tree root {} is outside the {num_nodes}-node table",
                w.get(ro + num_trees - 1).copied().unwrap_or(0)
            ));
        }

        // Per-node structural validation + lane metadata, per tree range.
        let no = nodes_off as usize;
        let mut lane_ok = Vec::with_capacity(num_trees);
        let mut lane_attrs: Vec<Vec<u32>> = Vec::with_capacity(num_trees);
        for ti in 0..num_trees {
            let lo = w[ro + ti] as usize;
            let hi = if ti + 1 < num_trees { w[ro + ti + 1] as usize } else { num_nodes };
            let mut ok = true;
            let mut attrs: Vec<u32> = Vec::new();
            for i in lo..hi {
                let b = no + i * NODE_WORDS;
                let w0 = w[b];
                if w0 >> 9 != 0 {
                    return Err(format!("node {i}: reserved flag bits set ({w0:#x})"));
                }
                let kind = (w0 & 0xFF) as u8;
                let attr = w[b + 1] as usize;
                let aux = w[b + 3] as u64;
                let aux_len = w[b + 4] as u64;
                let child = w[b + 5] as usize;
                match kind {
                    KIND_LEAF => {
                        if aux_len as usize != leaf_dim
                            || aux + aux_len > num_leaf_values as u64
                        {
                            return Err(format!(
                                "node {i}: leaf values {aux}+{aux_len} escape the \
                                 {num_leaf_values}-value table"
                            ));
                        }
                    }
                    KIND_HIGHER | KIND_CONTAINS | KIND_CONTAINS_SET | KIND_OBLIQUE
                    | KIND_IS_TRUE => {
                        // Children strictly forward and inside this tree's
                        // range: traversal always terminates.
                        if child <= i || child + 1 >= hi {
                            return Err(format!(
                                "node {i}: children {child},{} escape the tree range {lo}..{hi}",
                                child + 1
                            ));
                        }
                        if kind != KIND_OBLIQUE && attr >= ncols {
                            return Err(format!(
                                "node {i}: attribute {attr} is outside the {ncols}-column dataspec"
                            ));
                        }
                        if (kind == KIND_CONTAINS || kind == KIND_CONTAINS_SET)
                            && aux + aux_len > num_bitmap_words as u64
                        {
                            return Err(format!(
                                "node {i}: bitmap {aux}+{aux_len} escapes the \
                                 {num_bitmap_words}-word table"
                            ));
                        }
                        if kind == KIND_OBLIQUE {
                            if aux + aux_len > num_oblique_terms as u64 {
                                return Err(format!(
                                    "node {i}: oblique terms {aux}+{aux_len} escape the \
                                     {num_oblique_terms}-term table"
                                ));
                            }
                            let oo = oblique_off as usize;
                            for t in aux..aux + aux_len {
                                let a = w[oo + 2 * t as usize] as usize;
                                if a >= ncols {
                                    return Err(format!(
                                        "node {i}: oblique term attribute {a} is outside the \
                                         {ncols}-column dataspec"
                                    ));
                                }
                            }
                        }
                    }
                    k => return Err(format!("node {i}: unknown condition kind {k}")),
                }
                match kind {
                    KIND_LEAF | KIND_OBLIQUE => {}
                    KIND_HIGHER => attrs.push(attr as u32),
                    _ => ok = false,
                }
            }
            attrs.sort_unstable();
            attrs.dedup();
            lane_ok.push(ok);
            lane_attrs.push(attrs);
        }

        let (nodes_off, bitmaps_off, oblique_off, leaves_off, initial_off) = (
            nodes_off as usize,
            bitmaps_off as usize,
            oblique_off as usize,
            leaves_off as usize,
            initial_off as usize,
        );
        Ok(CompiledForest {
            words,
            num_trees,
            num_nodes,
            nodes_off,
            bitmaps_off,
            oblique_off,
            leaves_off,
            initial_off,
            leaf_dim,
            aggregate,
            spec,
            task,
            label_col,
            lane_ok,
            lane_attrs,
        })
    }

    // ----- accessors -----

    pub fn num_trees(&self) -> usize {
        self.num_trees
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    /// True when the payload is served straight off an mmap-ed file.
    pub fn is_mapped(&self) -> bool {
        self.words.is_mapped()
    }

    pub fn spec(&self) -> &DataSpec {
        &self.spec
    }

    pub fn task(&self) -> Task {
        self.task
    }

    pub fn label_col(&self) -> usize {
        self.label_col
    }

    pub fn output_dim(&self) -> usize {
        self.aggregate.output_dim()
    }

    /// The lowered model family: "RANDOM_FOREST" or
    /// "GRADIENT_BOOSTED_TREES".
    pub fn model_type_name(&self) -> &'static str {
        match self.aggregate {
            Aggregate::Gbt { .. } => "GRADIENT_BOOSTED_TREES",
            _ => "RANDOM_FOREST",
        }
    }

    fn kind_display(&self) -> &'static str {
        match self.aggregate {
            Aggregate::Gbt { .. } => "GradientBoostedTrees",
            _ => "RandomForest",
        }
    }

    /// Sorted, deduplicated attribute indices the forest reads — the same
    /// contract as `model::forest::used_attributes`.
    pub fn used_attributes(&self) -> Vec<usize> {
        let w = self.words.as_slice();
        let mut attrs = Vec::new();
        for i in 0..self.num_nodes {
            let n = self.node_at(w, i);
            match n.kind {
                KIND_LEAF => {}
                KIND_OBLIQUE => {
                    for t in n.aux..n.aux + n.aux_len {
                        attrs.push(self.oblique_term(w, t as usize).0 as usize);
                    }
                }
                _ => attrs.push(n.attr as usize),
            }
        }
        attrs.sort_unstable();
        attrs.dedup();
        attrs
    }

    #[inline]
    fn node_at(&self, w: &[u32], idx: usize) -> CNode {
        let b = self.nodes_off + idx * NODE_WORDS;
        CNode {
            kind: (w[b] & 0xFF) as u8,
            missing_to_positive: (w[b] >> 8) & 1 == 1,
            attr: w[b + 1],
            threshold: f32::from_bits(w[b + 2]),
            aux: w[b + 3],
            aux_len: w[b + 4],
            child: w[b + 5],
        }
    }

    #[inline]
    fn root(&self, w: &[u32], ti: usize) -> u32 {
        w[META_WORDS + ti]
    }

    /// Mirrors `model::tree::bitmap_contains` over the word-packed u64s.
    #[inline]
    fn bitmap_has(&self, w: &[u32], aux: u32, aux_len: u32, value: u32) -> bool {
        let word = (value / 64) as usize;
        if word >= aux_len as usize {
            return false;
        }
        let b = self.bitmaps_off + 2 * (aux as usize + word);
        let bits = w[b] as u64 | (w[b + 1] as u64) << 32;
        (bits >> (value % 64)) & 1 == 1
    }

    #[inline]
    fn oblique_term(&self, w: &[u32], t: usize) -> (u32, f32) {
        let b = self.oblique_off + 2 * t;
        (w[b], f32::from_bits(w[b + 1]))
    }

    #[inline]
    fn leaf_value(&self, w: &[u32], off: usize) -> f32 {
        f32::from_bits(w[self.leaves_off + off])
    }

    // ----- traversal (exact mirrors of the flat engine's kernels) -----

    /// Evaluates one tree on a row observation; returns leaf-value offset.
    fn eval_tree_row(&self, w: &[u32], root: u32, obs: &Observation) -> u32 {
        let mut idx = root;
        loop {
            let n = self.node_at(w, idx as usize);
            let go_pos = match n.kind {
                KIND_LEAF => return n.aux,
                KIND_HIGHER => match &obs[n.attr as usize] {
                    AttrValue::Num(x) if !x.is_nan() => *x >= n.threshold,
                    _ => n.missing_to_positive,
                },
                KIND_CONTAINS => match &obs[n.attr as usize] {
                    AttrValue::Cat(c) => self.bitmap_has(w, n.aux, n.aux_len, *c),
                    _ => n.missing_to_positive,
                },
                KIND_CONTAINS_SET => match &obs[n.attr as usize] {
                    AttrValue::CatSet(items) => {
                        items.iter().any(|&i| self.bitmap_has(w, n.aux, n.aux_len, i))
                    }
                    _ => n.missing_to_positive,
                },
                KIND_OBLIQUE => {
                    let mut acc = 0.0f32;
                    for t in n.aux..n.aux + n.aux_len {
                        let (a, wgt) = self.oblique_term(w, t as usize);
                        if let AttrValue::Num(x) = &obs[a as usize] {
                            if !x.is_nan() {
                                acc += wgt * x;
                            }
                        }
                    }
                    acc >= n.threshold
                }
                KIND_IS_TRUE => match &obs[n.attr as usize] {
                    AttrValue::Bool(b) => *b,
                    _ => n.missing_to_positive,
                },
                _ => unreachable!("kinds validated at build"),
            };
            idx = if go_pos { n.child } else { n.child + 1 };
        }
    }

    /// Same traversal against resolved columnar slices (scalar kernel).
    fn eval_tree_cols(&self, w: &[u32], root: u32, cols: &ColumnAccess, row: usize) -> u32 {
        let mut idx = root;
        loop {
            let n = self.node_at(w, idx as usize);
            let go_pos = match n.kind {
                KIND_LEAF => return n.aux,
                KIND_HIGHER => match cols.num[n.attr as usize] {
                    Some(v) => {
                        let x = v[row];
                        if x.is_nan() {
                            n.missing_to_positive
                        } else {
                            x >= n.threshold
                        }
                    }
                    None => n.missing_to_positive,
                },
                KIND_CONTAINS => match cols.cat[n.attr as usize] {
                    Some(v) => {
                        let c = v[row];
                        if c == crate::dataset::MISSING_CAT {
                            n.missing_to_positive
                        } else {
                            self.bitmap_has(w, n.aux, n.aux_len, c)
                        }
                    }
                    None => n.missing_to_positive,
                },
                KIND_CONTAINS_SET => {
                    let col = &cols.columns[n.attr as usize];
                    if col.is_missing(row) {
                        n.missing_to_positive
                    } else {
                        col.set_values(row)
                            .map(|items| {
                                items.iter().any(|&i| self.bitmap_has(w, n.aux, n.aux_len, i))
                            })
                            .unwrap_or(n.missing_to_positive)
                    }
                }
                KIND_OBLIQUE => {
                    let mut acc = 0.0f32;
                    for t in n.aux..n.aux + n.aux_len {
                        let (a, wgt) = self.oblique_term(w, t as usize);
                        if let Some(v) = cols.num[a as usize] {
                            let x = v[row];
                            if !x.is_nan() {
                                acc += wgt * x;
                            }
                        }
                    }
                    acc >= n.threshold
                }
                KIND_IS_TRUE => match cols.boolean[n.attr as usize] {
                    Some(v) => match v[row] {
                        1 => true,
                        0 => false,
                        _ => n.missing_to_positive,
                    },
                    None => n.missing_to_positive,
                },
                _ => unreachable!("kinds validated at build"),
            };
            idx = if go_pos { n.child } else { n.child + 1 };
        }
    }

    /// Lane-wise (level-synchronous) traversal of one tree over the block
    /// rows `start..start + bs` — the flat engine's lane kernel over the
    /// word layout: same gating, same run detection, same term-major
    /// oblique accumulation preserving each lane's scalar term order, so
    /// it is bit-identical to `eval_tree_cols`.
    #[allow(clippy::too_many_arguments)]
    fn eval_tree_cols_lanes(
        &self,
        w: &[u32],
        root: u32,
        cols: &ColumnAccess,
        start: usize,
        bs: usize,
        leaves: &mut [u32],
        stride: usize,
        ti: usize,
    ) {
        debug_assert!(bs <= BLOCK_SIZE);
        let mut idx = [0u32; BLOCK_SIZE];
        let mut row = [0u32; BLOCK_SIZE];
        let mut xs = [0.0f32; BLOCK_SIZE];
        let mut ts = [0.0f32; BLOCK_SIZE];
        let mut m2p = [false; BLOCK_SIZE];
        let mut ch = [0u32; BLOCK_SIZE];
        for i in 0..bs {
            idx[i] = root;
            row[i] = i as u32;
        }
        let mut m = bs;
        while m > 0 {
            // Retire lanes that reached a leaf; keep the rest in row order.
            let mut kept = 0usize;
            for i in 0..m {
                let n = self.node_at(w, idx[i] as usize);
                if n.kind == KIND_LEAF {
                    leaves[row[i] as usize * stride + ti] = n.aux;
                } else {
                    idx[kept] = idx[i];
                    row[kept] = row[i];
                    kept += 1;
                }
            }
            m = kept;
            if m == 0 {
                break;
            }
            // Gather (x, threshold, child) per lane, sharing node decode
            // across runs of consecutive lanes on the same node.
            let mut i = 0usize;
            while i < m {
                let node_idx = idx[i];
                let mut j = i + 1;
                while j < m && idx[j] == node_idx {
                    j += 1;
                }
                let n = self.node_at(w, node_idx as usize);
                match n.kind {
                    KIND_HIGHER => {
                        let col = cols.num[n.attr as usize]
                            .expect("lane kernel requires resolved numerical columns");
                        for k in i..j {
                            xs[k] = col[start + row[k] as usize];
                        }
                        for k in i..j {
                            ts[k] = n.threshold;
                            m2p[k] = n.missing_to_positive;
                            ch[k] = n.child;
                        }
                    }
                    KIND_OBLIQUE => {
                        xs[i..j].fill(0.0);
                        // Term-major across the run's lanes; each lane still
                        // accumulates in the scalar kernel's term order.
                        for t in n.aux..n.aux + n.aux_len {
                            let (a, wgt) = self.oblique_term(w, t as usize);
                            if let Some(col) = cols.num[a as usize] {
                                for k in i..j {
                                    let x = col[start + row[k] as usize];
                                    if !x.is_nan() {
                                        xs[k] += wgt * x;
                                    }
                                }
                            }
                        }
                        for k in i..j {
                            ts[k] = n.threshold;
                            // The scalar kernel never routes oblique nodes by
                            // the missing policy: `acc >= threshold` with a
                            // NaN accumulator is plain false.
                            m2p[k] = false;
                            ch[k] = n.child;
                        }
                    }
                    _ => unreachable!("lane kernel gated on node kinds"),
                }
                i = j;
            }
            // Branch-free compare + advance, vectorizable.
            for i in 0..m {
                let x = xs[i];
                let nan = x.is_nan();
                let go_pos = (!nan && x >= ts[i]) | (nan & m2p[i]);
                idx[i] = ch[i] + (!go_pos) as u32;
            }
        }
    }

    /// Aggregates one example's per-tree leaf offsets into `out`
    /// (`aggregate.output_dim()` values); `scores` is reusable scratch of
    /// `aggregate.score_dim()` values. Same operation order as the flat
    /// engine's aggregation, so outputs are bit-identical.
    fn aggregate_leaves_into(
        &self,
        w: &[u32],
        leaf_offsets: &[u32],
        scores: &mut [f64],
        out: &mut [f64],
    ) {
        match &self.aggregate {
            Aggregate::RfAverage { winner_take_all, .. } => {
                out.fill(0.0);
                for &off in leaf_offsets {
                    let base = off as usize;
                    if *winner_take_all {
                        let mut best = 0usize;
                        let mut best_v = self.leaf_value(w, base);
                        for k in 1..self.leaf_dim {
                            let x = self.leaf_value(w, base + k);
                            if x > best_v {
                                best = k;
                                best_v = x;
                            }
                        }
                        out[best] += 1.0;
                    } else {
                        for (k, a) in out.iter_mut().enumerate() {
                            *a += self.leaf_value(w, base + k) as f64;
                        }
                    }
                }
                let n = leaf_offsets.len().max(1) as f64;
                for a in out.iter_mut() {
                    *a /= n;
                }
            }
            Aggregate::RfRegression => {
                let sum: f64 = leaf_offsets
                    .iter()
                    .map(|&off| self.leaf_value(w, off as usize) as f64)
                    .sum();
                out[0] = sum / leaf_offsets.len().max(1) as f64;
            }
            Aggregate::Gbt { loss, dim, initial } => {
                scores.copy_from_slice(initial);
                for (i, &off) in leaf_offsets.iter().enumerate() {
                    scores[i % dim] += self.leaf_value(w, off as usize) as f64;
                }
                Aggregate::apply_gbt_link(*loss, scores, out);
            }
        }
    }

    /// Predicts one row observation.
    pub fn predict_row_obs(&self, obs: &Observation) -> Vec<f64> {
        let w = self.words.as_slice();
        let leaves: Vec<u32> = (0..self.num_trees)
            .map(|ti| self.eval_tree_row(w, self.root(w, ti), obs))
            .collect();
        let mut scores = vec![0.0f64; self.aggregate.score_dim()];
        let mut out = vec![0.0f64; self.aggregate.output_dim()];
        self.aggregate_leaves_into(w, &leaves, &mut scores, &mut out);
        out
    }

    /// Predicts one dataset row through the scalar columnar path. Resolves
    /// columns per call — fine for the `Model` fallback, not the batch path.
    pub fn predict_ds_single(&self, ds: &Dataset, row: usize) -> Vec<f64> {
        let w = self.words.as_slice();
        let cols = ColumnAccess::new(ds);
        let leaves: Vec<u32> = (0..self.num_trees)
            .map(|ti| self.eval_tree_cols(w, self.root(w, ti), &cols, row))
            .collect();
        let mut scores = vec![0.0f64; self.aggregate.score_dim()];
        let mut out = vec![0.0f64; self.aggregate.output_dim()];
        self.aggregate_leaves_into(w, &leaves, &mut scores, &mut out);
        out
    }

    /// Batch prediction over `rows` into the caller's row-major buffer —
    /// the flat engine's block loop over the word layout. `simd` selects
    /// the lane kernel where the per-tree gate allows it.
    pub(crate) fn predict_batch_cols(
        &self,
        ds: &Dataset,
        rows: Range<usize>,
        out: &mut [f64],
        simd: bool,
    ) {
        let dim = self.aggregate.output_dim();
        debug_assert_eq!(out.len(), rows.len() * dim);
        let w = self.words.as_slice();
        let cols = ColumnAccess::new(ds);
        let num_trees = self.num_trees;
        let use_lanes: Vec<bool> = if simd {
            (0..num_trees)
                .map(|ti| {
                    self.lane_ok[ti]
                        && self.lane_attrs[ti]
                            .iter()
                            .all(|&a| cols.num[a as usize].is_some())
                })
                .collect()
        } else {
            vec![false; num_trees]
        };
        let mut leaves = vec![0u32; BLOCK_SIZE * num_trees];
        let mut scores = vec![0.0f64; self.aggregate.score_dim()];
        let mut start = rows.start;
        let mut out_off = 0usize;
        while start < rows.end {
            let bs = BLOCK_SIZE.min(rows.end - start);
            for ti in 0..num_trees {
                let root = self.root(w, ti);
                if use_lanes[ti] {
                    self.eval_tree_cols_lanes(
                        w, root, &cols, start, bs, &mut leaves, num_trees, ti,
                    );
                } else {
                    for bi in 0..bs {
                        leaves[bi * num_trees + ti] =
                            self.eval_tree_cols(w, root, &cols, start + bi);
                    }
                }
            }
            for bi in 0..bs {
                let o = out_off + bi * dim;
                self.aggregate_leaves_into(
                    w,
                    &leaves[bi * num_trees..(bi + 1) * num_trees],
                    &mut scores,
                    &mut out[o..o + dim],
                );
            }
            start += bs;
            out_off += bs * dim;
        }
    }
}

fn parse_artifact(bytes: &[u8], origin: &str) -> Result<(Json, usize, usize), String> {
    let err = |msg: String| format!("compiled artifact {origin}: {msg}");
    if bytes.len() < HEADER_LEN {
        return Err(err(format!(
            "{} bytes is too short to be a compiled artifact",
            bytes.len()
        )));
    }
    if bytes[0..4] != ARTIFACT_MAGIC {
        return Err(err("bad magic (not a compiled-forest artifact)".into()));
    }
    let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if version != ARTIFACT_VERSION {
        return Err(err(format!(
            "artifact version {version} is not supported (this library reads version \
             {ARTIFACT_VERSION})"
        )));
    }
    let meta_len = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as u64;
    let words_len = u32::from_le_bytes([bytes[12], bytes[13], bytes[14], bytes[15]]) as u64;
    let words_off = (HEADER_LEN as u64 + meta_len + 7) & !7;
    let expected = words_off + 4 * words_len;
    if bytes.len() as u64 != expected {
        return Err(err(format!(
            "truncated or oversized: {} bytes on disk, the header requires {expected}",
            bytes.len()
        )));
    }
    let stored = u64::from_le_bytes([
        bytes[16], bytes[17], bytes[18], bytes[19], bytes[20], bytes[21], bytes[22], bytes[23],
    ]);
    let computed = fnv1a64(&bytes[HEADER_LEN..]);
    if stored != computed {
        return Err(err(format!(
            "checksum mismatch (stored {stored:016x}, computed {computed:016x}) — the file is \
             corrupted"
        )));
    }
    let meta_text = std::str::from_utf8(&bytes[HEADER_LEN..HEADER_LEN + meta_len as usize])
        .map_err(|_| err("meta block is not valid UTF-8".into()))?;
    let meta = Json::parse(meta_text).map_err(|e| err(format!("invalid meta JSON: {e}")))?;
    Ok((meta, words_off as usize, words_len as usize))
}

/// Inference engine over a [`CompiledForest`]. Scalar and lane block
/// kernels like the flat engine; `set_simd` selects per instance.
pub struct CompiledEngine {
    forest: Arc<CompiledForest>,
    simd: bool,
}

impl CompiledEngine {
    /// Compiles from a trained RF/GBT (lowering it) or a [`CompiledModel`]
    /// (sharing its already-lowered forest). `None` for anything else.
    pub fn compile(model: &dyn Model) -> Option<CompiledEngine> {
        if let Some(cm) = model.as_any().downcast_ref::<CompiledModel>() {
            return Some(CompiledEngine::new(Arc::clone(&cm.forest)));
        }
        CompiledForest::lower(model).ok().map(|f| CompiledEngine::new(Arc::new(f)))
    }

    pub fn new(forest: Arc<CompiledForest>) -> CompiledEngine {
        CompiledEngine { forest, simd: cfg!(feature = "simd") }
    }

    /// Selects the lane-wise (`true`) or scalar (`false`) block kernel,
    /// like `FlatEngine::set_simd`; the two are bit-identical.
    pub fn set_simd(&mut self, on: bool) {
        self.simd = on;
    }

    pub fn forest(&self) -> &Arc<CompiledForest> {
        &self.forest
    }
}

impl InferenceEngine for CompiledEngine {
    fn name(&self) -> String {
        format!("{}Compiled", self.forest.kind_display())
    }

    fn output_dim(&self) -> usize {
        self.forest.aggregate.output_dim()
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        self.forest.predict_row_obs(obs)
    }

    fn predict_batch(&self, ds: &Dataset, rows: Range<usize>, out: &mut [f64]) {
        self.forest.predict_batch_cols(ds, rows, out, self.simd);
    }
}

/// A compiled artifact as a [`Model`]: what `model::io::load_model`
/// returns for a `.bin` path, so the CLI and the serving `Session` open
/// artifacts exactly like JSON models. Engine selection routes it to
/// [`CompiledEngine`] (the only engine that understands it). Note that
/// `to_json` is intentionally a stub — the artifact byte format
/// ([`CompiledForest::write_artifact`]) is this model's serialization.
pub struct CompiledModel {
    forest: Arc<CompiledForest>,
}

impl CompiledModel {
    /// Opens a `.bin` artifact (mmap where available).
    pub fn open(path: &Path) -> Result<CompiledModel, String> {
        CompiledForest::open(path).map(|f| CompiledModel { forest: Arc::new(f) })
    }

    pub fn from_forest(forest: Arc<CompiledForest>) -> CompiledModel {
        CompiledModel { forest }
    }

    pub fn forest(&self) -> &Arc<CompiledForest> {
        &self.forest
    }
}

impl Model for CompiledModel {
    fn model_type(&self) -> &'static str {
        match self.forest.aggregate {
            Aggregate::Gbt { .. } => "COMPILED_GRADIENT_BOOSTED_TREES",
            _ => "COMPILED_RANDOM_FOREST",
        }
    }

    fn task(&self) -> Task {
        self.forest.task
    }

    fn spec(&self) -> &DataSpec {
        &self.forest.spec
    }

    fn label_col(&self) -> usize {
        self.forest.label_col
    }

    fn input_features(&self) -> Vec<usize> {
        self.forest.used_attributes()
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        self.forest.predict_row_obs(obs)
    }

    fn predict_ds_row(&self, ds: &Dataset, row: usize) -> Vec<f64> {
        self.forest.predict_ds_single(ds, row)
    }

    fn describe(&self) -> String {
        format!(
            "Type: \"{}\"\nTask: {}\nLabel: \"{}\"\n\nCompiled-forest artifact \
             (format v{ARTIFACT_VERSION}): {} trees, {} nodes, served {}.\nInput features: {}.\n",
            self.model_type(),
            self.forest.task.name(),
            self.forest.spec.columns[self.forest.label_col].name,
            self.forest.num_trees,
            self.forest.num_nodes,
            if self.forest.is_mapped() { "from an mmap-ed file" } else { "from heap memory" },
            self.forest.used_attributes().len(),
        )
    }

    fn to_json(&self) -> Json {
        // The artifact byte format is the serialization of this model; a
        // JSON dump would be a lossy second format to maintain.
        let mut j = Json::obj();
        j.set("model_type", Json::Str(self.model_type().into())).set(
            "note",
            Json::Str(
                "compiled artifact; serialize with CompiledForest::write_artifact (ydf compile)"
                    .into(),
            ),
        );
        j
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::random_forest::RandomForestConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};

    fn bit_eq(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: value {i}: {x} vs {y}");
        }
    }

    #[test]
    fn compiled_matches_flat_bitwise_gbt() {
        let ds = synthetic::adult_like(200, 231);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 10;
        cfg.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let flat = super::super::flat::FlatEngine::compile(model.as_ref()).unwrap();
        let compiled = CompiledEngine::compile(model.as_ref()).unwrap();
        assert_eq!(compiled.name(), "GradientBoostedTreesCompiled");
        let dim = compiled.output_dim();
        let n = ds.num_rows();
        let mut a = vec![0.0f64; n * dim];
        let mut b = vec![0.0f64; n * dim];
        flat.predict_batch(&ds, 0..n, &mut a);
        compiled.predict_batch(&ds, 0..n, &mut b);
        bit_eq(&a, &b, "batch");
        for r in 0..20 {
            bit_eq(
                &compiled.predict_row(&ds.row(r)),
                &model.predict_ds_row(&ds, r),
                "row",
            );
        }
    }

    #[test]
    fn compiled_matches_naive_rf_regression() {
        let ds = synthetic::adult_like(150, 233);
        let mut cfg = RandomForestConfig::new("age");
        cfg.task = Task::Regression;
        cfg.num_trees = 6;
        cfg.compute_oob = false;
        let model = RandomForestLearner::new(cfg).train(&ds).unwrap();
        let compiled = CompiledEngine::compile(model.as_ref()).unwrap();
        assert_eq!(compiled.name(), "RandomForestCompiled");
        for r in 0..ds.num_rows() {
            bit_eq(
                &compiled.predict_row(&ds.row(r)),
                &model.predict_ds_row(&ds, r),
                "rf-regression row",
            );
        }
    }

    #[test]
    fn lane_kernel_matches_scalar_bitwise() {
        let ds = synthetic::adult_like(150, 235);
        let mut cfg = GbtConfig::benchmark_rank1("income"); // oblique splits
        cfg.num_trees = 6;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let mut scalar = CompiledEngine::compile(model.as_ref()).unwrap();
        scalar.set_simd(false);
        let mut lanes = CompiledEngine::compile(model.as_ref()).unwrap();
        lanes.set_simd(true);
        let dim = scalar.output_dim();
        let n = ds.num_rows();
        let mut a = vec![0.0f64; n * dim];
        let mut b = vec![0.0f64; n * dim];
        scalar.predict_batch(&ds, 0..n, &mut a);
        lanes.predict_batch(&ds, 0..n, &mut b);
        bit_eq(&a, &b, "scalar vs lane kernel");
    }

    #[test]
    fn artifact_bytes_round_trip_bit_identical() {
        let ds = synthetic::adult_like(120, 237);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 6;
        cfg.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let forest = CompiledForest::lower(model.as_ref()).unwrap();
        let bytes = forest.to_artifact_bytes();
        let loaded = CompiledForest::from_artifact_bytes(&bytes).unwrap();
        assert_eq!(loaded.num_trees(), forest.num_trees());
        assert_eq!(loaded.num_nodes(), forest.num_nodes());
        assert_eq!(loaded.used_attributes(), forest.used_attributes());
        let n = ds.num_rows();
        let dim = forest.output_dim();
        let mut a = vec![0.0f64; n * dim];
        let mut b = vec![0.0f64; n * dim];
        forest.predict_batch_cols(&ds, 0..n, &mut a, true);
        loaded.predict_batch_cols(&ds, 0..n, &mut b, true);
        bit_eq(&a, &b, "round trip");
        // Byte-stable: re-serializing the loaded forest reproduces the file.
        assert_eq!(bytes, loaded.to_artifact_bytes());
    }

    #[test]
    fn hostile_artifacts_rejected_cleanly() {
        let ds = synthetic::adult_like(100, 239);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 3;
        cfg.max_depth = 3;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let bytes = CompiledForest::lower(model.as_ref()).unwrap().to_artifact_bytes();

        // Truncations at a spread of lengths, incl. mid-header.
        for cut in [0usize, 1, 4, 12, 23, HEADER_LEN, bytes.len() / 3, bytes.len() - 1] {
            let e = CompiledForest::from_artifact_bytes(&bytes[..cut]).unwrap_err();
            assert!(!e.is_empty(), "cut={cut}");
        }
        // Wrong magic.
        let mut b = bytes.clone();
        b[0..4].copy_from_slice(b"JSON");
        assert!(CompiledForest::from_artifact_bytes(&b).unwrap_err().contains("magic"));
        // Future version.
        let mut b = bytes.clone();
        b[4..8].copy_from_slice(&2u32.to_le_bytes());
        assert!(CompiledForest::from_artifact_bytes(&b).unwrap_err().contains("version"));
        // A bit flip anywhere in the body trips the checksum.
        let mut b = bytes.clone();
        let mid = HEADER_LEN + (b.len() - HEADER_LEN) / 2;
        b[mid] ^= 0x40;
        assert!(CompiledForest::from_artifact_bytes(&b).unwrap_err().contains("checksum"));
        // Trailing garbage is an exact-length violation.
        let mut b = bytes.clone();
        b.push(0);
        assert!(CompiledForest::from_artifact_bytes(&b).unwrap_err().contains("truncated"));
    }

    #[test]
    fn checksum_repaired_structural_corruption_rejected() {
        // An attacker who re-computes the checksum still cannot make the
        // structural validator accept out-of-range children.
        let ds = synthetic::adult_like(100, 241);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 3;
        cfg.max_depth = 3;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let forest = CompiledForest::lower(model.as_ref()).unwrap();
        let mut bytes = forest.to_artifact_bytes();
        let meta_len =
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
        let words_off = (HEADER_LEN + meta_len + 7) & !7;
        // Root node (first node) is internal in any depth>1 tree: smash its
        // child word (node word 5) to u32::MAX.
        let child_byte = words_off + 4 * (META_WORDS + forest.num_trees() + 5);
        bytes[child_byte..child_byte + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let ck = fnv1a64(&bytes[HEADER_LEN..]);
        bytes[16..24].copy_from_slice(&ck.to_le_bytes());
        let e = CompiledForest::from_artifact_bytes(&bytes).unwrap_err();
        assert!(e.contains("children") || e.contains("range"), "{e}");
    }

    #[test]
    fn linear_model_not_lowerable() {
        let ds = synthetic::adult_like(50, 243);
        let model = crate::learner::LinearLearner::default_config("income")
            .train(&ds)
            .unwrap();
        assert!(CompiledForest::lower(model.as_ref()).is_err());
        assert!(CompiledEngine::compile(model.as_ref()).is_none());
    }

    #[test]
    fn compiled_model_exposes_forest_metadata() {
        let ds = synthetic::adult_like(100, 245);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 4;
        cfg.max_depth = 3;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let forest = Arc::new(CompiledForest::lower(model.as_ref()).unwrap());
        let cm = CompiledModel::from_forest(Arc::clone(&forest));
        assert_eq!(cm.model_type(), "COMPILED_GRADIENT_BOOSTED_TREES");
        assert_eq!(cm.num_classes(), model.num_classes());
        assert_eq!(cm.input_features(), model.input_features());
        for r in 0..30 {
            bit_eq(&cm.predict_ds_row(&ds, r), &model.predict_ds_row(&ds, r), "model row");
        }
        assert!(cm.describe().contains("Compiled-forest artifact"));
        // An engine compiled *from* the CompiledModel shares the forest.
        let eng = CompiledEngine::compile(&cm).unwrap();
        assert_eq!(eng.name(), "GradientBoostedTreesCompiled");
    }
}
