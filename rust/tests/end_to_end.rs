//! End-to-end integration: CSV → dataspec → train → save/load → engines →
//! evaluation, across learner families; plus the benchmark harness's
//! expected orderings on a small suite. Deterministic model builders come
//! from `tests/common/mod.rs`.

mod common;

use std::collections::HashMap;
use ydf::dataset::csv::{read_csv_str, write_csv_string};
use ydf::dataset::dataspec::InferenceOptions;
use ydf::dataset::synthetic;
use ydf::evaluation::evaluate_model;
use ydf::inference::compile_engines;
use ydf::learner::create_learner;
use ydf::model::io::{model_from_string, model_to_string};

#[test]
fn csv_roundtrip_train_eval_all_learners() {
    let raw = synthetic::adult_like(500, 201);
    let csv = write_csv_string(&raw);
    let ds = read_csv_str(&csv, &InferenceOptions::default()).unwrap();

    for learner_name in ["GRADIENT_BOOSTED_TREES", "RANDOM_FOREST", "CART", "LINEAR"] {
        let mut params = HashMap::new();
        params.insert("num_trees".to_string(), "10".to_string());
        let learner = create_learner(learner_name, "income", &params).unwrap();
        let model = learner.train(&ds).unwrap();
        let ev = evaluate_model(model.as_ref(), &ds, "income").unwrap();
        assert!(ev.accuracy > 0.65, "{learner_name}: accuracy {}", ev.accuracy);

        // Serialization round-trip preserves predictions.
        let text = model_to_string(model.as_ref());
        let loaded = model_from_string(&text).unwrap();
        for r in [0usize, 13, 77] {
            let a = model.predict_ds_row(&ds, r);
            let b = loaded.predict_ds_row(&ds, r);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-9, "{learner_name} row {r}");
            }
        }
    }
}

#[test]
fn engines_agree_on_every_row() {
    let ds = synthetic::adult_like(300, 203);
    let model = common::adult_gbt(300, 203, 12, 5);
    let engines = compile_engines(model.as_ref());
    assert!(engines.len() >= 3, "expected QuickScorer+Flat+Naive");
    let reference = engines.last().unwrap().predict_dataset(&ds); // naive
    for e in &engines {
        let preds = e.predict_dataset(&ds);
        for (r, (a, b)) in preds.iter().zip(&reference).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-9, "{} row {r}: {a:?} vs {b:?}", e.name());
            }
        }
    }
}

/// The RF builder and the mixed-semantic GBT builder from the shared
/// fixture layer produce models every engine path agrees on — the
/// fixtures are safe foundations for bit-identity tests elsewhere.
#[test]
fn fixture_models_are_deterministic_and_consistent() {
    // Same arguments → the same model, prediction for prediction (the
    // serving tests rebuild references from seeds and rely on this).
    let ds = synthetic::adult_like(150, 331);
    let m1 = common::adult_gbt(150, 331, 4, 3);
    let m2 = common::adult_gbt(150, 331, 4, 3);
    let rf1 = common::adult_rf(150, 331, 5);
    let rf2 = common::adult_rf(150, 331, 5);
    for r in 0..ds.num_rows() {
        assert_eq!(m1.predict_ds_row(&ds, r), m2.predict_ds_row(&ds, r), "gbt row {r}");
        assert_eq!(rf1.predict_ds_row(&ds, r), rf2.predict_ds_row(&ds, r), "rf row {r}");
    }
    let (mixed_model, mixed) = common::mixed_gbt(120, 3, 77);
    let engines = compile_engines(mixed_model.as_ref());
    let reference = engines.last().unwrap().predict_dataset(&mixed);
    for e in &engines {
        let preds = e.predict_dataset(&mixed);
        for (r, (a, b)) in preds.iter().zip(&reference).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-9, "{} row {r}", e.name());
            }
        }
    }
}

#[test]
fn template_param_changes_model_structure() {
    let ds = synthetic::adult_like(300, 205);
    let mut params = HashMap::new();
    params.insert("num_trees".to_string(), "5".to_string());
    let default = create_learner("GRADIENT_BOOSTED_TREES", "income", &params)
        .unwrap()
        .train(&ds)
        .unwrap();
    params.insert("template".to_string(), "benchmark_rank1@v1".to_string());
    let benchmark = create_learner("GRADIENT_BOOSTED_TREES", "income", &params)
        .unwrap()
        .train(&ds)
        .unwrap();
    // The benchmark template enables oblique splits: the describe report
    // must show ObliqueCondition nodes; the default must not.
    assert!(!default.describe().contains("ObliqueCondition"));
    assert!(benchmark.describe().contains("ObliqueCondition"));
}

#[test]
fn histogram_splitter_faster_than_exact_on_large_numeric() {
    // §3.8: approximate splitting gives "a significant speed-up". Shape
    // check on a larger numeric dataset.
    use ydf::learner::gbt::GbtConfig;
    use ydf::learner::{GradientBoostedTreesLearner, Learner};
    use ydf::splitter::NumericalSplit;
    let spec = synthetic::spec_by_name("Eletricity").unwrap();
    let opts = synthetic::GenOptions { max_examples: 4000, ..Default::default() };
    let ds = synthetic::generate(spec, 207, &opts);

    let mut exact = GbtConfig::new("label");
    exact.num_trees = 10;
    exact.validation_ratio = 0.0;
    exact.early_stopping = ydf::learner::gbt::EarlyStopping::None;
    let mut hist = exact.clone();
    hist.splitter.numerical = NumericalSplit::Histogram { bins: 255 };

    let t0 = std::time::Instant::now();
    let m_exact = GradientBoostedTreesLearner::new(exact).train(&ds).unwrap();
    let t_exact = t0.elapsed();
    let t0 = std::time::Instant::now();
    let m_hist = GradientBoostedTreesLearner::new(hist).train(&ds).unwrap();
    let t_hist = t0.elapsed();

    let acc_exact = ydf::evaluation_free_accuracy(m_exact.as_ref(), &ds);
    let acc_hist = ydf::evaluation_free_accuracy(m_hist.as_ref(), &ds);
    assert!(
        t_hist < t_exact,
        "histogram {t_hist:?} should be faster than exact {t_exact:?}"
    );
    // Quality roughly preserved (within 5 accuracy points on train).
    assert!(acc_hist > acc_exact - 0.05, "hist {acc_hist} vs exact {acc_exact}");
}

#[test]
fn gbt_beats_rf_beats_linear_on_nonlinear_task() {
    // The paper's aggregate ordering (§5.5): GBT > RF on accuracy; both
    // beat linear on a nonlinear task.
    // Aggregate over several datasets, as the paper's claim is about the
    // mean over the suite, not any single dataset.
    use ydf::evaluation::cv::cross_validate;
    let opts = synthetic::GenOptions { max_examples: 800, ..Default::default() };
    let mut sum_gbt = 0.0;
    let mut sum_rf = 0.0;
    let mut sum_lin = 0.0;
    for name in ["Vehicule", "TicTacToe", "Phoneme", "Credit_Approval"] {
        let ds = synthetic::generate(synthetic::spec_by_name(name).unwrap(), 209, &opts);
        let mut params = HashMap::new();
        params.insert("num_trees".to_string(), "25".to_string());
        let gbt = create_learner("GRADIENT_BOOSTED_TREES", "label", &params).unwrap();
        let rf = create_learner("RANDOM_FOREST", "label", &params).unwrap();
        let lin = create_learner("LINEAR", "label", &HashMap::new()).unwrap();
        sum_gbt += cross_validate(gbt.as_ref(), &ds, 3, 7).unwrap().mean_accuracy();
        sum_rf += cross_validate(rf.as_ref(), &ds, 3, 7).unwrap().mean_accuracy();
        sum_lin += cross_validate(lin.as_ref(), &ds, 3, 7).unwrap().mean_accuracy();
    }
    // At this scaled-down budget (25 trees, 800 examples) the paper's
    // aggregate ordering holds in weak form: tree ensembles competitive
    // with or better than linear, and at least one clearly above it.
    assert!(sum_gbt > sum_lin - 0.03, "gbt {sum_gbt} vs linear {sum_lin}");
    assert!(sum_rf > sum_lin - 0.03, "rf {sum_rf} vs linear {sum_lin}");
    assert!(
        sum_gbt.max(sum_rf) > sum_lin,
        "best tree ensemble {} must beat linear {sum_lin}",
        sum_gbt.max(sum_rf)
    );
    assert!(sum_gbt > sum_rf - 0.10, "gbt {sum_gbt} vs rf {sum_rf}");
}
