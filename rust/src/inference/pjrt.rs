//! PJRT/XLA inference engine: executes the AOT artifact produced by the
//! build-time JAX + Pallas layers (`python/compile/aot.py`) through the
//! PJRT C API.
//!
//! The artifact is a *padded-tensor* forest evaluator with fixed shapes —
//! the "tensorized" adaptation of QuickScorer's insight for accelerators
//! (DESIGN.md §Hardware-Adaptation). Compilation is **lossy** in the §3.7
//! sense: only binary GBT models over numerical features with `Higher`
//! conditions are supported, missing values are mean-imputed before
//! packing, and models exceeding the padded shapes are rejected.

use super::InferenceEngine;
use crate::dataset::{AttrValue, ColumnData, Dataset, FeatureSemantic, Observation};
use crate::model::forest::{GbtLoss, GradientBoostedTreesModel};
use crate::model::tree::Condition;
use crate::model::Model;
use crate::runtime::{literal_f32, literal_i32, to_vec_f32, Executable, Runtime};

/// Padded shapes — must match python/compile/aot.py.
pub const BATCH: usize = 64;
pub const MAX_TREES: usize = 64;
pub const MAX_NODES: usize = 256;
pub const MAX_FEATURES: usize = 16;
pub const MAX_DEPTH: usize = 12;

/// The packed model tensors.
struct PackedForest {
    node_feature: Vec<i32>,  // [T, N], -1 = leaf
    node_threshold: Vec<f32>, // [T, N]
    node_pos: Vec<i32>,       // [T, N]
    node_neg: Vec<i32>,       // [T, N]
    leaf_value: Vec<f32>,     // [T, N]
    initial: f32,
    /// Numerical feature columns used, in packed order.
    feature_cols: Vec<usize>,
    /// Global means for imputation, aligned with `feature_cols`.
    feature_means: Vec<f32>,
}

pub struct PjrtEngine {
    exe: Executable,
    packed: PackedForest,
    num_classes: usize,
}

// SAFETY: the `xla` crate stores its PJRT handles behind `Rc` + raw
// pointers without Send/Sync annotations, but the PJRT CPU client is
// thread-safe for execution and `PjrtEngine` never clones the `Rc` or
// hands the raw handles out; all access goes through `&self`.
unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    /// Compiles `model` into the PJRT engine, if compatible. Requires the
    /// `forest.hlo.txt` artifact (built by `make artifacts`).
    pub fn compile(model: &dyn Model, runtime: &Runtime) -> Result<PjrtEngine, String> {
        let gbt = model
            .as_any()
            .downcast_ref::<GradientBoostedTreesModel>()
            .ok_or("PJRT engine supports GRADIENT_BOOSTED_TREES models only")?;
        if gbt.loss != GbtLoss::BinomialLogLikelihood {
            return Err("PJRT engine supports the binomial loss only".to_string());
        }
        if gbt.trees.len() > MAX_TREES {
            return Err(format!(
                "model has {} trees; the compiled artifact supports up to {MAX_TREES}",
                gbt.trees.len()
            ));
        }
        // Collect used numerical features.
        let mut feature_cols: Vec<usize> = Vec::new();
        for t in &gbt.trees {
            if t.num_nodes() > MAX_NODES {
                return Err(format!(
                    "a tree has {} nodes; the artifact supports up to {MAX_NODES}",
                    t.num_nodes()
                ));
            }
            if t.max_depth() > MAX_DEPTH {
                return Err(format!(
                    "a tree has depth {}; the artifact supports up to {MAX_DEPTH}",
                    t.max_depth()
                ));
            }
            for n in &t.nodes {
                match &n.condition {
                    None => {}
                    Some(Condition::Higher { attr, .. }) => {
                        if gbt.spec.columns[*attr].semantic != FeatureSemantic::Numerical {
                            return Err("non-numerical feature in model".to_string());
                        }
                        if !feature_cols.contains(attr) {
                            feature_cols.push(*attr);
                        }
                    }
                    Some(c) => {
                        return Err(format!(
                            "condition {} is not supported by the PJRT engine",
                            c.type_name()
                        ))
                    }
                }
            }
        }
        feature_cols.sort_unstable();
        if feature_cols.len() > MAX_FEATURES {
            return Err(format!(
                "model uses {} features; the artifact supports up to {MAX_FEATURES}",
                feature_cols.len()
            ));
        }
        let feature_means: Vec<f32> = feature_cols
            .iter()
            .map(|&c| gbt.spec.columns[c].num_stats.mean as f32)
            .collect();
        let feat_slot = |attr: usize| feature_cols.iter().position(|&c| c == attr).unwrap();

        // Pack node tables. Padding trees are a single leaf with value 0.
        let mut node_feature = vec![-1i32; MAX_TREES * MAX_NODES];
        let mut node_threshold = vec![0.0f32; MAX_TREES * MAX_NODES];
        let mut node_pos = vec![0i32; MAX_TREES * MAX_NODES];
        let mut node_neg = vec![0i32; MAX_TREES * MAX_NODES];
        let mut leaf_value = vec![0.0f32; MAX_TREES * MAX_NODES];
        for (t, tree) in gbt.trees.iter().enumerate() {
            for (i, node) in tree.nodes.iter().enumerate() {
                let idx = t * MAX_NODES + i;
                match &node.condition {
                    None => {
                        node_feature[idx] = -1;
                        leaf_value[idx] = node.value[0];
                    }
                    Some(Condition::Higher { attr, threshold }) => {
                        node_feature[idx] = feat_slot(*attr) as i32;
                        node_threshold[idx] = *threshold;
                        node_pos[idx] = node.positive as i32;
                        node_neg[idx] = node.negative as i32;
                    }
                    _ => unreachable!(),
                }
            }
        }

        let artifact = crate::runtime::artifacts_dir().join("forest.hlo.txt");
        let exe = runtime.load_hlo_text(&artifact)?;

        Ok(PjrtEngine {
            exe,
            packed: PackedForest {
                node_feature,
                node_threshold,
                node_pos,
                node_neg,
                leaf_value,
                initial: gbt.initial_predictions[0] as f32,
                feature_cols,
                feature_means,
            },
            num_classes: 2,
        })
    }

    /// Executes one padded batch; `features` is [BATCH, MAX_FEATURES]
    /// row-major, already imputed.
    fn run_batch(&self, features: &[f32]) -> Result<Vec<f64>, String> {
        let p = &self.packed;
        let inputs = vec![
            literal_f32(features, &[BATCH as i64, MAX_FEATURES as i64])?,
            literal_i32(&p.node_feature, &[MAX_TREES as i64, MAX_NODES as i64])?,
            literal_f32(&p.node_threshold, &[MAX_TREES as i64, MAX_NODES as i64])?,
            literal_i32(&p.node_pos, &[MAX_TREES as i64, MAX_NODES as i64])?,
            literal_i32(&p.node_neg, &[MAX_TREES as i64, MAX_NODES as i64])?,
            literal_f32(&p.leaf_value, &[MAX_TREES as i64, MAX_NODES as i64])?,
            literal_f32(&[p.initial], &[1])?,
        ];
        let out = self.exe.run(&inputs)?;
        let probs = to_vec_f32(&out[0])?;
        Ok(probs.into_iter().map(|x| x as f64).collect())
    }

    /// Packs dataset rows [start, start+count) into the feature buffer.
    fn pack_ds(&self, ds: &Dataset, start: usize, count: usize, buf: &mut [f32]) {
        let p = &self.packed;
        buf.fill(0.0);
        for (slot, (&col, &mean)) in
            p.feature_cols.iter().zip(&p.feature_means).enumerate()
        {
            if let ColumnData::Numerical(v) = &ds.columns[col] {
                for i in 0..count {
                    let x = v[start + i];
                    buf[i * MAX_FEATURES + slot] = if x.is_nan() { mean } else { x };
                }
            }
        }
    }
}

impl InferenceEngine for PjrtEngine {
    fn name(&self) -> String {
        "GradientBoostedTreesPjrtXla".to_string()
    }

    fn output_dim(&self) -> usize {
        self.num_classes
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        let p = &self.packed;
        let mut buf = vec![0.0f32; BATCH * MAX_FEATURES];
        for (slot, (&col, &mean)) in
            p.feature_cols.iter().zip(&p.feature_means).enumerate()
        {
            buf[slot] = match &obs[col] {
                AttrValue::Num(x) if !x.is_nan() => *x,
                _ => mean,
            };
        }
        let probs = self.run_batch(&buf).expect("PJRT execution failed");
        vec![1.0 - probs[0], probs[0]]
    }

    /// Batch path: rows are packed into the artifact's padded [BATCH,
    /// MAX_FEATURES] tensor and the probabilities written straight into
    /// the caller's buffer. `predict_dataset` rides the trait default
    /// (block fan-out over this method).
    fn predict_batch(&self, ds: &Dataset, rows: std::ops::Range<usize>, out: &mut [f64]) {
        debug_assert_eq!(out.len(), rows.len() * 2);
        let mut buf = vec![0.0f32; BATCH * MAX_FEATURES];
        let mut start = rows.start;
        let mut off = 0usize;
        while start < rows.end {
            let count = BATCH.min(rows.end - start);
            self.pack_ds(ds, start, count, &mut buf);
            let probs = self.run_batch(&buf).expect("PJRT execution failed");
            for &p in probs.iter().take(count) {
                out[off] = 1.0 - p;
                out[off + 1] = p;
                off += 2;
            }
            start += count;
        }
    }
}

// Integration coverage for this engine lives in rust/tests/pjrt_roundtrip.rs
// (requires `make artifacts`).
