//! §3.8 ablation: exact in-sorting vs pre-sorted vs per-node Auto vs
//! approximate histogram numerical splitters — training time and train
//! accuracy trade-off (the design choice DESIGN.md E12 calls out).
//!
//! Run: cargo bench --bench splitter_ablation

use ydf::dataset::synthetic;
use ydf::learner::gbt::{EarlyStopping, GbtConfig};
use ydf::learner::{GradientBoostedTreesLearner, Learner};
use ydf::splitter::NumericalSplit;
use ydf::utils::bench::Table;

fn main() {
    let spec = synthetic::spec_by_name("Eletricity").unwrap();
    let opts = synthetic::GenOptions { max_examples: 6000, ..Default::default() };
    let ds = synthetic::generate(spec, 20230806, &opts);

    let variants: Vec<(&str, NumericalSplit)> = vec![
        ("exact in-sorting", NumericalSplit::ExactInSort),
        ("exact pre-sorted", NumericalSplit::Presorted),
        ("auto (per-node choice)", NumericalSplit::Auto),
        ("histogram 255 bins", NumericalSplit::Histogram { bins: 255 }),
        ("histogram 32 bins", NumericalSplit::Histogram { bins: 32 }),
    ];
    let mut t = Table::new(&["Splitter", "train (s)", "train accuracy"]);
    for (name, numerical) in variants {
        let mut cfg = GbtConfig::new("label");
        cfg.num_trees = 15;
        cfg.max_depth = 6;
        cfg.validation_ratio = 0.0;
        cfg.early_stopping = EarlyStopping::None;
        cfg.splitter.numerical = numerical;
        let t0 = std::time::Instant::now();
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let acc = ydf::evaluation_free_accuracy(model.as_ref(), &ds);
        t.row(vec![name.to_string(), format!("{secs:.2}"), format!("{acc:.4}")]);
    }
    println!("Splitter ablation (GBT, 15 trees, {} examples)\n{}", ds.num_rows(), t.render());
}
