#!/usr/bin/env bash
# Serving smoke test (`make serve-smoke`): train two models (GBT + RF),
# compile the GBT ones to mmap-able artifacts (`ydf compile`), serve
# JSON- and artifact-backed models behind one ephemeral port, and drive
# the multi-model wire protocol end to end: routed and default requests
# bit-identical to each model's offline `ydf predict` output (including
# the `.bin`-backed model), per-model stats, unknown-model and
# malformed-input error replies on a surviving connection, a live hot
# swap to an artifact-backed generation under concurrent traffic (zero
# dropped requests, post-swap replies bit-identical to the replacement's
# offline `ydf predict`), Prometheus metrics exposition ({"cmd":
# "metrics"} — every sample line syntax-checked, all three metric groups
# present, router decision counters included), measured engine routing
# (the default --calibrate=load pass reports a calibrated per-bucket
# table in health, before and after the swap), a load/unload round
# trip, and protocol shutdown. A second act covers the fleet routing
# tier: `ydf route` in front of two replica backends — routed replies
# bit-identical to offline predict, a SIGKILL of the rendezvous primary
# mid-traffic with zero dropped requests, re-admission of the restarted
# replica, and ydf_route_* metric families in the router's exposition.
# Exits non-zero on any mismatch.
set -euo pipefail

BIN=${BIN:-./target/release/ydf}
if [ ! -x "$BIN" ]; then
    echo "serve-smoke: $BIN not found; run 'cargo build --release' first" >&2
    exit 1
fi

TMP=$(mktemp -d)
SERVER_PID=""
B1_PID=""
B2_PID=""
ROUTER_PID=""
BR_PID=""
cleanup() {
    for pid in $SERVER_PID $B1_PID $B2_PID $ROUTER_PID $BR_PID; do
        kill "$pid" 2>/dev/null || true
    done
    rm -rf "$TMP"
}
trap cleanup EXIT

echo "serve-smoke: training two tiny models (GBT + RF)"
"$BIN" synth --name=Iris --output=csv:"$TMP/iris.csv" >/dev/null
"$BIN" train --dataset=csv:"$TMP/iris.csv" --label=label \
    --learner=GRADIENT_BOOSTED_TREES --param:num_trees=5 \
    --output="$TMP/model_gbt.json" >/dev/null
"$BIN" train --dataset=csv:"$TMP/iris.csv" --label=label \
    --learner=RANDOM_FOREST --param:num_trees=7 \
    --output="$TMP/model_rf.json" >/dev/null
# A third model to hot-swap in for "gbt" while traffic is in flight.
"$BIN" train --dataset=csv:"$TMP/iris.csv" --label=label \
    --learner=GRADIENT_BOOSTED_TREES --param:num_trees=9 \
    --output="$TMP/model_gbt2.json" >/dev/null

echo "serve-smoke: compiling the GBT models to artifacts (ydf compile)"
"$BIN" compile --model="$TMP/model_gbt.json" --output="$TMP/model_gbt.bin" >/dev/null
"$BIN" compile --model="$TMP/model_gbt2.json" --output="$TMP/model_gbt2.bin" >/dev/null

echo "serve-smoke: computing offline batch predictions for all models"
"$BIN" predict --dataset=csv:"$TMP/iris.csv" --model="$TMP/model_gbt.json" \
    --output=csv:"$TMP/preds_gbt.csv" >/dev/null
"$BIN" predict --dataset=csv:"$TMP/iris.csv" --model="$TMP/model_rf.json" \
    --output=csv:"$TMP/preds_rf.csv" >/dev/null
"$BIN" predict --dataset=csv:"$TMP/iris.csv" --model="$TMP/model_gbt2.json" \
    --output=csv:"$TMP/preds_gbt2.csv" >/dev/null

# Offline predictions through the compiled artifact must be byte-for-byte
# the JSON model's output — the `.bin` is a lossless lowering.
"$BIN" predict --dataset=csv:"$TMP/iris.csv" --model="$TMP/model_gbt.bin" \
    --output=csv:"$TMP/preds_cgbt.csv" >/dev/null
cmp "$TMP/preds_gbt.csv" "$TMP/preds_cgbt.csv" || {
    echo "serve-smoke: compiled-artifact predictions differ from the JSON model" >&2
    exit 1
}
echo "serve-smoke: ok: offline predict via .bin artifact is byte-identical"

echo "serve-smoke: starting the three-model server on an ephemeral port"
"$BIN" serve --model=gbt="$TMP/model_gbt.json" --model=rf="$TMP/model_rf.json" \
    --model=cgbt="$TMP/model_gbt.bin" \
    --port=0 --max-delay-ms=1 --score-threads=2 \
    >"$TMP/serve.log" 2>&1 &
SERVER_PID=$!

PORT=""
for _ in $(seq 100); do
    PORT=$(sed -n 's/^listening on .*:\([0-9][0-9]*\)$/\1/p' "$TMP/serve.log" | head -1)
    [ -n "$PORT" ] && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "serve-smoke: server died during startup:" >&2
        cat "$TMP/serve.log" >&2
        exit 1
    fi
    sleep 0.1
done
if [ -z "$PORT" ]; then
    echo "serve-smoke: server did not report its port:" >&2
    cat "$TMP/serve.log" >&2
    exit 1
fi
echo "serve-smoke: server is up on port $PORT"

python3 - "$PORT" "$TMP/iris.csv" "$TMP/preds_gbt.csv" "$TMP/preds_rf.csv" \
    "$TMP/preds_gbt2.csv" "$TMP/model_gbt2.bin" "$TMP/model_rf.json" <<'EOF'
import json, socket, sys, threading, time

port = int(sys.argv[1])

def read_csv(path):
    with open(path) as f:
        lines = [l.rstrip("\n") for l in f if l.strip()]
    header = lines[0].split(",")
    return header, [l.split(",") for l in lines[1:]]

def rpc(line):
    s = socket.create_connection(("127.0.0.1", port), timeout=10)
    s.sendall((line + "\n").encode())
    resp = s.makefile().readline()
    s.close()
    return json.loads(resp)

checks = 0
def check(cond, what):
    global checks
    if not cond:
        raise SystemExit(f"serve-smoke: FAILED: {what}")
    checks += 1
    print(f"serve-smoke: ok: {what}")

health = rpc(json.dumps({"cmd": "health"}))
check(health.get("ok") is True, "health reports ok")
check(health.get("models") == ["gbt", "rf", "cgbt"],
      "health lists all three models (incl. the artifact-backed one)")
check(health.get("model") == "gbt", "first registered model is the default")

# Measured engine routing: the default --calibrate=load ran a
# micro-calibration pass at model load (no cached table existed for the
# freshly trained models), so health must report a calibrated router
# with one pinned engine tag per batch-size bucket.
router = health.get("router", {})
check(router.get("calibrated") is True,
      "health: default --calibrate=load measured a routing table")
buckets = router.get("buckets", {})
check(set(buckets) == {"1", "8", "64", "512"},
      "health: router pins every batch-size bucket")
check(all(isinstance(t, str) and "[" in t for t in buckets.values()),
      f"health: every bucket names an engine[lane] variant: {buckets}")

spec = rpc(json.dumps({"cmd": "spec"}))
label = spec["label"]
check(len(spec["features"]) > 0 and len(spec["classes"]) > 0,
      "spec lists features and classes")
rf_spec = rpc(json.dumps({"cmd": "spec", "model": "rf"}))
check(rf_spec.get("model") == "rf", "spec routes by the model field")

# Request rows straight from the training CSV: every cell is sent as its
# raw string, so the server's string->f32 parse is byte-for-byte the same
# parse the offline CSV reader did — the predictions must then be
# bit-identical to `ydf predict` output for the same model.
N = 40
header, data = read_csv(sys.argv[2])
rows = []
for cells in data[:N]:
    row = {}
    for name, cell in zip(header, cells):
        if name != label and cell != "":
            row[name] = cell
    rows.append(row)

def offline(path):
    _, pred_rows = read_csv(path)
    return [[float(x) for x in cells] for cells in pred_rows]

offline_preds = {"gbt": offline(sys.argv[3]), "rf": offline(sys.argv[4])}

for name in ("gbt", "rf"):
    resp = rpc(json.dumps({"model": name, "rows": rows}))
    check(resp.get("model") == name, f"response names model '{name}'")
    preds = resp["predictions"]
    check(len(preds) == N, f"model '{name}': one prediction per request row")
    exact = all(
        served == expected
        for served, expected in zip(preds, offline_preds[name][:N])
    )
    check(exact, f"model '{name}': served == offline predict, bit for bit")

check(offline_preds["gbt"][:N] != offline_preds["rf"][:N],
      "the two models genuinely disagree (the routing test is meaningful)")

# The artifact-backed model ("cgbt" serves model_gbt.bin) must answer the
# exact same bits as the JSON-backed "gbt" — one forest, two storage
# formats, one compiled-vs-naive differential contract.
cgbt = rpc(json.dumps({"model": "cgbt", "rows": rows}))
check(cgbt.get("model") == "cgbt" and cgbt["predictions"] == offline_preds["gbt"][:N],
      "artifact-backed model serves bit-identically to its JSON source")

# Requests without a "model" field go to the default model (gbt) — the
# single-model wire protocol is preserved.
default = rpc(json.dumps({"rows": rows[:3]}))
check(default.get("model") == "gbt"
      and default["predictions"] == offline_preds["gbt"][:3],
      "default-routed request served by the first model, bit-identical")

single = rpc(json.dumps(rows[0]))
check(single.get("model") == "gbt" and len(single["predictions"]) == 1,
      "single-row shorthand goes to the default model")
check(abs(sum(single["predictions"][0]) - 1.0) < 1e-9, "probabilities sum to 1")

# Unknown model: a clean in-band error reply, not a dropped connection —
# the same socket answers a valid request right after.
s = socket.create_connection(("127.0.0.1", port), timeout=10)
f = s.makefile()
s.sendall((json.dumps({"model": "nope", "rows": [rows[0]]}) + "\n").encode())
err = json.loads(f.readline())
check("nope" in err.get("error", "") and "gbt" in err.get("error", ""),
      "unknown model gets an error naming the registered models")
s.sendall((json.dumps({"rows": [rows[0]]}) + "\n").encode())
again = json.loads(f.readline())
check("predictions" in again, "connection survives an unknown-model error")
s.close()

bad = rpc("this is { not json")
check("error" in bad, "malformed JSON answers with an in-band error")

unknown = rpc(json.dumps({"rows": [{"no_such_feature": 1}]}))
check("no_such_feature" in unknown.get("error", ""),
      "unknown feature error names the offender")

stats = rpc(json.dumps({"cmd": "stats"}))
check(stats["requests"] >= 5, "aggregate stats counted the requests")
check(stats["errors"] >= 3, "aggregate stats counted the error responses")
per_model = stats.get("models", {})
check(per_model.get("gbt", {}).get("requests", 0) >= 4,
      "per-model stats reported for 'gbt'")
check(per_model.get("rf", {}).get("requests", 0) >= 1,
      "per-model stats reported for 'rf'")
check(per_model.get("rf", {}).get("errors", 1) == 0,
      "errors are attributed per model, not smeared")
check(per_model.get("cgbt", {}).get("requests", 0) >= 1,
      "per-model stats reported for the artifact-backed model")
check(stats.get("overlong_lines") == 0,
      "stats expose the overlong-line counter (and nothing tripped it)")

# --- Observability: Prometheus exposition over the wire ---------------
# By this point the server has answered requests (serving counters),
# flushed coalesced batches (per-engine flush counters) and built its
# scoring pool (--score-threads=2 → pool gauges), so all three metric
# groups must appear, and every sample line must parse as Prometheus
# text exposition.
import re
metrics = rpc(json.dumps({"cmd": "metrics"}))
check(metrics.get("content_type", "").startswith("text/plain"),
      "metrics reply declares the Prometheus text content type")
body = metrics.get("metrics")
check(isinstance(body, str) and body.strip() != "",
      "metrics body is a non-empty string")
sample_re = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? [-+]?[0-9]*\.?[0-9]+([eE][-+]?[0-9]+)?$')
lines = [l for l in body.splitlines() if l and not l.startswith("#")]
bad_lines = [l for l in lines if not sample_re.match(l)]
check(not bad_lines,
      f"every metrics sample parses as exposition syntax: {bad_lines[:3]}")
check(len(lines) > 0, f"metrics exposition carries samples ({len(lines)})")
check('ydf_serving_requests_total{model="gbt"}' in body,
      "serving counters exposed per model")
check('ydf_serving_latency_us{model="gbt",quantile="0.5"}' in body,
      "latency summary exposed with quantile labels")
check("ydf_flush_total" in body, "per-engine flush counters exposed")
check("ydf_pool_workers_total" in body, "scoring-pool metrics exposed")
check("ydf_router_decisions_total" in body,
      "router decision counters exposed with engine and bucket labels")
check("ydf_serving_overlong_lines_total" in body,
      "overlong-line counter exposed per model")

# --- Control plane: hot swap to an artifact-backed generation ---------
# The replacement path is model_gbt2.bin: the server's swap handler goes
# through the same magic-sniffing loader as startup, so the incoming
# generation runs the compiled engine off the mmap-ed artifact.
offline_gbt2 = offline(sys.argv[5])
model_gbt2_path, model_rf_path = sys.argv[6], sys.argv[7]
check(offline_preds["gbt"][:N] != offline_gbt2[:N],
      "the replacement model genuinely disagrees with the original")

stop = threading.Event()
dropped, errors, served = [], [], [0]
alock = threading.Lock()

def hammer():
    # One long-lived connection per client: a dropped request would show
    # up as a reply-less line (EOF) — exactly what must never happen.
    s = socket.create_connection(("127.0.0.1", port), timeout=10)
    f = s.makefile()
    req = json.dumps({"model": "gbt", "rows": rows[:4]}) + "\n"
    while not stop.is_set():
        s.sendall(req.encode())
        line = f.readline()
        if not line:
            with alock:
                dropped.append("connection closed without a reply")
            return
        resp = json.loads(line)
        with alock:
            if "predictions" in resp:
                served[0] += 1
            else:
                errors.append(resp.get("error", str(resp)))
    s.close()

def served_at_least(n):
    deadline = time.time() + 30
    while time.time() < deadline:
        with alock:
            if served[0] >= n or dropped:
                return
        time.sleep(0.01)
    raise SystemExit("serve-smoke: FAILED: swap traffic stalled")

threads = [threading.Thread(target=hammer) for _ in range(3)]
for t in threads:
    t.start()
served_at_least(10)  # traffic is flowing before the swap lands
swap = rpc(json.dumps({"cmd": "swap", "model": "gbt", "path": model_gbt2_path}))
check(swap.get("ok") is True and swap.get("generation", 0) > 0,
      "live swap to the .bin artifact acknowledged with a new generation")
with alock:
    after_swap_target = served[0] + 10
served_at_least(after_swap_target)  # the new generation is serving
stop.set()
for t in threads:
    t.join()
check(not dropped, "zero requests dropped across the swap")
# The only tolerable in-band replies at the swap instant are drain
# rejections from the retiring generation — anything else is a bug.
bad = [e for e in errors if "shutting down" not in e]
check(not bad, f"no unexpected error replies across the swap: {bad[:3]}")

after = rpc(json.dumps({"model": "gbt", "rows": rows}))
check(after["predictions"] == offline_gbt2[:N],
      "post-swap artifact-backed serving is bit-identical to the "
      "replacement's offline predict")

# The old generation drains to Retired, visible in the transition log.
states, retired = {}, False
for _ in range(100):
    health = rpc(json.dumps({"cmd": "health"}))
    states = health.get("states", {})
    if any(t.get("state") == "Retired" for t in health.get("transitions", [])):
        retired = True
        break
    time.sleep(0.1)
check(retired, "old generation drained to Retired in the transition log")
check(states.get("gbt") == "Serving" and states.get("rf") == "Serving"
      and states.get("cgbt") == "Serving",
      "all live models report Serving after the swap")
check(health.get("router", {}).get("calibrated") is True,
      "the swapped-in generation was calibrated too (load went through "
      "the same --calibrate policy as startup)")

stats = rpc(json.dumps({"cmd": "stats"}))
check(stats.get("reloads", 0) == 1, "aggregate stats counted the reload")
check(stats.get("models", {}).get("gbt", {}).get("reloads", 0) == 1,
      "the reload is attributed to the swapped model")

# Load/unload round trip: a third model comes and goes on the live server.
loaded = rpc(json.dumps({"cmd": "load", "model": "extra", "path": model_rf_path}))
check(loaded.get("ok") is True, "live load of a third model acknowledged")
via_extra = rpc(json.dumps({"model": "extra", "rows": rows[:5]}))
check(via_extra.get("predictions") == offline_preds["rf"][:5],
      "the freshly loaded model serves bit-identically to offline predict")
gone = rpc(json.dumps({"cmd": "unload", "model": "extra"}))
check(gone.get("ok") is True, "unload acknowledged")
unknown_again = rpc(json.dumps({"model": "extra", "rows": rows[:1]}))
check("extra" in unknown_again.get("error", ""),
      "an unloaded model is unknown again")

bye = rpc(json.dumps({"cmd": "shutdown"}))
check(bye.get("ok") is True, "shutdown acknowledged")
print(f"serve-smoke: all {checks} checks passed")
EOF

echo "serve-smoke: waiting for server to exit"
for _ in $(seq 100); do
    kill -0 "$SERVER_PID" 2>/dev/null || break
    sleep 0.1
done
if kill -0 "$SERVER_PID" 2>/dev/null; then
    echo "serve-smoke: server still running after shutdown command" >&2
    exit 1
fi
SERVER_PID=""
grep -q "server stopped" "$TMP/serve.log" || {
    echo "serve-smoke: server log missing clean-stop marker" >&2
    exit 1
}
grep -q "serving model 'rf'" "$TMP/serve.log" || {
    echo "serve-smoke: server log missing the second model's startup line" >&2
    exit 1
}
grep -q "serving model 'cgbt'" "$TMP/serve.log" || {
    echo "serve-smoke: server log missing the artifact-backed model's startup line" >&2
    exit 1
}

# --- Act two: the fleet routing tier ----------------------------------
# Two replica backends serving the same model behind one `ydf route`
# front end. The router speaks the identical wire protocol, so the same
# python harness drives it: bit-identity through the extra hop, then a
# SIGKILL of whichever replica rendezvous hashing made the primary while
# traffic is in flight (zero dropped requests, only retryable in-band
# errors), then a restart on the same port and probe-driven re-admission.

wait_port() { # wait_port LOGFILE PID — echoes the port from "listening on"
    local port=""
    for _ in $(seq 100); do
        port=$(sed -n 's/^listening on .*:\([0-9][0-9]*\)$/\1/p' "$1" | head -1)
        [ -n "$port" ] && break
        if ! kill -0 "$2" 2>/dev/null; then
            echo "serve-smoke: process died during startup:" >&2
            cat "$1" >&2
            return 1
        fi
        sleep 0.1
    done
    if [ -z "$port" ]; then
        echo "serve-smoke: process did not report its port:" >&2
        cat "$1" >&2
        return 1
    fi
    echo "$port"
}

# --workers=8 on every process in the fleet: the router's pooled
# forwarding connections occupy backend workers for as long as they sit
# in the reuse pool, and the health probe plus the direct shutdown
# client need free workers on top of the concurrent request lanes.
echo "serve-smoke: starting two replica backends for the routing tier"
"$BIN" serve --model=iris="$TMP/model_gbt.json" --port=0 --max-delay-ms=1 \
    --workers=8 >"$TMP/backend1.log" 2>&1 &
B1_PID=$!
"$BIN" serve --model=iris="$TMP/model_gbt.json" --port=0 --max-delay-ms=1 \
    --workers=8 >"$TMP/backend2.log" 2>&1 &
B2_PID=$!
B1_PORT=$(wait_port "$TMP/backend1.log" "$B1_PID")
B2_PORT=$(wait_port "$TMP/backend2.log" "$B2_PID")
echo "serve-smoke: replica backends up on ports $B1_PORT and $B2_PORT"

"$BIN" route --backend=127.0.0.1:"$B1_PORT" --backend=127.0.0.1:"$B2_PORT" \
    --port=0 --workers=8 --probe-interval-ms=100 --backoff-base-ms=5 \
    --backoff-cap-ms=50 >"$TMP/route.log" 2>&1 &
ROUTER_PID=$!
ROUTER_PORT=$(wait_port "$TMP/route.log" "$ROUTER_PID")
echo "serve-smoke: router is up on port $ROUTER_PORT"

python3 - "$ROUTER_PORT" "$TMP/iris.csv" "$TMP/preds_gbt.csv" \
    "$B1_PID" "$B1_PORT" "$B2_PID" "$B2_PORT" "$TMP/victim_port" <<'EOF'
import json, os, signal, socket, sys, threading, time

port = int(sys.argv[1])
port_pid = {int(sys.argv[5]): int(sys.argv[4]), int(sys.argv[7]): int(sys.argv[6])}

def read_csv(path):
    with open(path) as f:
        lines = [l.rstrip("\n") for l in f if l.strip()]
    return lines[0].split(","), [l.split(",") for l in lines[1:]]

def rpc(line):
    s = socket.create_connection(("127.0.0.1", port), timeout=10)
    s.sendall((line + "\n").encode())
    resp = s.makefile().readline()
    s.close()
    return json.loads(resp)

checks = 0
def check(cond, what):
    global checks
    if not cond:
        raise SystemExit(f"serve-smoke: FAILED: {what}")
    checks += 1
    print(f"serve-smoke: ok: {what}")

N = 40
header, data = read_csv(sys.argv[2])
_, pred_rows = read_csv(sys.argv[3])
offline = [[float(x) for x in cells] for cells in pred_rows]
rows = []
for cells in data[:N]:
    rows.append({name: cell for name, cell in zip(header, cells)
                 if name != "label" and cell != ""})

health = rpc(json.dumps({"cmd": "health"}))
check(health.get("ok") is True and "router" in health,
      "router health carries a router block")
backends = health["router"]["backends"]
check(len(backends) == 2 and all("state" in b for b in backends),
      "router health lists both replica backends with health states")

resp = rpc(json.dumps({"model": "iris", "rows": rows}))
check(resp.get("predictions") == offline[:N],
      "routed predictions are bit-identical to offline predict")

metrics = rpc(json.dumps({"cmd": "metrics"}))["metrics"]
check('ydf_route_forwarded_total' in metrics,
      "router metrics expose ydf_route_forwarded_total")
check('ydf_route_backend_up' in metrics,
      "router metrics expose the per-backend up gauge")

# Rendezvous hashing sends every "iris" request to one primary; find it
# from the per-backend forwarded counters so the SIGKILL provably forces
# failover instead of landing on the idle replica.
health = rpc(json.dumps({"cmd": "health"}))
fwd = {b["addr"]: b.get("forwarded", 0) for b in health["router"]["backends"]}
primary = max(fwd, key=fwd.get)
check(fwd[primary] > 0, f"the rendezvous primary for 'iris' took traffic ({fwd})")
victim_port = int(primary.rsplit(":", 1)[1])

stop = threading.Event()
dropped, bad, served = [], [], [0]
alock = threading.Lock()

def hammer():
    s = socket.create_connection(("127.0.0.1", port), timeout=10)
    f = s.makefile()
    req = json.dumps({"model": "iris", "rows": rows[:4]}) + "\n"
    while not stop.is_set():
        s.sendall(req.encode())
        line = f.readline()
        if not line:
            with alock:
                dropped.append("connection closed without a reply")
            return
        r = json.loads(line)
        with alock:
            if r.get("predictions") == offline[:4]:
                served[0] += 1
            elif "error" in r and r.get("retryable") is True:
                pass  # in-band degradation is the contract under failure
            else:
                bad.append(line.strip())
    s.close()

def served_at_least(n):
    deadline = time.time() + 30
    while time.time() < deadline:
        with alock:
            if served[0] >= n or dropped:
                return
        time.sleep(0.01)
    raise SystemExit("serve-smoke: FAILED: routed traffic stalled")

threads = [threading.Thread(target=hammer) for _ in range(3)]
for t in threads:
    t.start()
served_at_least(10)
os.kill(port_pid[victim_port], signal.SIGKILL)
print(f"serve-smoke: SIGKILLed the primary replica on port {victim_port}")
with alock:
    target = served[0] + 30
served_at_least(target)  # the survivor is carrying the load
stop.set()
for t in threads:
    t.join()
check(not dropped, "zero requests dropped across the replica kill")
check(not bad, f"survivor replies bit-identical; failures retryable: {bad[:3]}")

state = None
for _ in range(100):
    health = rpc(json.dumps({"cmd": "health"}))
    state = next((b.get("state") for b in health["router"]["backends"]
                  if b["addr"] == primary), None)
    if state == "Down":
        break
    time.sleep(0.1)
check(state == "Down", "router probes mark the SIGKILLed replica Down")

metrics = rpc(json.dumps({"cmd": "metrics"}))["metrics"]
check('ydf_route_retries_total' in metrics and 'ydf_route_failovers_total' in metrics,
      "retry and failover counters exposed after the kill")

with open(sys.argv[8], "w") as f:
    f.write(str(victim_port))
print(f"serve-smoke: routing act 1: all {checks} checks passed")
EOF

VICTIM_PORT=$(cat "$TMP/victim_port")
echo "serve-smoke: restarting the killed backend on port $VICTIM_PORT"
"$BIN" serve --model=iris="$TMP/model_gbt.json" --port="$VICTIM_PORT" \
    --max-delay-ms=1 --workers=8 >"$TMP/backend_restart.log" 2>&1 &
BR_PID=$!
wait_port "$TMP/backend_restart.log" "$BR_PID" >/dev/null

python3 - "$ROUTER_PORT" "$TMP/iris.csv" "$TMP/preds_gbt.csv" \
    "$VICTIM_PORT" "$B1_PORT" "$B2_PORT" <<'EOF'
import json, socket, sys, time

port = int(sys.argv[1])

def read_csv(path):
    with open(path) as f:
        lines = [l.rstrip("\n") for l in f if l.strip()]
    return lines[0].split(","), [l.split(",") for l in lines[1:]]

def rpc_at(p, line):
    s = socket.create_connection(("127.0.0.1", p), timeout=10)
    s.sendall((line + "\n").encode())
    resp = s.makefile().readline()
    s.close()
    return json.loads(resp)

def rpc(line):
    return rpc_at(port, line)

checks = 0
def check(cond, what):
    global checks
    if not cond:
        raise SystemExit(f"serve-smoke: FAILED: {what}")
    checks += 1
    print(f"serve-smoke: ok: {what}")

N = 40
header, data = read_csv(sys.argv[2])
_, pred_rows = read_csv(sys.argv[3])
offline = [[float(x) for x in cells] for cells in pred_rows]
rows = []
for cells in data[:N]:
    rows.append({name: cell for name, cell in zip(header, cells)
                 if name != "label" and cell != ""})

victim = f"127.0.0.1:{sys.argv[4]}"
state = None
for _ in range(100):
    health = rpc(json.dumps({"cmd": "health"}))
    state = next((b.get("state") for b in health["router"]["backends"]
                  if b["addr"] == victim), None)
    if state == "Healthy":
        break
    time.sleep(0.1)
check(state == "Healthy",
      "restarted replica re-admitted by the probe loop (Recovering -> Healthy)")

resp = rpc(json.dumps({"model": "iris", "rows": rows}))
check(resp.get("predictions") == offline[:N],
      "post-recovery routed predictions are bit-identical to offline predict")

bye = rpc(json.dumps({"cmd": "shutdown"}))
check(bye.get("ok") is True, "router shutdown acknowledged")
for p in (int(sys.argv[5]), int(sys.argv[6])):
    gone = rpc_at(p, json.dumps({"cmd": "shutdown"}))
    check(gone.get("ok") is True, f"backend on port {p} shutdown acknowledged")
print(f"serve-smoke: routing act 2: all {checks} checks passed")
EOF

echo "serve-smoke: waiting for the routing fleet to exit"
for pid in $ROUTER_PID $BR_PID $B1_PID $B2_PID; do
    for _ in $(seq 100); do
        kill -0 "$pid" 2>/dev/null || break
        sleep 0.1
    done
    if kill -0 "$pid" 2>/dev/null; then
        echo "serve-smoke: routing process $pid still running after shutdown" >&2
        exit 1
    fi
done
ROUTER_PID=""; BR_PID=""; B1_PID=""; B2_PID=""
grep -q "routing to backend" "$TMP/route.log" || {
    echo "serve-smoke: router log missing its backend roster" >&2
    exit 1
}
grep -q "router stopped" "$TMP/route.log" || {
    echo "serve-smoke: router log missing clean-stop marker" >&2
    exit 1
}
echo "serve-smoke: PASS"
