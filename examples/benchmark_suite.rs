//! END-TO-END DRIVER: the §5 benchmark on a real (synthetic-suite)
//! workload — 16 learners × datasets × K-fold CV — regenerating Figure 6
//! and Tables 2/3/4/5/6/7. This is the headline experiment of the paper;
//! the run is recorded in EXPERIMENTS.md.
//!
//! Run:        cargo run --release --example benchmark_suite
//! Bigger run: cargo run --release --example benchmark_suite -- --trees=50 --folds=5

use ydf::benchmark::{run_suite, table5_report, SuiteConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut config = SuiteConfig::default();
    for a in &args {
        if let Some(v) = a.strip_prefix("--trees=") {
            config.scale.num_trees = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--folds=") {
            config.folds = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--trials=") {
            config.scale.tuner_trials = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--max-examples=") {
            config.max_examples = v.parse().unwrap();
        } else if a == "--full" {
            config = SuiteConfig::full();
        }
    }
    eprintln!(
        "suite: {} datasets, {} folds, {} trees, {} tuning trials, <= {} examples",
        config.datasets.len(),
        config.folds,
        config.scale.num_trees,
        config.scale.tuner_trials,
        config.max_examples
    );
    let t0 = std::time::Instant::now();
    let result = run_suite(&config, |line| eprintln!("{line}"));
    eprintln!("suite completed in {:.1}s", t0.elapsed().as_secs_f64());

    println!("{}", result.fig6_report());
    println!("{}", result.table2_report());
    println!("{}", result.table3_report());
    println!("{}", result.table4_report());
    println!("{}", table5_report());
    println!("{}", result.time_table_report(false));
    println!("{}", result.time_table_report(true));
}
