//! Basic statistics: running moments, quantiles, ranking and bootstrap
//! resampling — the numeric substrate under dataspec inference, the
//! evaluation module's confidence intervals (§2.2) and the benchmark
//! harness's mean-rank computation (Figure 6).

use crate::utils::rng::Rng;

/// Single-pass mean/variance/min/max accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Moments {
    pub fn new() -> Self {
        Moments { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    /// Sample variance (n-1 denominator).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn merge(&mut self, other: &Moments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Quantile of a sorted slice with linear interpolation (type-7, the
/// NumPy/R default).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Quantile of an unsorted slice (copies + sorts).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    let mut m = Moments::new();
    for &x in xs {
        m.add(x);
    }
    m.std()
}

/// Fractional ranks (1-based, ties get the average rank). Lower value =
/// rank 1. Used for Figure 6's "mean rank" where rank 1 is the *best*
/// (highest accuracy) learner — callers negate accuracies first.
pub fn fractional_ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[order[j + 1]] == xs[order[i]] {
            j += 1;
        }
        // average rank of tied block [i, j]
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[order[k]] = avg;
        }
        i = j + 1;
    }
    ranks
}

/// Percentile bootstrap confidence interval of a statistic of `xs`.
pub fn bootstrap_ci<F: Fn(&[f64]) -> f64>(
    xs: &[f64],
    stat: F,
    rounds: usize,
    alpha: f64,
    rng: &mut Rng,
) -> (f64, f64) {
    assert!(!xs.is_empty());
    let mut vals = Vec::with_capacity(rounds);
    let mut buf = vec![0.0; xs.len()];
    for _ in 0..rounds {
        for b in buf.iter_mut() {
            *b = xs[rng.uniform_usize(xs.len())];
        }
        vals.push(stat(&buf));
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        quantile_sorted(&vals, alpha / 2.0),
        quantile_sorted(&vals, 1.0 - alpha / 2.0),
    )
}

/// Wilson score interval for a binomial proportion (closed-form CI used as
/// the fast path for accuracy CIs; the report also offers bootstrap).
pub fn wilson_interval(successes: u64, total: u64, z: f64) -> (f64, f64) {
    if total == 0 {
        return (0.0, 1.0);
    }
    let n = total as f64;
    let p = successes as f64 / n;
    let z2 = z * z;
    let denom = 1.0 + z2 / n;
    let center = (p + z2 / (2.0 * n)) / denom;
    let half = (z / denom) * ((p * (1.0 - p) / n + z2 / (4.0 * n * n)).sqrt());
    ((center - half).max(0.0), (center + half).min(1.0))
}

/// Two-sided sign test p-value: #wins of A over B out of n non-tied trials,
/// under H0 ~ Binomial(n, 0.5). Used for pairwise learner comparison
/// (Table 3 significance shading).
pub fn sign_test_p_value(wins: u64, losses: u64) -> f64 {
    let n = wins + losses;
    if n == 0 {
        return 1.0;
    }
    let k = wins.min(losses);
    // P(X <= k) * 2 with X ~ Bin(n, 0.5), computed in log space.
    let mut log_p = f64::NEG_INFINITY;
    for i in 0..=k {
        let lp = log_binom(n, i) - n as f64 * std::f64::consts::LN_2;
        log_p = log_add(log_p, lp);
    }
    (2.0 * log_p.exp()).min(1.0)
}

fn log_binom(n: u64, k: u64) -> f64 {
    ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0)
}

fn log_add(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// Lanczos approximation of ln Γ(x).
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        std::f64::consts::PI.ln() - (std::f64::consts::PI * x).sin().ln() - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = G[0];
        let t = x + 7.5;
        for (i, &g) in G.iter().enumerate().skip(1) {
            a += g / (x + i as f64);
        }
        0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically-stable softmax in place.
pub fn softmax_in_place(xs: &mut [f64]) {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let mut m = Moments::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            m.add(x);
        }
        assert_eq!(m.count(), 4);
        assert!((m.mean() - 2.5).abs() < 1e-12);
        assert!((m.variance() - 1.25).abs() < 1e-12);
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.max(), 4.0);
    }

    #[test]
    fn moments_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Moments::new();
        for &x in &xs {
            all.add(x);
        }
        let mut a = Moments::new();
        let mut b = Moments::new();
        for &x in &xs[..37] {
            a.add(x);
        }
        for &x in &xs[37..] {
            b.add(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_with_ties() {
        let xs = [3.0, 1.0, 2.0, 2.0];
        let r = fractional_ranks(&xs);
        assert_eq!(r, vec![4.0, 1.0, 2.5, 2.5]);
    }

    #[test]
    fn wilson_sane() {
        let (lo, hi) = wilson_interval(85, 100, 1.96);
        assert!(lo < 0.85 && 0.85 < hi);
        assert!(lo > 0.75 && hi < 0.95);
        let (lo0, hi0) = wilson_interval(0, 0, 1.96);
        assert_eq!((lo0, hi0), (0.0, 1.0));
    }

    #[test]
    fn bootstrap_mean_ci_contains_mean() {
        let xs: Vec<f64> = (0..200).map(|i| (i % 10) as f64).collect();
        let mut rng = Rng::seed_from_u64(1);
        let (lo, hi) = bootstrap_ci(&xs, mean, 500, 0.05, &mut rng);
        let m = mean(&xs);
        assert!(lo < m && m < hi, "({lo}, {hi}) vs {m}");
        assert!(hi - lo < 1.5);
    }

    #[test]
    fn sign_test() {
        // Even split => p ~ 1.
        assert!(sign_test_p_value(50, 50) > 0.9);
        // Extreme split => tiny p.
        assert!(sign_test_p_value(95, 5) < 1e-10);
        // Symmetric.
        let a = sign_test_p_value(30, 70);
        let b = sign_test_p_value(70, 30);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn ln_gamma_matches_factorials() {
        for n in 1..10u64 {
            let f: f64 = (1..=n).product::<u64>() as f64;
            assert!((ln_gamma(n as f64 + 1.0) - f.ln()).abs() < 1e-9);
        }
    }

    #[test]
    fn sigmoid_softmax() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(30.0) > 0.999999);
        assert!(sigmoid(-30.0) < 1e-6);
        let mut xs = [1.0, 2.0, 3.0];
        softmax_in_place(&mut xs);
        assert!((xs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }
}
