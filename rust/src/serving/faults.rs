//! Fault-injection harness for the serving runtime.
//!
//! Compiled only under `cfg(any(test, feature = "fault-injection"))`: in
//! release builds without the `fault-injection` feature this module — and
//! every hook call site — vanishes, so the hot path pays nothing.
//!
//! A [`FaultPlan`] is a bundle of armed, self-decrementing fault budgets.
//! Each `Batcher` owns its own plan (reachable via `Batcher::faults()`),
//! and the TCP server accepts an optional plan through
//! `ServerConfig::faults`; plans are per-instance `Arc`s, never global
//! state, so parallel tests cannot contaminate each other.
//!
//! Three injectable fault points:
//! - **scorer panic mid-flush** (`arm_scorer_panics`): the next N flushes
//!   panic inside the scorer's panic boundary, emulating an engine bug.
//!   The batcher must convert each into in-band error replies and keep
//!   serving.
//! - **artificial flush latency** (`arm_flush_delay`): the next N flushes
//!   sleep before scoring, emulating a slow engine; drives the queue
//!   deadline shedding path.
//! - **connection stall** (`arm_conn_stalls`): the server sleeps before
//!   processing the next N request lines, emulating a wedged worker;
//!   drives client-visible tail latency without touching the scorer.
//!
//! Two more target the routing tier (`ydf route`, `RouteConfig::faults`):
//! - **forward blackhole** (`arm_forward_drops`): the next N forwarded
//!   hops fail without touching the network, emulating a killed or
//!   partitioned backend; drives the router's retry/failover path
//!   deterministically.
//! - **forward stall** (`arm_forward_stalls`): the router sleeps before
//!   the next N forwarded hops, emulating a saturated backend link;
//!   drives hop-timeout and tail-latency behavior mid-traffic.
//!
//! Every fault also increments a `fired_*` counter so chaos tests can
//! assert the fault actually happened rather than silently racing past it.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

/// Armed fault budgets plus fired counters. All methods take `&self`;
/// share a plan across threads with `Arc<FaultPlan>`.
#[derive(Debug, Default)]
pub struct FaultPlan {
    panic_flushes: AtomicUsize,
    delay_flushes: AtomicUsize,
    flush_delay_ms: AtomicU64,
    stall_lines: AtomicUsize,
    line_stall_ms: AtomicU64,
    forward_drops: AtomicUsize,
    forward_stalls: AtomicUsize,
    forward_stall_ms: AtomicU64,
    fired_panics: AtomicUsize,
    fired_delays: AtomicUsize,
    fired_stalls: AtomicUsize,
    fired_forward_drops: AtomicUsize,
    fired_forward_stalls: AtomicUsize,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Arms the next `n` flushes to panic inside the scorer.
    pub fn arm_scorer_panics(&self, n: usize) {
        self.panic_flushes.store(n, Ordering::SeqCst);
    }

    /// Arms the next `n` flushes to sleep `ms` milliseconds before scoring.
    pub fn arm_flush_delay(&self, n: usize, ms: u64) {
        self.flush_delay_ms.store(ms, Ordering::SeqCst);
        self.delay_flushes.store(n, Ordering::SeqCst);
    }

    /// Arms the next `n` request lines to stall `ms` milliseconds before
    /// the server processes them.
    pub fn arm_conn_stalls(&self, n: usize, ms: u64) {
        self.line_stall_ms.store(ms, Ordering::SeqCst);
        self.stall_lines.store(n, Ordering::SeqCst);
    }

    /// Arms the next `n` forwarded hops (routing tier) to fail as if the
    /// backend were unreachable — a blackhole, not a slow link.
    pub fn arm_forward_drops(&self, n: usize) {
        self.forward_drops.store(n, Ordering::SeqCst);
    }

    /// Arms the next `n` forwarded hops to sleep `ms` milliseconds before
    /// the router dials the backend.
    pub fn arm_forward_stalls(&self, n: usize, ms: u64) {
        self.forward_stall_ms.store(ms, Ordering::SeqCst);
        self.forward_stalls.store(n, Ordering::SeqCst);
    }

    /// Disarms everything armed; fired counters are kept.
    pub fn disarm(&self) {
        self.panic_flushes.store(0, Ordering::SeqCst);
        self.delay_flushes.store(0, Ordering::SeqCst);
        self.stall_lines.store(0, Ordering::SeqCst);
        self.forward_drops.store(0, Ordering::SeqCst);
        self.forward_stalls.store(0, Ordering::SeqCst);
    }

    pub fn fired_panics(&self) -> usize {
        self.fired_panics.load(Ordering::SeqCst)
    }

    pub fn fired_delays(&self) -> usize {
        self.fired_delays.load(Ordering::SeqCst)
    }

    pub fn fired_stalls(&self) -> usize {
        self.fired_stalls.load(Ordering::SeqCst)
    }

    pub fn fired_forward_drops(&self) -> usize {
        self.fired_forward_drops.load(Ordering::SeqCst)
    }

    pub fn fired_forward_stalls(&self) -> usize {
        self.fired_forward_stalls.load(Ordering::SeqCst)
    }

    /// Atomically consumes one unit of an armed budget; false when spent.
    fn take(counter: &AtomicUsize) -> bool {
        counter.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |c| c.checked_sub(1)).is_ok()
    }

    /// Scorer hook, called once per flush *inside* the batcher's panic
    /// boundary: an injected panic here is indistinguishable from an
    /// engine panicking mid-batch.
    pub fn on_flush(&self) {
        if Self::take(&self.delay_flushes) {
            self.fired_delays.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(self.flush_delay_ms.load(Ordering::SeqCst)));
        }
        if Self::take(&self.panic_flushes) {
            self.fired_panics.fetch_add(1, Ordering::SeqCst);
            panic!("fault-injection: scorer panic mid-flush");
        }
    }

    /// Server hook, called once per received request line.
    pub fn on_request_line(&self) {
        if Self::take(&self.stall_lines) {
            self.fired_stalls.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(self.line_stall_ms.load(Ordering::SeqCst)));
        }
    }

    /// Router hook, called once per forwarded hop before dialing the
    /// backend. Returns `true` when the hop must be blackholed (treated
    /// as a transport failure without touching the network); a stall
    /// sleeps, then lets the hop proceed.
    pub fn on_forward(&self) -> bool {
        if Self::take(&self.forward_stalls) {
            self.fired_forward_stalls.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(
                self.forward_stall_ms.load(Ordering::SeqCst),
            ));
        }
        if Self::take(&self.forward_drops) {
            self.fired_forward_drops.fetch_add(1, Ordering::SeqCst);
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_decrement_and_fired_counters_track() {
        let p = FaultPlan::new();
        p.arm_flush_delay(2, 0);
        p.on_flush();
        p.on_flush();
        p.on_flush(); // budget spent: no third delay
        assert_eq!(p.fired_delays(), 2);
        assert_eq!(p.fired_panics(), 0);

        p.arm_conn_stalls(1, 0);
        p.on_request_line();
        p.on_request_line();
        assert_eq!(p.fired_stalls(), 1);
    }

    #[test]
    fn armed_panic_fires_once_then_disarms() {
        let p = FaultPlan::new();
        p.arm_scorer_panics(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.on_flush()));
        assert!(r.is_err());
        assert_eq!(p.fired_panics(), 1);
        p.on_flush(); // budget spent: no second panic
        assert_eq!(p.fired_panics(), 1);
    }

    #[test]
    fn disarm_clears_armed_budgets() {
        let p = FaultPlan::new();
        p.arm_scorer_panics(5);
        p.arm_flush_delay(5, 1);
        p.arm_forward_drops(5);
        p.arm_forward_stalls(5, 1);
        p.disarm();
        p.on_flush();
        assert!(!p.on_forward());
        assert_eq!(p.fired_panics(), 0);
        assert_eq!(p.fired_delays(), 0);
        assert_eq!(p.fired_forward_drops(), 0);
        assert_eq!(p.fired_forward_stalls(), 0);
    }

    #[test]
    fn forward_drops_blackhole_then_let_traffic_through() {
        let p = FaultPlan::new();
        p.arm_forward_drops(2);
        assert!(p.on_forward());
        assert!(p.on_forward());
        assert!(!p.on_forward(), "budget spent: hops proceed again");
        assert_eq!(p.fired_forward_drops(), 2);

        p.arm_forward_stalls(1, 0);
        assert!(!p.on_forward(), "a stall delays but never drops");
        assert_eq!(p.fired_forward_stalls(), 1);
    }
}
