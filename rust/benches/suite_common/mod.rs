//! Shared scaled-down suite configuration for the table/figure benches.
//! Each bench binary regenerates one paper artifact; the configuration is
//! printed so the scale is explicit in the recorded output.

use ydf::benchmark::learners::LearnerScale;
use ydf::benchmark::{run_suite, SuiteConfig, SuiteResult};

pub fn bench_config() -> SuiteConfig {
    SuiteConfig {
        datasets: vec![
            "Iris",
            "Blood_Transfusion",
            "Diabetes",
            "Banknote_Authentication",
            "Credit_Approval",
            "TicTacToe",
        ],
        folds: 3,
        max_examples: 300,
        max_features: 16,
        scale: LearnerScale { num_trees: 10, tuner_trials: 2 },
        seed: 20230806,
    }
}

pub fn run() -> SuiteResult {
    let config = bench_config();
    eprintln!(
        "[suite] {} datasets, {} folds, {} trees, {} trials (paper: 70 datasets, 10 folds, \
         500 trees, 300 trials — scale with `ydf benchmark_suite --full`)",
        config.datasets.len(),
        config.folds,
        config.scale.num_trees,
        config.scale.tuner_trials
    );
    run_suite(&config, |_| {})
}
