//! b5: serving-runtime benchmark — the micro-batching path under load.
//!
//! For every request-size × concurrency combination (1/8/64 rows ×
//! 1/4/16 clients by default), clients submit pre-decoded request blocks
//! through `serving::Batcher` in a closed loop (one in-flight request per
//! client — the standard closed-system load model), and the run records
//! µs/request and requests/s (plus rows/s and the mean coalesced batch
//! size). Results go to `BENCH_serving.json` so serving performance is
//! tracked across PRs exactly like `BENCH_inference.json` tracks the
//! engine kernels.
//!
//! Run: cargo bench --bench b5_serving
//!      cargo bench --bench b5_serving -- --requests=500 --out=path.json

use std::sync::Arc;
use std::time::Duration;
use ydf::dataset::synthetic;
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};
use ydf::serving::{Batcher, BatcherConfig, RowBlock, Session};
use ydf::utils::json::Json;

const REQUEST_ROWS: [usize; 3] = [1, 8, 64];
const CONCURRENCY: [usize; 3] = [1, 4, 16];

struct ComboResult {
    request_rows: usize,
    concurrency: usize,
    requests: usize,
    us_per_request: f64,
    requests_per_s: f64,
    rows_per_s: f64,
    mean_batch_rows: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut requests_per_client = 200usize;
    let mut out_path = "BENCH_serving.json".to_string();
    for a in &args {
        if let Some(v) = a.strip_prefix("--requests=") {
            requests_per_client = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--out=") {
            out_path = v.to_string();
        }
    }

    // The b4 workload: adult-like mixed features, QuickScorer-compatible
    // GBT, so b4 and b5 numbers describe the same model family.
    let ds = synthetic::adult_like(4000, 20230806);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = 50;
    cfg.max_depth = 5;
    let session =
        Arc::new(Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()));
    println!(
        "serving benchmark: engine {}, {} requests/client\n  {:>12} {:>11} {:>14} {:>14} {:>12} {:>16}",
        session.engine_name(),
        requests_per_client,
        "request_rows",
        "concurrency",
        "us/request",
        "requests/s",
        "rows/s",
        "mean batch rows",
    );

    let mut results: Vec<ComboResult> = Vec::new();
    for &request_rows in &REQUEST_ROWS {
        // One prototype request per size, decoded once from dataset rows
        // (steady-state serving measures the queue + score + scatter path;
        // JSON decode is measured per-request by the server's own stats).
        for &concurrency in &CONCURRENCY {
            let batcher = Batcher::new(
                Arc::clone(&session),
                BatcherConfig {
                    // Adaptive drain: coalesce exactly the backlog that
                    // accumulates while the previous batch scores.
                    max_delay: Duration::ZERO,
                    ..Default::default()
                },
            );
            let total_requests = requests_per_client * concurrency;
            let t0 = std::time::Instant::now();
            std::thread::scope(|s| {
                for client in 0..concurrency {
                    let session = &session;
                    let batcher = &batcher;
                    s.spawn(move || {
                        let block = request_block(session, request_rows, client);
                        for _ in 0..requests_per_client {
                            let out = batcher
                                .submit(&block)
                                .expect("bench load stays under queue capacity")
                                .wait()
                                .expect("batcher serves until dropped");
                            std::hint::black_box(out);
                        }
                    });
                }
            });
            let wall = t0.elapsed().as_secs_f64();
            let snap = batcher.stats().snapshot();
            let r = ComboResult {
                request_rows,
                concurrency,
                requests: total_requests,
                us_per_request: wall / total_requests as f64 * 1e6,
                requests_per_s: total_requests as f64 / wall,
                rows_per_s: (total_requests * request_rows) as f64 / wall,
                mean_batch_rows: if snap.batches > 0 {
                    snap.batched_rows as f64 / snap.batches as f64
                } else {
                    0.0
                },
            };
            println!(
                "  {:>12} {:>11} {:>14.2} {:>14.0} {:>12.0} {:>16.1}",
                r.request_rows,
                r.concurrency,
                r.us_per_request,
                r.requests_per_s,
                r.rows_per_s,
                r.mean_batch_rows,
            );
            results.push(r);
        }
    }

    let mut combos = Json::obj();
    for r in &results {
        let mut cj = Json::obj();
        cj.set("request_rows", Json::Num(r.request_rows as f64))
            .set("concurrency", Json::Num(r.concurrency as f64))
            .set("requests", Json::Num(r.requests as f64))
            .set("us_per_request", Json::Num(r.us_per_request))
            .set("requests_per_s", Json::Num(r.requests_per_s))
            .set("rows_per_s", Json::Num(r.rows_per_s))
            .set("mean_batch_rows", Json::Num(r.mean_batch_rows));
        combos.set(&format!("s{}_c{}", r.request_rows, r.concurrency), cj);
    }
    let mut j = Json::obj();
    j.set("engine", Json::Str(session.engine_name()))
        .set("requests_per_client", Json::Num(requests_per_client as f64))
        .set("block_size", Json::Num(ydf::inference::BLOCK_SIZE as f64))
        .set("combos", combos);
    match std::fs::write(&out_path, j.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("cannot write {out_path}: {e}"),
    }
}

/// Builds one request of `rows` rows from dataset-like feature values,
/// varied per client so coalesced batches are not degenerate.
fn request_block(session: &Session, rows: usize, client: usize) -> RowBlock {
    let workclasses = ["Private", "Self-emp-inc", "Federal-gov", "Local-gov"];
    let educations = ["HS-grad", "Bachelors", "Masters", "Doctorate"];
    let mut block = session.new_block();
    for i in 0..rows {
        let k = client * 31 + i;
        let row = Json::parse(&format!(
            r#"{{"age": {}, "hours_per_week": {}, "workclass": "{}",
                "education": "{}", "capital_gain": {}}}"#,
            18 + k % 60,
            20 + (k * 7) % 50,
            workclasses[k % workclasses.len()],
            educations[(k / 2) % educations.len()],
            (k % 9) * 700,
        ))
        .unwrap();
        session.decode_row(&mut block, &row).unwrap();
    }
    block
}
