//! MODEL abstraction (§3.1): a model is a function that takes an
//! observation and returns a prediction. Concrete models (Random Forest,
//! Gradient Boosted Trees, linear) implement the [`Model`] trait; learners
//! return `Box<dyn Model>` so meta-learners and tools stay model-agnostic.
//!
//! Prediction output convention: classification models return one
//! probability per class, aligned with the label column's dictionary;
//! regression models return a single value. Besides predicting, a model
//! carries its [`DataSpec`], an optional self-evaluation
//! ([`SelfEvaluation`], §3.6), variable importances
//! ([`VariableImportance`], Appendix B.2), a human-readable
//! [`Model::describe`] report, and JSON (de)serialization via [`io`].
//! For fast batch prediction, models are *compiled* into the inference
//! engines of [`crate::inference`] rather than called row by row.

pub mod describe;
pub mod forest;
pub mod io;
pub mod linear;
pub mod tree;

pub use forest::{GradientBoostedTreesModel, RandomForestModel};
pub use linear::LinearModel;

use crate::dataset::{DataSpec, Dataset, Observation};
use crate::utils::json::Json;

/// The learning task. Ranking and uplifting from the paper reduce to
/// regression over engineered labels in this reproduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Classification,
    Regression,
}

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Classification => "CLASSIFICATION",
            Task::Regression => "REGRESSION",
        }
    }
}

/// Model self-evaluation (§3.6): a fair quality estimate computed by the
/// learner itself (out-of-bag for RF, validation loss for GBT), available
/// without a held-out dataset.
#[derive(Clone, Debug, Default)]
pub struct SelfEvaluation {
    /// e.g. "out-of-bag accuracy" or "validation loss".
    pub metric: String,
    pub value: f64,
    /// Number of examples the estimate is based on.
    pub num_examples: u64,
}

/// One variable-importance ranking (Appendix B.2 shows NUM_AS_ROOT and
/// NUM_NODES; SUM_SCORE and INV_MEAN_MIN_DEPTH are also standard in YDF).
#[derive(Clone, Debug)]
pub struct VariableImportance {
    pub kind: &'static str,
    /// (feature name, importance), sorted descending.
    pub values: Vec<(String, f64)>,
}

/// A trained model. Prediction output: probabilities per class for
/// classification (aligned with the label dictionary), a single value for
/// regression.
pub trait Model: Send + Sync {
    /// Type string, e.g. "GRADIENT_BOOSTED_TREES" (report header).
    fn model_type(&self) -> &'static str;
    fn task(&self) -> Task;
    /// Dataspec of the columns the model was trained with (incl. label).
    fn spec(&self) -> &DataSpec;
    /// Column index of the label within `spec`.
    fn label_col(&self) -> usize;
    /// Indices of the columns actually used as input features.
    fn input_features(&self) -> Vec<usize>;

    /// Predicts a single row observation (column order = `spec`).
    fn predict_row(&self, obs: &Observation) -> Vec<f64>;
    /// Predicts row `row` of a column-wise dataset.
    fn predict_ds_row(&self, ds: &Dataset, row: usize) -> Vec<f64>;
    /// Batch prediction. Default: row loop; engines override.
    fn predict_dataset(&self, ds: &Dataset) -> Vec<Vec<f64>> {
        (0..ds.num_rows()).map(|r| self.predict_ds_row(ds, r)).collect()
    }

    /// Human-readable summary (`show_model`, Appendix B.2).
    fn describe(&self) -> String;
    /// Serialization to the versioned JSON model format.
    fn to_json(&self) -> Json;
    /// Variable importances, if the model supports them.
    fn variable_importances(&self) -> Vec<VariableImportance> {
        vec![]
    }
    /// Self-evaluation recorded at training time (§3.6).
    fn self_evaluation(&self) -> Option<&SelfEvaluation> {
        None
    }
    /// Downcasting support (engine compilation inspects concrete types).
    fn as_any(&self) -> &dyn std::any::Any;

    /// Class names for classification models (label dictionary).
    fn class_names(&self) -> Vec<String> {
        self.spec().columns[self.label_col()].dictionary.clone()
    }

    /// Number of classes (1 for regression).
    fn num_classes(&self) -> usize {
        match self.task() {
            Task::Classification => self.spec().columns[self.label_col()].vocab_size(),
            Task::Regression => 1,
        }
    }
}

/// Classification decision: argmax class index of a probability vector.
pub fn argmax(probs: &[f64]) -> usize {
    let mut best = 0usize;
    for (i, &p) in probs.iter().enumerate().skip(1) {
        if p > probs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.7, 0.2]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[0.5, 0.5]), 0); // first wins ties
    }

    #[test]
    fn task_names() {
        assert_eq!(Task::Classification.name(), "CLASSIFICATION");
        assert_eq!(Task::Regression.name(), "REGRESSION");
    }
}
