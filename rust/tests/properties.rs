//! Property-based tests over coordinator invariants: splitter optimality
//! and consistency, engine equivalence, serving-session decode fidelity,
//! partition conservation, metric bounds, determinism — randomized with
//! fixed seeds (utils::prop). Mixed-semantic dataset generators live in
//! `tests/common/mod.rs`.

mod common;

use common::{mixed_ds, mixed_ds_opt};
use ydf::dataset::dataspec::{ColumnSpec, DataSpec};
use ydf::dataset::{ColumnData, Dataset};
use ydf::splitter::score::Labels;
use ydf::splitter::{
    find_best_split, partition_rows, ColumnIndex, NodeScratch, NumericalSplit, RowArena,
    SplitterConfig,
};
use ydf::utils::prop::{gen_f64_vec, gen_labels, run_cases};
use ydf::utils::rng::Rng;

fn numeric_ds(values: Vec<f32>) -> Dataset {
    let spec = DataSpec { columns: vec![ColumnSpec::numerical("x")] };
    Dataset::new(spec, vec![ColumnData::Numerical(values)]).unwrap()
}

/// Brute-force best split: try every boundary between sorted distinct
/// values, missing excluded (generator produces no NaN).
fn brute_force_best_gain(values: &[f32], labels: &[u32], min_examples: usize) -> Option<f64> {
    let labels_view = Labels::Classification { labels, num_classes: 2 };
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).unwrap());
    let mut best: Option<f64> = None;
    for cut in 1..values.len() {
        if values[idx[cut - 1]] >= values[idx[cut]] {
            continue;
        }
        if cut < min_examples || values.len() - cut < min_examples {
            continue;
        }
        let mut parent = labels_view.new_acc();
        let mut left = labels_view.new_acc();
        let mut right = labels_view.new_acc();
        for (pos, &i) in idx.iter().enumerate() {
            parent.add(&labels_view, i);
            if pos < cut {
                left.add(&labels_view, i);
            } else {
                right.add(&labels_view, i);
            }
        }
        let g = ydf::splitter::score::ScoreAcc::gain(&parent, &left, &right, &labels_view);
        if best.map(|b| g > b).unwrap_or(true) {
            best = Some(g);
        }
    }
    best
}

#[test]
fn prop_exact_splitter_is_optimal() {
    run_cases(0xA11CE, 40, |rng, case| {
        let n = 20 + rng.uniform_usize(60);
        let values: Vec<f32> = gen_f64_vec(rng, n).into_iter().map(|v| v as f32).collect();
        let labels = gen_labels(rng, n, 2);
        let ds = numeric_ds(values.clone());
        let labels_view = Labels::Classification { labels: &labels, num_classes: 2 };
        let cfg = SplitterConfig { min_examples: 2, ..Default::default() };
        let index = ColumnIndex::new(&ds);
        let mut scratch = NodeScratch::new(ds.num_rows());
        let mut split_rng = Rng::seed_from_u64(1);
        let rows: Vec<u32> = (0..n as u32).collect();
        let found = find_best_split(
            &ds, &rows, &labels_view, &[0], &cfg, &index, &mut scratch, &mut split_rng,
        );
        let brute = brute_force_best_gain(&values, &labels, 2)
            .filter(|&g| g > 1e-12);
        match (found, brute) {
            (Some(f), Some(b)) => {
                assert!((f.gain - b).abs() < 1e-9, "case {case}: {} vs {b}", f.gain)
            }
            (None, None) => {}
            (f, b) => panic!("case {case}: splitter {f:?} vs brute {b:?}"),
        }
    });
}

#[test]
fn prop_partition_conserves_rows() {
    run_cases(0xB0B, 30, |rng, _| {
        let n = 30 + rng.uniform_usize(50);
        let values: Vec<f32> = gen_f64_vec(rng, n).into_iter().map(|v| v as f32).collect();
        let labels = gen_labels(rng, n, 2);
        let ds = numeric_ds(values);
        let labels_view = Labels::Classification { labels: &labels, num_classes: 2 };
        let cfg = SplitterConfig { min_examples: 1, ..Default::default() };
        let index = ColumnIndex::new(&ds);
        let mut scratch = NodeScratch::new(ds.num_rows());
        let mut split_rng = Rng::seed_from_u64(2);
        let rows: Vec<u32> = (0..n as u32).collect();
        if let Some(split) = find_best_split(
            &ds, &rows, &labels_view, &[0], &cfg, &index, &mut scratch, &mut split_rng,
        ) {
            let (pos, neg) =
                partition_rows(&ds, &rows, &split.condition, split.missing_to_positive);
            let mut all: Vec<u32> = pos.iter().chain(neg.iter()).copied().collect();
            all.sort_unstable();
            assert_eq!(all, rows, "partition must conserve rows");
        }
    });
}

#[test]
fn prop_histogram_gain_never_exceeds_exact() {
    run_cases(0xC0FFEE, 25, |rng, case| {
        let n = 60 + rng.uniform_usize(100);
        let values: Vec<f32> = gen_f64_vec(rng, n).into_iter().map(|v| v as f32).collect();
        let labels = gen_labels(rng, n, 2);
        let ds = numeric_ds(values);
        let labels_view = Labels::Classification { labels: &labels, num_classes: 2 };
        let rows: Vec<u32> = (0..n as u32).collect();
        let mut split_rng = Rng::seed_from_u64(3);
        let exact_cfg = SplitterConfig { min_examples: 1, ..Default::default() };
        let index = ColumnIndex::new(&ds);
        let mut scratch = NodeScratch::new(ds.num_rows());
        let exact = find_best_split(
            &ds, &rows, &labels_view, &[0], &exact_cfg, &index, &mut scratch, &mut split_rng,
        );
        let hist_cfg = SplitterConfig {
            min_examples: 1,
            numerical: NumericalSplit::Histogram { bins: 32 },
            ..Default::default()
        };
        let hist = find_best_split(
            &ds, &rows, &labels_view, &[0], &hist_cfg, &index, &mut scratch, &mut split_rng,
        );
        if let (Some(e), Some(h)) = (&exact, &hist) {
            assert!(
                h.gain <= e.gain + 1e-9,
                "case {case}: histogram gain {} exceeds exact {}",
                h.gain,
                e.gain
            );
        }
    });
}

#[test]
fn prop_probability_outputs_valid() {
    use ydf::learner::gbt::GbtConfig;
    use ydf::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};
    run_cases(0xDEED, 6, |rng, _| {
        let seed = rng.next_u64();
        let ds = ydf::dataset::synthetic::adult_like(120, seed);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 4;
        cfg.max_depth = 3;
        let models: Vec<Box<dyn ydf::model::Model>> = vec![
            GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap(),
            {
                let mut rf = ydf::learner::random_forest::RandomForestConfig::new("income");
                rf.num_trees = 4;
                rf.compute_oob = false;
                RandomForestLearner::new(rf).train(&ds).unwrap()
            },
        ];
        for model in &models {
            for r in 0..ds.num_rows() {
                let p = model.predict_ds_row(&ds, r);
                let sum: f64 = p.iter().sum();
                assert!((sum - 1.0).abs() < 1e-9, "probs must sum to 1: {p:?}");
                assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)), "{p:?}");
            }
        }
    });
}

#[test]
fn prop_auc_invariant_under_monotone_transform() {
    use ydf::evaluation::metrics::roc_auc;
    run_cases(0xF00D, 30, |rng, _| {
        let n = 20 + rng.uniform_usize(100);
        let scores: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let pos: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.4)).collect();
        let auc = roc_auc(&scores, &pos);
        let transformed: Vec<f64> = scores.iter().map(|&s| (3.0 * s + 1.0).exp()).collect();
        let auc2 = roc_auc(&transformed, &pos);
        assert!((auc - auc2).abs() < 1e-12, "AUC must be rank-invariant");
        // Complement symmetry: flipping labels mirrors the AUC.
        let neg: Vec<bool> = pos.iter().map(|&p| !p).collect();
        let auc3 = roc_auc(&scores, &neg);
        assert!((auc + auc3 - 1.0).abs() < 1e-9, "{auc} + {auc3} != 1");
    });
}

/// Asserts one engine agrees with the model (== NaiveEngine) on the
/// per-row path, the full-range batch path, an offset non-block-aligned
/// subrange, and the multi-threaded whole-dataset path.
fn check_engine_consistency(
    engine: &dyn ydf::inference::InferenceEngine,
    model: &dyn ydf::model::Model,
    ds: &Dataset,
    ctx: &str,
) {
    fn close(a: &[f64], b: &[f64], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}");
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-9, "{ctx}: {a:?} vs {b:?}");
        }
    }
    let n = ds.num_rows();
    let dim = engine.output_dim();
    for r in 0..n {
        close(
            &engine.predict_row(&ds.row(r)),
            &model.predict_ds_row(ds, r),
            &format!("{ctx}/row {r}"),
        );
    }
    let mut out = vec![0.0f64; n * dim];
    engine.predict_batch(ds, 0..n, &mut out);
    for r in 0..n {
        close(
            &out[r * dim..(r + 1) * dim],
            &model.predict_ds_row(ds, r),
            &format!("{ctx}/batch {r}"),
        );
    }
    let (lo, hi) = (n / 3 + 1, n - 2); // offset, not block-aligned
    let mut sub = vec![0.0f64; (hi - lo) * dim];
    engine.predict_batch(ds, lo..hi, &mut sub);
    for (i, r) in (lo..hi).enumerate() {
        close(
            &sub[i * dim..(i + 1) * dim],
            &model.predict_ds_row(ds, r),
            &format!("{ctx}/subrange {r}"),
        );
    }
    let mut multi = vec![0.0f64; n * dim];
    engine.predict_into(ds, 3, &mut multi);
    close(&multi, &out, &format!("{ctx}/predict_into"));
}

fn check_all_engines(model: &dyn ydf::model::Model, ds: &Dataset, ctx: &str) {
    use ydf::inference::{flat::FlatEngine, naive::NaiveEngine, quickscorer::QuickScorerEngine};
    let naive = NaiveEngine::compile(model);
    check_engine_consistency(&naive, model, ds, &format!("{ctx}/naive"));
    let flat = FlatEngine::compile(model)
        .unwrap_or_else(|| panic!("{ctx}: flat engine must compile for forest models"));
    check_engine_consistency(&flat, model, ds, &format!("{ctx}/flat"));
    if let Some(qs) = QuickScorerEngine::compile(model) {
        check_engine_consistency(&qs, model, ds, &format!("{ctx}/quickscorer"));
    }
}

#[test]
fn prop_batch_path_matches_row_path_and_naive() {
    use ydf::learner::gbt::GbtConfig;
    use ydf::learner::random_forest::RandomForestConfig;
    use ydf::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};
    use ydf::model::Task;

    run_cases(0xBA7C4, 3, |rng, case| {
        let n = 91 + rng.uniform_usize(80); // tail block almost never 64-aligned
        let classes = if case % 2 == 0 { 2 } else { 3 };

        // Classification: binomial (2 classes) and multinomial (3).
        let ds = mixed_ds(n, classes, rng);
        let mut gbt = GbtConfig::new("label");
        gbt.num_trees = 5;
        gbt.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(gbt).train(&ds).unwrap();
        check_all_engines(model.as_ref(), &ds, &format!("case {case}/gbt-cls"));

        let mut rf = RandomForestConfig::new("label");
        rf.num_trees = 4;
        rf.compute_oob = false;
        let model = RandomForestLearner::new(rf).train(&ds).unwrap();
        check_all_engines(model.as_ref(), &ds, &format!("case {case}/rf-cls"));

        // Regression on the same mixed features.
        let ds = mixed_ds(n, 0, rng);
        let mut gbt = GbtConfig::new("label");
        gbt.task = Task::Regression;
        gbt.num_trees = 5;
        gbt.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(gbt).train(&ds).unwrap();
        check_all_engines(model.as_ref(), &ds, &format!("case {case}/gbt-reg"));

        let mut rf = RandomForestConfig::new("label");
        rf.task = Task::Regression;
        rf.num_trees = 4;
        rf.compute_oob = false;
        let model = RandomForestLearner::new(rf).train(&ds).unwrap();
        check_all_engines(model.as_ref(), &ds, &format!("case {case}/rf-reg"));
    });

    // Oblique conditions (sparse projections): flat + naive engines only.
    let ds = ydf::dataset::synthetic::adult_like(141, 77);
    let mut cfg = ydf::learner::gbt::GbtConfig::benchmark_rank1("income");
    cfg.num_trees = 5;
    let model = ydf::learner::GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
    check_all_engines(model.as_ref(), &ds, "oblique-gbt");
}

/// Runs one engine twice — scalar kernel vs SIMD lane kernel — over the
/// full range, an offset non-block-aligned subrange, and the
/// multi-threaded path, asserting the outputs are *bit-identical*
/// (`f64::to_bits`), not merely close. `make_engine` returns a freshly
/// compiled engine or None when the model is incompatible.
fn check_simd_bitwise<E: ydf::inference::InferenceEngine>(
    make_engine: impl Fn(bool) -> Option<E>,
    ds: &Dataset,
    ctx: &str,
) {
    let (scalar, lanes) = match (make_engine(false), make_engine(true)) {
        (Some(s), Some(l)) => (s, l),
        _ => return,
    };
    let n = ds.num_rows();
    let dim = scalar.output_dim();
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
    let mut a = vec![0.0f64; n * dim];
    let mut b = vec![0.0f64; n * dim];
    scalar.predict_batch(ds, 0..n, &mut a);
    lanes.predict_batch(ds, 0..n, &mut b);
    assert_eq!(bits(&a), bits(&b), "{ctx}: full-range batch");
    let (lo, hi) = (n / 3 + 1, n - 2); // offset, not 64-aligned
    let mut sa = vec![0.0f64; (hi - lo) * dim];
    let mut sb = vec![0.0f64; (hi - lo) * dim];
    scalar.predict_batch(ds, lo..hi, &mut sa);
    lanes.predict_batch(ds, lo..hi, &mut sb);
    assert_eq!(bits(&sa), bits(&sb), "{ctx}: unaligned subrange");
    let mut ma = vec![0.0f64; n * dim];
    scalar.predict_into(ds, 3, &mut ma);
    assert_eq!(bits(&ma), bits(&a), "{ctx}: multi-threaded scalar");
    let mut mb = vec![0.0f64; n * dim];
    lanes.predict_into(ds, 3, &mut mb);
    assert_eq!(bits(&mb), bits(&a), "{ctx}: multi-threaded lanes");
}

/// Flat engine with the given kernel selection (None: model incompatible).
fn flat_with(
    model: &dyn ydf::model::Model,
    simd: bool,
) -> Option<ydf::inference::flat::FlatEngine> {
    ydf::inference::flat::FlatEngine::compile(model).map(|mut e| {
        e.set_simd(simd);
        e
    })
}

/// QuickScorer engine with the given kernel selection.
fn qs_with(
    model: &dyn ydf::model::Model,
    simd: bool,
) -> Option<ydf::inference::quickscorer::QuickScorerEngine> {
    ydf::inference::quickscorer::QuickScorerEngine::compile(model).map(|mut e| {
        e.set_simd(simd);
        e
    })
}

/// The SIMD lane kernels are pinned to the scalar kernels bit-for-bit —
/// and through them (via `prop_batch_path_matches_row_path_and_naive`) to
/// the naive engine — across NaN/missing values in every semantic,
/// non-64-aligned tails and subranges, classification and regression.
#[test]
fn prop_simd_lanes_match_scalar() {
    use ydf::learner::gbt::GbtConfig;
    use ydf::learner::random_forest::RandomForestConfig;
    use ydf::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};
    use ydf::model::Task;

    run_cases(0x51D0, 3, |rng, case| {
        let n = 91 + rng.uniform_usize(80); // tail block almost never 64-aligned
        let classes = if case % 2 == 0 { 2 } else { 3 };
        let mut models: Vec<(Box<dyn ydf::model::Model>, String)> = Vec::new();

        let ds = mixed_ds(n, classes, rng);
        let mut gbt = GbtConfig::new("label");
        gbt.num_trees = 5;
        gbt.max_depth = 5;
        models.push((
            GradientBoostedTreesLearner::new(gbt).train(&ds).unwrap(),
            format!("case {case}/gbt-cls"),
        ));
        let mut rf = RandomForestConfig::new("label");
        rf.num_trees = 4;
        rf.compute_oob = false;
        models.push((
            RandomForestLearner::new(rf).train(&ds).unwrap(),
            format!("case {case}/rf-cls"),
        ));
        for (model, ctx) in &models {
            check_simd_bitwise(
                |simd| flat_with(model.as_ref(), simd),
                &ds,
                &format!("{ctx}/flat"),
            );
            check_simd_bitwise(
                |simd| qs_with(model.as_ref(), simd),
                &ds,
                &format!("{ctx}/quickscorer"),
            );
        }

        // Regression on the same mixed (NaN-bearing) features.
        let ds = mixed_ds(n, 0, rng);
        let mut gbt = GbtConfig::new("label");
        gbt.task = Task::Regression;
        gbt.num_trees = 5;
        gbt.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(gbt).train(&ds).unwrap();
        check_simd_bitwise(
            |simd| flat_with(model.as_ref(), simd),
            &ds,
            &format!("case {case}/gbt-reg/flat"),
        );
        check_simd_bitwise(
            |simd| qs_with(model.as_ref(), simd),
            &ds,
            &format!("case {case}/gbt-reg/quickscorer"),
        );

        // Without the categorical-set column the trees stay inside
        // QuickScorer's condition envelope, so its NaN/missing lane paths
        // are guaranteed to run (compile() must succeed here).
        let ds = mixed_ds_opt(n, classes, false, rng);
        let mut gbt = GbtConfig::new("label");
        gbt.num_trees = 5;
        gbt.max_depth = 5;
        let model = GradientBoostedTreesLearner::new(gbt).train(&ds).unwrap();
        assert!(
            qs_with(model.as_ref(), true).is_some(),
            "case {case}: catset-free GBT must be QS-compatible"
        );
        check_simd_bitwise(
            |simd| qs_with(model.as_ref(), simd),
            &ds,
            &format!("case {case}/no-catset/quickscorer"),
        );
        check_simd_bitwise(
            |simd| flat_with(model.as_ref(), simd),
            &ds,
            &format!("case {case}/no-catset/flat"),
        );
    });

    // Oblique conditions: the lane kernel's term-major dot products must
    // keep each lane's scalar accumulation order (flat engine only —
    // QuickScorer rejects oblique models).
    let ds = ydf::dataset::synthetic::adult_like(141, 78);
    let mut cfg = ydf::learner::gbt::GbtConfig::benchmark_rank1("income");
    cfg.num_trees = 5;
    let model = ydf::learner::GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
    check_simd_bitwise(|simd| flat_with(model.as_ref(), simd), &ds, "oblique-gbt/flat");
}

/// The serving session's JSON request decode is pinned against columnar
/// ground truth built independently of the decoder: NaN/missing values in
/// every semantic, out-of-dictionary categoricals, categorical-set
/// columns (array and string forms, empty-vs-missing, dropped unknown
/// tokens), numeric strings, and unknown/extra JSON keys (including the
/// label) which must error without touching the block.
#[test]
fn prop_session_decode_round_trips_columnar_ground_truth() {
    use ydf::dataset::{MISSING_BOOL, MISSING_CAT};
    use ydf::learner::gbt::GbtConfig;
    use ydf::learner::{GradientBoostedTreesLearner, Learner};
    use ydf::serving::Session;
    use ydf::utils::json::Json;

    run_cases(0xD0DE, 4, |rng, case| {
        let n = 60 + rng.uniform_usize(60);
        let ds = mixed_ds(n, 2, rng);
        let mut cfg = GbtConfig::new("label");
        cfg.num_trees = 3;
        cfg.max_depth = 3;
        let session =
            Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap());
        let mut block = session.new_block();

        let m = 50 + rng.uniform_usize(30);
        let mut exp_x0: Vec<f32> = Vec::new();
        let mut exp_cat: Vec<u32> = Vec::new();
        let mut exp_flag: Vec<u8> = Vec::new();
        let mut exp_sets: Vec<Vec<u32>> = Vec::new();
        for _ in 0..m {
            let mut row = Json::obj();
            // x0 — numbers in eighths are exact in f32, f64 and decimal,
            // so every representation (number, numeric string, padded
            // string) must decode to the same bits.
            let v = (rng.uniform_usize(2001) as f64 - 1000.0) / 8.0;
            match rng.uniform_usize(5) {
                0 => exp_x0.push(f32::NAN), // absent
                1 => {
                    row.set("x0", Json::Null);
                    exp_x0.push(f32::NAN);
                }
                2 => {
                    row.set("x0", Json::Num(v));
                    exp_x0.push(v as f32);
                }
                3 => {
                    row.set("x0", Json::Str(format!("{v}")));
                    exp_x0.push(v as f32);
                }
                _ => {
                    row.set("x0", Json::Str(format!("  {v} ")));
                    exp_x0.push(v as f32);
                }
            }
            // x1 stays absent in every row: an always-missing column.
            match rng.uniform_usize(4) {
                0 => exp_cat.push(MISSING_CAT), // absent
                1 => {
                    row.set("cat", Json::Null);
                    exp_cat.push(MISSING_CAT);
                }
                2 => {
                    let k = rng.uniform_usize(4);
                    row.set("cat", Json::Str(format!("c{k}")));
                    exp_cat.push(k as u32);
                }
                _ => {
                    // Out-of-dictionary category decodes to missing,
                    // mirroring dataspec encoding at training time.
                    row.set("cat", Json::Str("definitely-not-in-dict".to_string()));
                    exp_cat.push(MISSING_CAT);
                }
            }
            match rng.uniform_usize(5) {
                0 => exp_flag.push(MISSING_BOOL),
                1 => {
                    row.set("flag", Json::Null);
                    exp_flag.push(MISSING_BOOL);
                }
                2 => {
                    let b = rng.bernoulli(0.5);
                    row.set("flag", Json::Bool(b));
                    exp_flag.push(b as u8);
                }
                3 => {
                    let b = rng.bernoulli(0.5);
                    row.set("flag", Json::Num(b as u8 as f64));
                    exp_flag.push(b as u8);
                }
                _ => {
                    let b = rng.bernoulli(0.5);
                    row.set(
                        "flag",
                        Json::Str(if b { "true" } else { "0" }.to_string()),
                    );
                    exp_flag.push(b as u8);
                }
            }
            match rng.uniform_usize(5) {
                0 => exp_sets.push(vec![MISSING_CAT]), // absent = missing set
                1 => {
                    row.set("tokens", Json::Null);
                    exp_sets.push(vec![MISSING_CAT]);
                }
                2 => {
                    // Empty set is distinct from a missing set.
                    row.set("tokens", Json::Arr(vec![]));
                    exp_sets.push(vec![]);
                }
                3 => {
                    // Array form; unknown tokens are dropped in place.
                    let a = rng.uniform_usize(5);
                    let b = rng.uniform_usize(5);
                    row.set(
                        "tokens",
                        Json::Arr(vec![
                            Json::Str(format!("t{a}")),
                            Json::Str("zzz-not-a-token".to_string()),
                            Json::Str(format!("t{b}")),
                        ]),
                    );
                    exp_sets.push(vec![a as u32, b as u32]);
                }
                _ => {
                    // Whitespace-separated string form, duplicates kept.
                    let a = rng.uniform_usize(5);
                    row.set("tokens", Json::Str(format!("t{a} junk t{a}")));
                    exp_sets.push(vec![a as u32, a as u32]);
                }
            }
            session.decode_row(&mut block, &row).unwrap();
        }

        // Unknown/extra keys and the label are rejected without touching
        // the block.
        let before = block.rows();
        let mut extra = Json::obj();
        extra.set("x0", Json::Num(1.0)).set("extra_key", Json::Num(2.0));
        let err = session.decode_row(&mut block, &extra).unwrap_err();
        assert!(err.contains("extra_key"), "case {case}: {err}");
        let mut labeled = Json::obj();
        labeled.set("label", Json::Str("y0".into()));
        let err = session.decode_row(&mut block, &labeled).unwrap_err();
        assert!(err.contains("label"), "case {case}: {err}");
        assert_eq!(block.rows(), before, "failed decodes must not grow the block");

        // Columnar ground truth, bit for bit.
        let got = block.dataset();
        let x0 = got.columns[0].as_numerical().unwrap();
        assert_eq!(x0.len(), m);
        for (i, (a, e)) in x0.iter().zip(&exp_x0).enumerate() {
            assert_eq!(a.to_bits(), e.to_bits(), "case {case} x0 row {i}: {a} vs {e}");
        }
        let x1 = got.columns[1].as_numerical().unwrap();
        assert!(x1.iter().all(|v| v.is_nan()), "absent x1 must be all-NaN");
        assert_eq!(got.columns[2].as_categorical().unwrap(), exp_cat.as_slice());
        assert_eq!(got.columns[3].as_boolean().unwrap(), exp_flag.as_slice());
        match &got.columns[4] {
            ColumnData::CategoricalSet { offsets, values } => {
                assert_eq!(offsets.len(), m + 1);
                for i in 0..m {
                    let s = &values[offsets[i] as usize..offsets[i + 1] as usize];
                    assert_eq!(s, exp_sets[i].as_slice(), "case {case} tokens row {i}");
                }
            }
            _ => panic!("tokens column must be a categorical set"),
        }
        // The decoded block also scores through the engine batch path.
        let out = session.predict_block(&mut block);
        assert_eq!(out.len(), m * session.output_dim());
    });
}

/// Threaded training is bit-identical to single-threaded. RF parallelizes
/// across trees (`num_threads` in `parallel_map`); GBT parallelizes each
/// node's split search across candidate features (`num_threads` in the
/// `SplitEngine` pool). Exercised on mixed-semantic data with NaN/missing
/// values in every column, bootstrap duplicates (RF), and both the exact
/// and the randomized (oblique + random-categorical, best-first) splitter
/// stacks — the configurations where per-candidate RNG derivation and the
/// `(gain, lowest feature index)` tie-break actually carry the guarantee.
#[test]
fn prop_threaded_training_bit_identical_to_sequential() {
    use ydf::learner::gbt::GbtConfig;
    use ydf::learner::random_forest::RandomForestConfig;
    use ydf::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};

    run_cases(0x7EAD5, 3, |rng, case| {
        // Large enough that the root nodes clear the engine's parallel
        // cutoff (rows × candidate units ≥ 512) — the pooled scatter must
        // actually run, not just its sequential fallback.
        let n = 150 + rng.uniform_usize(60);
        let classes = if case % 2 == 0 { 2 } else { 3 };
        let ds = mixed_ds(n, classes, rng);

        // Random Forest: bootstrap duplicates + sqrt attribute sampling.
        let mut rf = RandomForestConfig::new("label");
        rf.num_trees = 6;
        rf.compute_oob = false;
        rf.num_threads = 1;
        let seq = RandomForestLearner::new(rf.clone()).train(&ds).unwrap();
        rf.num_threads = 3;
        let par = RandomForestLearner::new(rf).train(&ds).unwrap();
        assert_eq!(
            seq.to_json().to_string(),
            par.to_json().to_string(),
            "case {case}: threaded RF must equal sequential"
        );

        // GBT, exact axis-aligned splitters (no scoring RNG at all).
        let mut gbt = GbtConfig::new("label");
        gbt.num_trees = 4;
        gbt.max_depth = 4;
        gbt.num_threads = 1;
        let seq = GradientBoostedTreesLearner::new(gbt.clone()).train(&ds).unwrap();
        gbt.num_threads = 4;
        let par = GradientBoostedTreesLearner::new(gbt).train(&ds).unwrap();
        assert_eq!(
            seq.to_json().to_string(),
            par.to_json().to_string(),
            "case {case}: threaded GBT must equal sequential"
        );

        // GBT, randomized stack: sparse oblique projections + random
        // categorical subsets + best-first growth (benchmark_rank1@v1).
        let mut gbt = GbtConfig::benchmark_rank1("label");
        gbt.num_trees = 3;
        gbt.num_threads = 1;
        let seq = GradientBoostedTreesLearner::new(gbt.clone()).train(&ds).unwrap();
        gbt.num_threads = 3;
        let par = GradientBoostedTreesLearner::new(gbt).train(&ds).unwrap();
        assert_eq!(
            seq.to_json().to_string(),
            par.to_json().to_string(),
            "case {case}: threaded randomized GBT must equal sequential"
        );

        // CART: single tree, every feature considered at every node —
        // the pure feature-parallel path.
        use ydf::learner::cart::{CartConfig, CartLearner};
        let mut cart = CartConfig::new("label");
        cart.num_threads = 1;
        let seq = CartLearner::new(cart.clone()).train(&ds).unwrap();
        cart.num_threads = 4;
        let par = CartLearner::new(cart).train(&ds).unwrap();
        assert_eq!(
            seq.to_json().to_string(),
            par.to_json().to_string(),
            "case {case}: threaded CART must equal sequential"
        );
    });
}

/// The arena's in-place span partition is exactly `partition_rows`:
/// same sides, same (stable) order, under duplicates, NaN-driven missing
/// routing, and nested sub-span partitioning.
#[test]
fn prop_arena_partition_matches_partition_rows() {
    run_cases(0xA2E4A, 25, |rng, case| {
        let n = 30 + rng.uniform_usize(60);
        let ds = mixed_ds(n, 2, rng);
        // Bootstrap-style duplicated row multiset.
        let rows: Vec<u32> =
            (0..n + n / 3).map(|_| rng.uniform_usize(n) as u32).collect();
        let labels: Vec<u32> = match &ds.columns[ds.num_columns() - 1] {
            ydf::dataset::ColumnData::Categorical(v) => v.clone(),
            _ => panic!("label column"),
        };
        let labels_view = Labels::Classification { labels: &labels, num_classes: 2 };
        let cfg = SplitterConfig { min_examples: 1, ..Default::default() };
        let index = ColumnIndex::new(&ds);
        let mut scratch = NodeScratch::new(ds.num_rows());
        let mut split_rng = Rng::seed_from_u64(case as u64);
        let candidates: Vec<usize> = (0..ds.num_columns() - 1).collect();
        let split = match find_best_split(
            &ds, &rows, &labels_view, &candidates, &cfg, &index, &mut scratch, &mut split_rng,
        ) {
            Some(s) => s,
            None => return,
        };

        let (pos, neg) =
            partition_rows(&ds, &rows, &split.condition, split.missing_to_positive);
        let mut arena = RowArena::new();
        arena.reset(&rows);
        let n_pos = arena.partition_span(
            &ds,
            &split.condition,
            split.missing_to_positive,
            0,
            rows.len(),
        );
        assert_eq!(n_pos, pos.len(), "case {case}: positive count");
        assert_eq!(arena.span(0, n_pos), pos.as_slice(), "case {case}: positive side");
        assert_eq!(
            arena.span(n_pos, rows.len() - n_pos),
            neg.as_slice(),
            "case {case}: negative side"
        );

        // Re-partition the positive child span (as the grower does) —
        // must match partition_rows applied to the positive side.
        if pos.len() > 1 {
            let (pp, pn) =
                partition_rows(&ds, &pos, &split.condition, !split.missing_to_positive);
            let k = arena.partition_span(
                &ds,
                &split.condition,
                !split.missing_to_positive,
                0,
                n_pos,
            );
            assert_eq!(arena.span(0, k), pp.as_slice(), "case {case}: nested positive");
            assert_eq!(
                arena.span(k, n_pos - k),
                pn.as_slice(),
                "case {case}: nested negative"
            );
            // The sibling (negative) span was untouched by the nested
            // partition.
            assert_eq!(
                arena.span(n_pos, rows.len() - n_pos),
                neg.as_slice(),
                "case {case}: sibling span must survive nested partitions"
            );
        }
    });
}

#[test]
fn prop_kfold_partitions() {
    run_cases(0x5EED, 20, |rng, _| {
        let n = 10 + rng.uniform_usize(200);
        let folds = 2 + rng.uniform_usize(8);
        let ds = ydf::dataset::synthetic::adult_like(n, rng.next_u64());
        let fold_rows = ds.kfold_indices(folds, rng.next_u64());
        assert_eq!(fold_rows.len(), folds);
        let mut all: Vec<usize> = fold_rows.concat();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
        let sizes: Vec<usize> = fold_rows.iter().map(|f| f.len()).collect();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        assert!(max - min <= 1, "folds must be balanced: {sizes:?}");
    });
}

/// Measured engine routing never changes predictions, only which
/// bit-identical engine computes them: for randomized mixed-semantic
/// models (NaN numericals, missing categoricals/booleans, optional
/// categorical-set columns, oblique splits, binary/multiclass/
/// regression), a calibrated router's output at every bucket's row
/// count — and one row past each bucket boundary, where [`route`]
/// switches engines — is bit-for-bit the naive reference. Every
/// candidate variant the calibration pass can rank is itself checked
/// against naive, so whichever ranking the timing jitter produces, the
/// routed bits are pinned.
#[test]
fn prop_router_bit_identical_across_buckets() {
    use ydf::inference::naive::NaiveEngine;
    use ydf::inference::router::{self, Router};
    use ydf::inference::InferenceEngine;
    use ydf::learner::gbt::GbtConfig;
    use ydf::learner::{GradientBoostedTreesLearner, Learner};
    use ydf::model::Task;

    run_cases(0x40073, 6, |rng, case| {
        let classes = [2usize, 3, 0][case % 3];
        let with_catset = case % 2 == 0;
        // Enough rows to exercise the largest bucket (512) plus one.
        let ds = mixed_ds_opt(520, classes, with_catset, rng);
        let model: Box<dyn ydf::model::Model> = match (classes, case % 4) {
            (0, _) => {
                let mut cfg = GbtConfig::new("label");
                cfg.task = Task::Regression;
                cfg.num_trees = 3;
                cfg.max_depth = 4;
                GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()
            }
            (_, 1) => {
                // Oblique splits: QuickScorer refuses, so the router's
                // candidate set shrinks to flat/compiled — the routing
                // must stay exact over a partial engine roster too.
                let mut cfg = GbtConfig::benchmark_rank1("label");
                cfg.num_trees = 3;
                GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()
            }
            _ => {
                let mut cfg = GbtConfig::new("label");
                cfg.num_trees = 3;
                cfg.max_depth = 4;
                GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()
            }
        };

        let naive = NaiveEngine::compile(model.as_ref());
        let dim = naive.output_dim();
        let router = Router::calibrated_in_memory(model.as_ref(), 0x5EED ^ case as u64)
            .expect("forest models always compile at least one optimized engine");

        let mut sizes: Vec<usize> = router::BUCKETS.to_vec();
        sizes.extend(router::BUCKETS.iter().map(|&b| b + 1)); // cross each boundary
        for rows in sizes {
            let mut want = vec![0.0f64; rows * dim];
            naive.predict_batch(&ds, 0..rows, &mut want);
            let engine = router.route(rows);
            let mut got = vec![0.0f64; rows * dim];
            engine.predict_batch(&ds, 0..rows, &mut got);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    g.to_bits() == w.to_bits(),
                    "case {case}: {rows} rows via {}: value {i} differs: {g} vs {w}",
                    router.engine_name_for_rows(rows)
                );
            }
        }

        // The table the router picked from: every ranked variant is a
        // real engine, every bucket is covered, times are finite.
        let table = router::measure_model(model.as_ref(), 0x5EED ^ case as u64).unwrap();
        assert_eq!(table.buckets.len(), router::BUCKETS.len());
        for b in &table.buckets {
            assert!(!b.ranking.is_empty(), "case {case}: bucket {} unranked", b.rows);
            for (variant, ns) in &b.ranking {
                assert!(ns.is_finite() && *ns >= 0.0, "case {case}: bad time {ns}");
                assert_eq!(
                    router::Variant::parse(&variant.tag()),
                    Some(*variant),
                    "case {case}: variant tag must round-trip"
                );
            }
        }
    });
}
