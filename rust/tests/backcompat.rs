//! Backwards compatibility (§3.11): model files are compatible forever.
//! A v1 model fixture is embedded verbatim; this test must keep passing
//! for every future format version.

use ydf::dataset::AttrValue;
use ydf::model::io::model_from_string;

/// A v1 GRADIENT_BOOSTED_TREES model file, written by format version 1.
/// DO NOT REGENERATE — the point is that old bytes keep loading.
const V1_GBT_FIXTURE: &str = r#"{
  "format_version": 1,
  "initial_predictions": [-0.5],
  "label_col": 1,
  "loss": "BINOMIAL_LOG_LIKELIHOOD",
  "model_type": "GRADIENT_BOOSTED_TREES",
  "task": "CLASSIFICATION",
  "trees_per_iter": 1,
  "validation_loss": 0.42,
  "spec": {
    "columns": [
      {"name": "age", "semantic": "NUMERICAL", "dictionary": [], "dict_counts": [],
       "ood_items": 0, "mean": 40.0, "min": 17.0, "max": 90.0, "std": 12.0,
       "missing_count": 0, "manually_defined": false},
      {"name": "income", "semantic": "CATEGORICAL",
       "dictionary": ["<=50K", ">50K"], "dict_counts": [70, 30],
       "ood_items": 0, "mean": 0, "min": 0, "max": 0, "std": 0,
       "missing_count": 0, "manually_defined": false}
    ]
  },
  "trees": [
    {"nodes": [
      {"cond": {"type": "higher", "attr": 0, "threshold": 35.5},
       "pos": 1, "neg": 2, "miss_pos": false, "score": 0.8, "n": 100},
      {"value": [0.6], "n": 40},
      {"value": [-0.4], "n": 60}
    ]}
  ]
}"#;

const V1_RF_FIXTURE: &str = r#"{
  "format_version": 1,
  "label_col": 1,
  "model_type": "RANDOM_FOREST",
  "task": "CLASSIFICATION",
  "winner_take_all": false,
  "spec": {
    "columns": [
      {"name": "x", "semantic": "NUMERICAL", "dictionary": [], "dict_counts": [],
       "ood_items": 0, "mean": 0.0, "min": -1.0, "max": 1.0, "std": 0.5,
       "missing_count": 0, "manually_defined": false},
      {"name": "y", "semantic": "CATEGORICAL",
       "dictionary": ["a", "b"], "dict_counts": [5, 5],
       "ood_items": 0, "mean": 0, "min": 0, "max": 0, "std": 0,
       "missing_count": 0, "manually_defined": false}
    ]
  },
  "trees": [
    {"nodes": [
      {"cond": {"type": "higher", "attr": 0, "threshold": 0.0},
       "pos": 1, "neg": 2, "miss_pos": true, "score": 0.3, "n": 10},
      {"value": [0.2, 0.8], "n": 5},
      {"value": [0.9, 0.1], "n": 5}
    ]}
  ]
}"#;

#[test]
fn v1_gbt_fixture_loads_and_predicts() {
    let model = model_from_string(V1_GBT_FIXTURE).expect("v1 file must load forever");
    assert_eq!(model.model_type(), "GRADIENT_BOOSTED_TREES");
    assert_eq!(model.class_names(), vec!["<=50K", ">50K"]);
    // age=50 -> positive branch: score = -0.5 + 0.6 = 0.1 -> sigmoid.
    let p = model.predict_row(&vec![AttrValue::Num(50.0), AttrValue::Missing]);
    let expected = 1.0 / (1.0 + (-0.1f64).exp());
    assert!((p[1] - expected).abs() < 1e-6, "{p:?}");
    // age=20 -> negative branch: score = -0.5 - 0.4 = -0.9.
    let p = model.predict_row(&vec![AttrValue::Num(20.0), AttrValue::Missing]);
    let expected = 1.0 / (1.0 + (0.9f64).exp());
    assert!((p[1] - expected).abs() < 1e-6, "{p:?}");
}

#[test]
fn v1_rf_fixture_loads_and_respects_missing_branch() {
    let model = model_from_string(V1_RF_FIXTURE).expect("v1 file must load forever");
    assert_eq!(model.model_type(), "RANDOM_FOREST");
    // Missing x -> miss_pos=true -> positive leaf [0.2, 0.8].
    let p = model.predict_row(&vec![AttrValue::Missing, AttrValue::Missing]);
    assert!((p[1] - 0.8).abs() < 1e-6, "{p:?}");
}

#[test]
fn deterministic_training_regression_guard() {
    // §3.11: same learner + same dataset => same model. Pin a structural
    // digest of a trained model; if this changes, determinism (or the
    // hyper-parameter backwards-compatibility rule) broke.
    use ydf::dataset::synthetic;
    use ydf::learner::gbt::GbtConfig;
    use ydf::learner::{GradientBoostedTreesLearner, Learner};
    let ds = synthetic::adult_like(200, 77);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = 3;
    cfg.max_depth = 3;
    let m1 = GradientBoostedTreesLearner::new(cfg.clone()).train(&ds).unwrap();
    let m2 = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
    let j1 = m1.to_json().to_string();
    let j2 = m2.to_json().to_string();
    assert_eq!(j1, j2);
    // Structural invariants that the fixed seed pins down.
    let gbt = m1
        .as_any()
        .downcast_ref::<ydf::model::GradientBoostedTreesModel>()
        .unwrap();
    assert_eq!(gbt.trees_per_iter, 1);
    assert!(!gbt.trees.is_empty());
}
