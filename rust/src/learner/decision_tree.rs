//! Decision-tree growth: local (divide-and-conquer) and global best-first
//! (leaf-wise, Shi 2007) strategies (§3.11), generic over label type.

use crate::dataset::Dataset;
use crate::model::tree::{DecisionTree, Node};
use crate::splitter::score::Labels;
use crate::splitter::{find_best_split, partition_rows, SplitterConfig, TrainingCache};
use crate::utils::rng::Rng;

/// Tree growth strategy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GrowingStrategy {
    /// Divide and conquer, depth-first, bounded by `max_depth`.
    Local,
    /// Best-first (leaf-wise) growth bounded by a total leaf budget —
    /// `growing_strategy: BEST_FIRST_GLOBAL` of benchmark_rank1@v1.
    BestFirstGlobal { max_num_leaves: usize },
}

/// Number of candidate attributes examined per split (Breiman's rule of
/// thumb √p is the RF classification default — §3.11).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttrSampling {
    All,
    Sqrt,
    Ratio(f64),
    Count(usize),
}

impl AttrSampling {
    pub fn num_candidates(&self, total: usize) -> usize {
        match self {
            AttrSampling::All => total,
            AttrSampling::Sqrt => ((total as f64).sqrt().ceil() as usize).clamp(1, total),
            AttrSampling::Ratio(r) => {
                (((total as f64) * r).ceil() as usize).clamp(1, total)
            }
            AttrSampling::Count(k) => (*k).clamp(1, total),
        }
    }
}

/// Configuration for one tree.
#[derive(Clone, Debug)]
pub struct TreeConfig {
    pub max_depth: usize,
    pub min_examples: usize,
    pub splitter: SplitterConfig,
    pub growing: GrowingStrategy,
    pub attr_sampling: AttrSampling,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig {
            max_depth: 16,
            min_examples: 5,
            splitter: SplitterConfig::default(),
            growing: GrowingStrategy::Local,
            attr_sampling: AttrSampling::All,
        }
    }
}

fn leaf_from_rows(rows: &[u32], labels: &Labels) -> Node {
    let mut acc = labels.new_acc();
    for &r in rows {
        acc.add(labels, r as usize);
    }
    Node::leaf(acc.leaf_value(labels), rows.len() as f64)
}

fn sample_features(features: &[usize], sampling: AttrSampling, rng: &mut Rng) -> Vec<usize> {
    let k = sampling.num_candidates(features.len());
    if k >= features.len() {
        features.to_vec()
    } else {
        rng.sample_without_replacement(features.len(), k)
            .into_iter()
            .map(|i| features[i])
            .collect()
    }
}

/// Grows one decision tree on the `rows` of `ds` (duplicates allowed —
/// bootstrap), splitting on `features`.
pub fn grow_tree(
    ds: &Dataset,
    rows: Vec<u32>,
    labels: &Labels,
    features: &[usize],
    cfg: &TreeConfig,
    cache: &mut TrainingCache,
    rng: &mut Rng,
) -> DecisionTree {
    match cfg.growing {
        GrowingStrategy::Local => grow_local(ds, rows, labels, features, cfg, cache, rng),
        GrowingStrategy::BestFirstGlobal { max_num_leaves } => {
            grow_best_first(ds, rows, labels, features, cfg, cache, rng, max_num_leaves)
        }
    }
}

fn grow_local(
    ds: &Dataset,
    rows: Vec<u32>,
    labels: &Labels,
    features: &[usize],
    cfg: &TreeConfig,
    cache: &mut TrainingCache,
    rng: &mut Rng,
) -> DecisionTree {
    let mut tree = DecisionTree { nodes: vec![leaf_from_rows(&rows, labels)] };
    // Stack of (node index, rows, depth). Depth-first keeps peak memory at
    // O(depth) row-sets.
    let mut stack = vec![(0usize, rows, 0usize)];
    while let Some((idx, node_rows, depth)) = stack.pop() {
        if depth >= cfg.max_depth || node_rows.len() < 2 * cfg.min_examples.max(1) {
            continue; // keep as leaf
        }
        let cands = sample_features(features, cfg.attr_sampling, rng);
        let split = match find_best_split(
            ds,
            &node_rows,
            labels,
            &cands,
            &cfg.splitter,
            cache,
            rng,
        ) {
            Some(s) => s,
            None => continue,
        };
        let (pos_rows, neg_rows) =
            partition_rows(ds, &node_rows, &split.condition, split.missing_to_positive);
        if pos_rows.len() < cfg.min_examples || neg_rows.len() < cfg.min_examples {
            continue;
        }
        let pos_idx = tree.nodes.len() as u32;
        tree.nodes.push(leaf_from_rows(&pos_rows, labels));
        let neg_idx = tree.nodes.len() as u32;
        tree.nodes.push(leaf_from_rows(&neg_rows, labels));
        {
            let node = &mut tree.nodes[idx];
            node.condition = Some(split.condition);
            node.positive = pos_idx;
            node.negative = neg_idx;
            node.missing_to_positive = split.missing_to_positive;
            node.score = split.gain as f32;
            node.value = vec![];
        }
        stack.push((pos_idx as usize, pos_rows, depth + 1));
        stack.push((neg_idx as usize, neg_rows, depth + 1));
    }
    tree
}

#[allow(clippy::too_many_arguments)]
fn grow_best_first(
    ds: &Dataset,
    rows: Vec<u32>,
    labels: &Labels,
    features: &[usize],
    cfg: &TreeConfig,
    cache: &mut TrainingCache,
    rng: &mut Rng,
    max_num_leaves: usize,
) -> DecisionTree {
    let mut tree = DecisionTree { nodes: vec![leaf_from_rows(&rows, labels)] };
    // Expandable leaves with their precomputed best split.
    struct Open {
        idx: usize,
        rows: Vec<u32>,
        depth: usize,
        split: crate::splitter::SplitCandidate,
    }
    let mut open: Vec<Open> = Vec::new();
    let mut try_open = |tree: &DecisionTree,
                        idx: usize,
                        rows: Vec<u32>,
                        depth: usize,
                        cache: &mut TrainingCache,
                        rng: &mut Rng,
                        open: &mut Vec<Open>| {
        let _ = tree;
        if depth >= cfg.max_depth || rows.len() < 2 * cfg.min_examples.max(1) {
            return;
        }
        let cands = sample_features(features, cfg.attr_sampling, rng);
        if let Some(split) =
            find_best_split(ds, &rows, labels, &cands, &cfg.splitter, cache, rng)
        {
            open.push(Open { idx, rows, depth, split });
        }
    };
    try_open(&tree, 0, rows, 0, cache, rng, &mut open);
    let mut num_leaves = 1usize;
    while num_leaves < max_num_leaves && !open.is_empty() {
        // Pop the highest-gain candidate (leaf-wise growth).
        let best_i = open
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.split.gain.partial_cmp(&b.1.split.gain).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let Open { idx, rows, depth, split } = open.swap_remove(best_i);
        let (pos_rows, neg_rows) =
            partition_rows(ds, &rows, &split.condition, split.missing_to_positive);
        if pos_rows.len() < cfg.min_examples || neg_rows.len() < cfg.min_examples {
            continue;
        }
        let pos_idx = tree.nodes.len();
        tree.nodes.push(leaf_from_rows(&pos_rows, labels));
        let neg_idx = tree.nodes.len();
        tree.nodes.push(leaf_from_rows(&neg_rows, labels));
        {
            let node = &mut tree.nodes[idx];
            node.condition = Some(split.condition);
            node.positive = pos_idx as u32;
            node.negative = neg_idx as u32;
            node.missing_to_positive = split.missing_to_positive;
            node.score = split.gain as f32;
            node.value = vec![];
        }
        num_leaves += 1; // one leaf became two
        try_open(&tree, pos_idx, pos_rows, depth + 1, cache, rng, &mut open);
        try_open(&tree, neg_idx, neg_rows, depth + 1, cache, rng, &mut open);
    }
    tree
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::{ColumnSpec, DataSpec};
    use crate::dataset::ColumnData;

    fn xor_dataset(n: usize) -> (Dataset, Vec<u32>) {
        // XOR over two features: needs depth 2.
        let mut rng = Rng::seed_from_u64(3);
        let x0: Vec<f32> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        let x1: Vec<f32> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        let y: Vec<u32> =
            x0.iter().zip(&x1).map(|(&a, &b)| ((a > 0.0) ^ (b > 0.0)) as u32).collect();
        let spec = DataSpec {
            columns: vec![ColumnSpec::numerical("x0"), ColumnSpec::numerical("x1")],
        };
        let ds = Dataset::new(
            spec,
            vec![ColumnData::Numerical(x0), ColumnData::Numerical(x1)],
        )
        .unwrap();
        (ds, y)
    }

    fn accuracy(tree: &DecisionTree, ds: &Dataset, y: &[u32]) -> f64 {
        let mut correct = 0usize;
        for r in 0..ds.num_rows() {
            let leaf = tree.eval_ds(ds, r);
            let pred = leaf
                .value
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred as u32 == y[r] {
                correct += 1;
            }
        }
        correct as f64 / ds.num_rows() as f64
    }

    #[test]
    fn local_growth_learns_xor() {
        let (ds, y) = xor_dataset(400);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig {
            max_depth: 4,
            min_examples: 2,
            ..Default::default()
        };
        let mut cache = TrainingCache::new(&ds);
        let rows: Vec<u32> = (0..ds.num_rows() as u32).collect();
        let tree = grow_tree(
            &ds,
            rows,
            &labels,
            &[0, 1],
            &cfg,
            &mut cache,
            &mut Rng::seed_from_u64(1),
        );
        assert!(tree.max_depth() >= 2);
        let acc = accuracy(&tree, &ds, &y);
        assert!(acc > 0.95, "accuracy {acc}");
    }

    #[test]
    fn best_first_respects_leaf_budget() {
        let (ds, y) = xor_dataset(400);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig {
            max_depth: 10,
            min_examples: 2,
            growing: GrowingStrategy::BestFirstGlobal { max_num_leaves: 8 },
            ..Default::default()
        };
        let mut cache = TrainingCache::new(&ds);
        let rows: Vec<u32> = (0..ds.num_rows() as u32).collect();
        let tree = grow_tree(
            &ds,
            rows,
            &labels,
            &[0, 1],
            &cfg,
            &mut cache,
            &mut Rng::seed_from_u64(1),
        );
        assert!(tree.num_leaves() <= 8);
        assert!(accuracy(&tree, &ds, &y) > 0.9);
    }

    #[test]
    fn max_depth_zero_is_single_leaf() {
        let (ds, y) = xor_dataset(50);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig { max_depth: 0, ..Default::default() };
        let mut cache = TrainingCache::new(&ds);
        let tree = grow_tree(
            &ds,
            (0..50).collect(),
            &labels,
            &[0, 1],
            &cfg,
            &mut cache,
            &mut Rng::seed_from_u64(1),
        );
        assert_eq!(tree.num_nodes(), 1);
        assert!(tree.nodes[0].is_leaf());
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, y) = xor_dataset(200);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig { attr_sampling: AttrSampling::Sqrt, ..Default::default() };
        let grow = |seed: u64| {
            let mut cache = TrainingCache::new(&ds);
            grow_tree(
                &ds,
                (0..200).collect(),
                &labels,
                &[0, 1],
                &cfg,
                &mut cache,
                &mut Rng::seed_from_u64(seed),
            )
        };
        let a = grow(7);
        let b = grow(7);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        let c = grow(8);
        // Different seed may legitimately produce an identical tree on this
        // simple task, but number of nodes is a cheap sanity check that the
        // seed is actually used.
        let _ = c;
    }

    #[test]
    fn attr_sampling_counts() {
        assert_eq!(AttrSampling::All.num_candidates(10), 10);
        assert_eq!(AttrSampling::Sqrt.num_candidates(100), 10);
        assert_eq!(AttrSampling::Sqrt.num_candidates(10), 4);
        assert_eq!(AttrSampling::Ratio(0.5).num_candidates(10), 5);
        assert_eq!(AttrSampling::Count(3).num_candidates(10), 3);
        assert_eq!(AttrSampling::Count(30).num_candidates(10), 10);
        assert_eq!(AttrSampling::Ratio(0.0).num_candidates(10), 1);
    }

    #[test]
    fn min_examples_leaf_size() {
        let (ds, y) = xor_dataset(300);
        let labels = Labels::Classification { labels: &y, num_classes: 2 };
        let cfg = TreeConfig { min_examples: 20, max_depth: 20, ..Default::default() };
        let mut cache = TrainingCache::new(&ds);
        let tree = grow_tree(
            &ds,
            (0..300).collect(),
            &labels,
            &[0, 1],
            &cfg,
            &mut cache,
            &mut Rng::seed_from_u64(2),
        );
        for n in &tree.nodes {
            if n.is_leaf() {
                assert!(n.num_examples >= 20.0, "leaf with {} examples", n.num_examples);
            }
        }
    }
}
